"""Layer-2: the paper's Vision Transformer in JAX (build-time only).

This module defines the model *and* every AOT entry point the Rust
coordinator calls (see DESIGN.md §2). The trunk parameters are carried as a
single flat f32 vector so the Rust side owns exactly three parameter
tensors (trunk, head_w, head_b); the manifest records the (name, shape,
offset) layout of the flat vector so the Muon optimizer can recover the
2-D matrices.

Entry points (all shapes static, lowered per preset by aot.py):

  train_grads        Forward + Backward (Algorithm 1 control batch /
                     Algorithm 2 baseline)
  cheap_fwd          CheapForward — no autodiff cache, pallas attention
  predict_grad       PredictGrad — pallas predictor kernels
  per_example_grads  vmap'd per-example trunk grads (predictor fitting and
                     the Sec. 5.3 cosine diagnostics)
  cv_combine         eq. (1) combine on device

The ViT follows the paper Sec. 7.1: patch 4 on 32x32 (64 tokens + CLS),
pre-LN blocks, MLP ratio 4, cross-entropy with label smoothing 0.05.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import cv_combine as cv_kernel
from .kernels import predict_grad as pg_kernel
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture hyperparameters for one preset."""

    image: int = 32
    patch: int = 4
    width: int = 64
    depth: int = 4
    heads: int = 4
    classes: int = 10
    mlp_ratio: int = 4
    label_smoothing: float = 0.05
    # Predictor hyperparameters (Sec. 4): NTK-rank r and fitting sizes.
    rank: int = 16
    n_chunk: int = 16   # per-example-grad chunk materialized per call
    n_fit: int = 128    # examples collected per predictor refit

    @property
    def tokens(self) -> int:
        side = self.image // self.patch
        return side * side + 1  # + CLS token

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * 3

    @property
    def head_dim(self) -> int:
        return self.width // self.heads

    @property
    def feat_dim(self) -> int:
        return (self.width + 1) * self.width  # (D+1)*D bilinear features


PRESETS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(image=16, patch=8, width=32, depth=2, heads=2,
                        rank=8, n_chunk=16, n_fit=64),
    "small": ModelConfig(image=32, patch=4, width=64, depth=4, heads=4,
                         rank=16, n_chunk=16, n_fit=128),
    # The paper's configuration (Sec. 7.1): width 192, 12 layers, 3 heads.
    "paper": ModelConfig(image=32, patch=4, width=192, depth=12, heads=3,
                         rank=16, n_chunk=8, n_fit=192),
}


# ---------------------------------------------------------------------------
# Trunk parameter layout (flat f32 vector <-> named tensors)
# ---------------------------------------------------------------------------

def trunk_layout(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], bool]]:
    """Ordered (name, shape, muon_eligible) triples of the trunk.

    The order here IS the flat-vector layout contract with the Rust side —
    recorded verbatim in manifest.json. Muon (Jordan et al., 2024)
    orthogonalizes only genuine 2-D hidden-layer matrices: embeddings,
    positional tables, LN parameters and biases fall back to AdamW.
    """
    d, t, pd, mr = cfg.width, cfg.tokens, cfg.patch_dim, cfg.mlp_ratio
    layout: List[Tuple[str, Tuple[int, ...], bool]] = [
        ("patch_embed/w", (pd, d), False),
        ("patch_embed/b", (d,), False),
        ("pos_embed", (t, d), False),
        ("cls_token", (d,), False),
    ]
    for i in range(cfg.depth):
        p = f"blk{i}"
        layout += [
            (f"{p}/ln1/scale", (d,), False),
            (f"{p}/ln1/bias", (d,), False),
            (f"{p}/attn/wqkv", (d, 3 * d), True),
            (f"{p}/attn/bqkv", (3 * d,), False),
            (f"{p}/attn/wo", (d, d), True),
            (f"{p}/attn/bo", (d,), False),
            (f"{p}/ln2/scale", (d,), False),
            (f"{p}/ln2/bias", (d,), False),
            (f"{p}/mlp/w1", (d, mr * d), True),
            (f"{p}/mlp/b1", (mr * d,), False),
            (f"{p}/mlp/w2", (mr * d, d), True),
            (f"{p}/mlp/b2", (d,), False),
        ]
    layout += [
        ("final_ln/scale", (d,), False),
        ("final_ln/bias", (d,), False),
    ]
    return layout


def trunk_size(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s, _ in trunk_layout(cfg))


def unflatten_trunk(flat: jnp.ndarray, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Slice the flat trunk vector into named tensors (traced, zero-copy
    under XLA — the slices fuse into consumers)."""
    params: Dict[str, jnp.ndarray] = {}
    off = 0
    for name, shape, _ in trunk_layout(cfg):
        n = 1
        for s in shape:
            n *= s
        params[name] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
        off += n
    return params


def init_params(cfg: ModelConfig, seed: int = 0):
    """Standard ViT init: trunc-normal(0.02) weights, zero biases, ones LN
    scale. Returns (trunk_flat, head_w, head_b) as numpy-compatible arrays."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape, _ in trunk_layout(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("/scale"):
            v = jnp.ones(shape, jnp.float32)
        elif name.endswith(("/b", "/bias", "/bqkv", "/bo", "/b1", "/b2")) or name == "cls_token":
            v = jnp.zeros(shape, jnp.float32)
        else:
            v = 0.02 * jax.random.truncated_normal(sub, -2.0, 2.0, shape, jnp.float32)
        chunks.append(v.reshape(-1))
    trunk = jnp.concatenate(chunks)
    key, k1 = jax.random.split(key)
    head_w = 0.02 * jax.random.truncated_normal(k1, -2.0, 2.0, (cfg.width, cfg.classes), jnp.float32)
    head_b = jnp.zeros((cfg.classes,), jnp.float32)
    return trunk, head_w, head_b


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6) * scale + bias


def _patchify(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """(m, 3, H, W) -> (m, T-1, patch*patch*3)."""
    m = x.shape[0]
    p, side = cfg.patch, cfg.image // cfg.patch
    x = x.reshape(m, 3, side, p, side, p)
    x = x.transpose(0, 2, 4, 3, 5, 1)          # (m, side, side, p, p, 3)
    return x.reshape(m, side * side, p * p * 3)


# CheapForward attention path: "jnp" (default -- XLA-fused, no autodiff
# residuals kept) or "pallas" (the L1 kernel; under interpret=True it
# lowers to a grid while-loop, the faithful-but-slow CPU stand-in for the
# real Mosaic kernel). aot.py exposes --pallas-cheap.
CHEAP_ATTENTION = "jnp"


def _attention_block(x, params, prefix, cfg: ModelConfig, cheap: bool):
    m, t, d = x.shape
    h, dh = cfg.heads, cfg.head_dim
    qkv = x @ params[f"{prefix}/attn/wqkv"] + params[f"{prefix}/attn/bqkv"]
    qkv = qkv.reshape(m, t, 3, h, dh).transpose(2, 0, 3, 1, 4)  # (3, m, h, t, dh)
    q, k, v = qkv[0], qkv[1], qkv[2]
    if cheap and CHEAP_ATTENTION == "pallas":
        # CheapForward via the fused pallas attention kernel (L1).
        o = attn_kernel.mha(q, k, v)
    else:
        # jnp attention: differentiable on the training path; on the cheap
        # path XLA fuses it and keeps no residuals (pure forward).
        o = ref.mha_ref(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(m, t, d)
    return o @ params[f"{prefix}/attn/wo"] + params[f"{prefix}/attn/bo"]


def forward(trunk_flat: jnp.ndarray, head_w: jnp.ndarray, head_b: jnp.ndarray,
            x: jnp.ndarray, cfg: ModelConfig, cheap: bool = False):
    """ViT forward. Returns (a, logits): a is the final-LN CLS activation —
    the paper's last-hidden-layer a(x) that feeds the gradient predictor."""
    params = unflatten_trunk(trunk_flat, cfg)
    m = x.shape[0]
    tok = _patchify(x, cfg) @ params["patch_embed/w"] + params["patch_embed/b"]
    cls = jnp.broadcast_to(params["cls_token"], (m, 1, cfg.width))
    z = jnp.concatenate([cls, tok], axis=1) + params["pos_embed"]
    for i in range(cfg.depth):
        p = f"blk{i}"
        z = z + _attention_block(
            _layer_norm(z, params[f"{p}/ln1/scale"], params[f"{p}/ln1/bias"]),
            params, p, cfg, cheap)
        zn = _layer_norm(z, params[f"{p}/ln2/scale"], params[f"{p}/ln2/bias"])
        hln = jax.nn.gelu(zn @ params[f"{p}/mlp/w1"] + params[f"{p}/mlp/b1"])
        z = z + hln @ params[f"{p}/mlp/w2"] + params[f"{p}/mlp/b2"]
    a = _layer_norm(z[:, 0, :], params["final_ln/scale"], params["final_ln/bias"])
    logits = a @ head_w + head_b
    return a, logits


def _loss_from_logits(logits: jnp.ndarray, y: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    y_s = ref.smooth_labels(y, cfg.classes, cfg.label_smoothing)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_s * logp, axis=-1))


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------

def train_grads(trunk, head_w, head_b, x, y, *, cfg: ModelConfig):
    """Forward + Backward. Returns
    (loss, g_trunk, g_head_w, g_head_b, a, probs)."""

    def loss_fn(tr, hw, hb):
        a, logits = forward(tr, hw, hb, x, cfg, cheap=False)
        return _loss_from_logits(logits, y, cfg), (a, jax.nn.softmax(logits))

    (loss, (a, probs)), grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2), has_aux=True)(
        trunk, head_w, head_b)
    g_tr, g_hw, g_hb = grads
    return loss, g_tr, g_hw, g_hb, a, probs


def cheap_fwd(trunk, head_w, head_b, x, *, cfg: ModelConfig):
    """CheapForward: activations + probabilities only, pallas attention."""
    a, logits = forward(trunk, head_w, head_b, x, cfg, cheap=True)
    return a, jax.nn.softmax(logits)


def predict_grad(a, probs, y, head_w, b_mat, u_mat, *, cfg: ModelConfig):
    """PredictGrad via the L1 pallas kernels."""
    return pg_kernel.predict_grad(a, probs, y, head_w, b_mat, u_mat,
                                  cfg.label_smoothing)


def per_example_grads(trunk, head_w, head_b, x, y, *, cfg: ModelConfig):
    """Per-example trunk gradients G (n, P_T) plus (a, probs).

    Used by the predictor fit (Sec. 4: collect gradient samples, find the
    rank-r basis U) and by the Sec. 5.3 cosine diagnostics."""

    def one(xi, yi):
        def loss_fn(tr):
            a, logits = forward(tr, head_w, head_b, xi[None], cfg, cheap=False)
            return _loss_from_logits(logits, yi[None], cfg), (a[0], jax.nn.softmax(logits)[0])

        (loss, (a, p)), g = jax.value_and_grad(loss_fn, has_aux=True)(trunk)
        return g, a, p

    return jax.vmap(one)(x, y)


def cv_combine(g_ct, g_cp, g_p, f, *, cfg: ModelConfig):
    """eq. (1) combine over the full flattened gradient (pallas)."""
    del cfg
    return (cv_kernel.cv_combine(g_ct, g_cp, g_p, f),)
