"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (with hypothesis sweeps)
asserts that each Pallas kernel (run under ``interpret=True``) matches its
oracle to float32 tolerance. The oracles are also the place where the
paper's algebra (Sections 3 and 4.3) is written in its most readable form.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def append_ones(a: jnp.ndarray) -> jnp.ndarray:
    """[a ; 1] — append the bias coordinate to a batch of activations.

    a: (m, D) -> (m, D+1). This is the paper's ``[a(x); 1]`` vector,
    batched.
    """
    m = a.shape[0]
    return jnp.concatenate([a, jnp.ones((m, 1), a.dtype)], axis=1)


def smooth_labels(y: jnp.ndarray, num_classes: int, smoothing: float) -> jnp.ndarray:
    """One-hot encode with label smoothing (paper Sec. 4.3 / Sec. 7.1).

    y: (m,) int -> (m, C) float32. With smoothing s the target is
    ``(1-s) * onehot + s / C`` (mixture of one-hot and uniform).
    """
    onehot = jax.nn.one_hot(y, num_classes, dtype=jnp.float32)
    return (1.0 - smoothing) * onehot + smoothing / num_classes


def residual(probs: jnp.ndarray, y: jnp.ndarray, num_classes: int, smoothing: float) -> jnp.ndarray:
    """Classification residual r = p(x) - y (paper Sec. 4.3)."""
    return probs - smooth_labels(y, num_classes, smoothing)


def predict_trunk_grad_ref(
    a: jnp.ndarray,       # (m, D)   last-hidden-layer activations
    probs: jnp.ndarray,   # (m, C)   softmax probabilities
    y: jnp.ndarray,       # (m,)     int labels
    head_w: jnp.ndarray,  # (D, C)   head weight (paper's W_a^T)
    b_mat: jnp.ndarray,   # (r, (D+1)*D) bilinear coefficient matrix B
    u_mat: jnp.ndarray,   # (P_T, r) gradient subspace basis U
    smoothing: float,
) -> jnp.ndarray:
    """Reference for the paper's linear trunk-gradient predictor.

    Per example j:  h_j = W_a^T r_j,  c_j = B vec([a_j;1] h_j^T),
    g_j = U c_j. The mini-batch mean commutes with every linear step, so
    the batched predictor is three matmuls over the moment matrix
    F = (1/m) A1^T H:

        F = A1^T H / m          (D+1, D)
        c = B vec(F)            (r,)
        g = U c                 (P_T,)
    """
    m = a.shape[0]
    num_classes = probs.shape[1]
    r = residual(probs, y, num_classes, smoothing)      # (m, C)
    h = r @ head_w.T                                    # (m, D);  h_j = W_a^T r_j
    a1 = append_ones(a)                                 # (m, D+1)
    f_mom = a1.T @ h / m                                # (D+1, D)
    c = b_mat @ f_mom.reshape(-1)                       # (r,)
    return u_mat @ c                                    # (P_T,)


def head_grad_ref(
    a: jnp.ndarray,      # (m, D)
    probs: jnp.ndarray,  # (m, C)
    y: jnp.ndarray,      # (m,)
    smoothing: float,
):
    """Exact head gradient (paper Sec. 4.3): mean_j r_j (x) [a_j;1].

    For logits = a @ W + b with cross-entropy(+smoothing) mean loss:
        dL/dW = A^T R / m   (D, C)
        dL/db = mean_j r_j  (C,)
    """
    m = a.shape[0]
    num_classes = probs.shape[1]
    r = residual(probs, y, num_classes, smoothing)
    return a.T @ r / m, jnp.mean(r, axis=0)


def cv_combine_ref(
    g_ct: jnp.ndarray,  # true gradient on the control micro-batch
    g_cp: jnp.ndarray,  # predicted gradient on the control micro-batch
    g_p: jnp.ndarray,   # predicted gradient on the prediction micro-batch
    f: float,
) -> jnp.ndarray:
    """Control-variate combine, paper eq. (1):

        g = f * g_ct + (1 - f) * (g_p - (g_cp - g_ct))

    Unbiased by Lemma 1: E[g_cp] = E[g_p] so the correction term cancels
    the predictor's bias in expectation.
    """
    return f * g_ct + (1.0 - f) * (g_p - (g_cp - g_ct))


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Single-head scaled dot-product attention; q,k,v: (T, dh)."""
    dh = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.asarray(dh, q.dtype))
    p = jax.nn.softmax(s, axis=-1)
    return p @ v


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Batched multi-head attention; q,k,v: (B, h, T, dh)."""
    return jax.vmap(jax.vmap(attention_ref))(q, k, v)
