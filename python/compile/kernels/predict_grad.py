"""Layer-1 Pallas kernels for the paper's gradient-prediction hot path.

The predictor of Sec. 4.3 is deliberately factored into MXU-shaped matmul
work (see DESIGN.md §Hardware-Adaptation):

    F = A1^T H / m       (D+1, D)   activation/backprop-feature moment
    c = B vec(F)         (r,)       bilinear coefficients
    g = U c              (P_T,)     projection back to parameter space

The third step dominates (P_T x r) and is tiled over the trunk-parameter
dimension with a BlockSpec, which on a real TPU expresses the HBM->VMEM
streaming schedule of U (the only large operand). A1, H and B are small and
stay VMEM-resident across the whole grid.

All pallas_calls use ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls; numerics are validated against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Trunk-dimension tile for the U-projection. Under interpret=True each grid
# step lowers to one iteration of an XLA while-loop, so larger tiles are
# strictly better on CPU; 65536 x r f32 = 4 MiB at r=16. On a real TPU this
# would be re-tiled to ~2048 rows (2048*32*4 = 256 KiB VMEM per U block,
# 8-sublane aligned) -- see DESIGN.md Hardware-Adaptation.
TRUNK_BLOCK = 65536


def _moment_kernel(a1_ref, h_ref, b_ref, c_ref, *, m: int):
    """c = B vec(A1^T H / m). Single grid point; everything is small."""
    f_mom = a1_ref[...].T @ h_ref[...] * (1.0 / m)       # (D+1, D)
    c_ref[...] = b_ref[...] @ f_mom.reshape(-1)          # (r,)


def _uproj_kernel(u_ref, c_ref, g_ref):
    """One trunk tile of g = U c. Grid dim 0 walks the P_T dimension."""
    g_ref[...] = u_ref[...] @ c_ref[...]


def _head_grad_kernel(a_ref, r_ref, gw_ref, gb_ref, *, m: int):
    """Exact head gradient: gW = A^T R / m, gb = mean(R)."""
    inv_m = 1.0 / m
    a = a_ref[...]
    r = r_ref[...]
    gw_ref[...] = a.T @ r * inv_m
    gb_ref[...] = jnp.sum(r, axis=0) * inv_m


def predictor_coefficients(
    a1: jnp.ndarray,     # (m, D+1)
    h: jnp.ndarray,      # (m, D)
    b_mat: jnp.ndarray,  # (r, (D+1)*D)
) -> jnp.ndarray:
    """Pallas: bilinear coefficients c = B vec(A1^T H / m); returns (r,)."""
    m = a1.shape[0]
    r = b_mat.shape[0]
    return pl.pallas_call(
        functools.partial(_moment_kernel, m=m),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(a1, h, b_mat)


def project_u(u_mat: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Pallas: g = U c, tiled over the trunk dimension; returns (P_T,)."""
    p_t, r = u_mat.shape
    grid = (pl.cdiv(p_t, TRUNK_BLOCK),)
    return pl.pallas_call(
        _uproj_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TRUNK_BLOCK, r), lambda i: (i, 0)),
            pl.BlockSpec((r,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TRUNK_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p_t,), jnp.float32),
        interpret=True,
    )(u_mat, c)


def head_grad(a: jnp.ndarray, resid: jnp.ndarray):
    """Pallas: exact head gradients from activations and residuals."""
    m, d = a.shape
    c = resid.shape[1]
    return pl.pallas_call(
        functools.partial(_head_grad_kernel, m=m),
        out_shape=(
            jax.ShapeDtypeStruct((d, c), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        ),
        interpret=True,
    )(a, resid)


def predict_grad(
    a: jnp.ndarray,       # (m, D)
    probs: jnp.ndarray,   # (m, C)
    y: jnp.ndarray,       # (m,) int32
    head_w: jnp.ndarray,  # (D, C)
    b_mat: jnp.ndarray,   # (r, (D+1)*D)
    u_mat: jnp.ndarray,   # (P_T, r)
    smoothing: float,
):
    """Full PredictGrad (paper Algorithm 1): predicted trunk gradient plus
    the exact head gradient, for one micro-batch.

    Returns (g_trunk (P_T,), g_head_w (D, C), g_head_b (C,)).
    """
    num_classes = probs.shape[1]
    resid = ref.residual(probs, y, num_classes, smoothing)  # (m, C)
    h = resid @ head_w.T                                    # (m, D)
    a1 = ref.append_ones(a)                                 # (m, D+1)
    c = predictor_coefficients(a1, h, b_mat)
    g_trunk = project_u(u_mat, c)
    g_w, g_b = head_grad(a, resid)
    return g_trunk, g_w, g_b
