"""Layer-1 Pallas kernel: the control-variate combine of paper eq. (1).

    g = f * g_ct + (1 - f) * (g_p - (g_cp - g_ct))

Elementwise over the full flattened gradient (trunk + head), tiled over
parameter blocks so each VMEM-resident tile is touched exactly once —
this is a pure bandwidth kernel (4 streams in, 1 out).

f arrives as a (1,) array rather than a python constant so a single
compiled artifact serves every control fraction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536


def _cv_kernel(f_ref, gct_ref, gcp_ref, gp_ref, o_ref):
    f = f_ref[0]
    gct = gct_ref[...]
    o_ref[...] = f * gct + (1.0 - f) * (gp_ref[...] - (gcp_ref[...] - gct))


def cv_combine(
    g_ct: jnp.ndarray,  # (P,)
    g_cp: jnp.ndarray,  # (P,)
    g_p: jnp.ndarray,   # (P,)
    f: jnp.ndarray,     # (1,)
) -> jnp.ndarray:
    p = g_ct.shape[0]
    grid = (pl.cdiv(p, BLOCK),)
    vec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _cv_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)), vec, vec, vec],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,
    )(f, g_ct, g_cp, g_p)
