"""Layer-1 Pallas kernel: fused inference attention for CheapForward.

The paper's CheapForward (Sec. 2) is a forward pass that keeps no autodiff
residuals and may use inference-only fast paths. On TPU the natural
expression is a fused attention kernel: one (batch, head) grid point
computes scores, a numerically-stable row softmax and the value matmul
entirely in VMEM, never materialising the (T, T) attention matrix in HBM.

For CIFAR-scale ViTs (T = 65 tokens) a whole head fits in VMEM, so the
BlockSpec carves the (B, h, T, dh) operands into (1, 1, T, dh) blocks; on
longer sequences the same kernel would additionally tile T (flash-style
running max/sum) — noted in DESIGN.md §Hardware-Adaptation.

interpret=True everywhere: CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[0, 0]                       # (T, dh)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    s = (q @ k.T) * scale                 # (T, T)
    s_max = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - s_max)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = p @ v


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Fused multi-head attention; q,k,v: (B, h, T, dh) -> (B, h, T, dh)."""
    b, h, t, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    spec = pl.BlockSpec((1, 1, t, dh), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=(b, h),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), jnp.float32),
        interpret=True,
    )(q, k, v)
