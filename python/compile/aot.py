"""AOT driver: lower every Layer-2 entry point to HLO *text* artifacts.

Run once per preset (``make artifacts``); the Rust coordinator is fully
self-contained afterwards. Usage:

    python -m compile.aot --preset small --out ../artifacts/small \
        [--fs 0.25,0.5] [--micro 64] [--seed 0]

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 rust crate binds) rejects
(``proto.id() <= INT_MAX``). The HLO text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

Each artifact is shape-specialized (XLA requires static shapes), so we emit
one artifact per (entry point, batch size) pair actually used by the
coordinator, all recorded in ``manifest.json`` together with the trunk
parameter layout, model dims and initial parameters.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32

# Default micro-batch size per preset (paper: 2000; scaled for single-CPU
# PJRT — the accumulation structure, not the absolute size, is what the
# algorithm depends on).
DEFAULT_MICRO = {"tiny": 16, "small": 64, "paper": 64}
VAL_BATCH = 100


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _arg_meta(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def lower_entries(cfg: M.ModelConfig, micro: int, fs, out_dir: str):
    """Lower all (entry, batch-size) pairs; return the manifest dict."""
    d, c, r = cfg.width, cfg.classes, cfg.rank
    p_t = M.trunk_size(cfg)
    p_total = p_t + d * c + c
    img = (3, cfg.image, cfg.image)

    mcs = sorted({max(1, round(f * micro)) for f in fs})
    mps = sorted({micro - mc for mc in mcs if micro - mc > 0})
    train_sizes = sorted(set(mcs) | {micro})
    cheap_sizes = sorted(set(mps) | {VAL_BATCH})
    predict_sizes = sorted(set(mcs) | set(mps))

    artifacts = {}

    def emit(name, fn, specs, args_meta, outs_meta):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(text)
        artifacts[name] = {"file": fname, "args": args_meta, "outs": outs_meta}
        print(f"  {name}: {len(text)/1e6:.2f} MB HLO text")

    params_meta = [
        _arg_meta("trunk", (p_t,)),
        _arg_meta("head_w", (d, c)),
        _arg_meta("head_b", (c,)),
    ]

    for m in train_sizes:
        emit(
            f"train_grads_b{m}",
            functools.partial(M.train_grads, cfg=cfg),
            (_spec((p_t,)), _spec((d, c)), _spec((c,)), _spec((m,) + img), _spec((m,), I32)),
            params_meta + [_arg_meta("x", (m,) + img), _arg_meta("y", (m,), "i32")],
            [_arg_meta("loss", ()), _arg_meta("g_trunk", (p_t,)),
             _arg_meta("g_head_w", (d, c)), _arg_meta("g_head_b", (c,)),
             _arg_meta("a", (m, d)), _arg_meta("probs", (m, c))],
        )

    for m in cheap_sizes:
        emit(
            f"cheap_fwd_b{m}",
            functools.partial(M.cheap_fwd, cfg=cfg),
            (_spec((p_t,)), _spec((d, c)), _spec((c,)), _spec((m,) + img)),
            params_meta + [_arg_meta("x", (m,) + img)],
            [_arg_meta("a", (m, d)), _arg_meta("probs", (m, c))],
        )

    for m in predict_sizes:
        emit(
            f"predict_grad_b{m}",
            functools.partial(M.predict_grad, cfg=cfg),
            (_spec((m, d)), _spec((m, c)), _spec((m,), I32), _spec((d, c)),
             _spec((r, cfg.feat_dim)), _spec((p_t, r))),
            [_arg_meta("a", (m, d)), _arg_meta("probs", (m, c)),
             _arg_meta("y", (m,), "i32"), _arg_meta("head_w", (d, c)),
             _arg_meta("B", (r, cfg.feat_dim)), _arg_meta("U", (p_t, r))],
            [_arg_meta("g_trunk", (p_t,)), _arg_meta("g_head_w", (d, c)),
             _arg_meta("g_head_b", (c,))],
        )

    n = cfg.n_chunk
    emit(
        f"per_example_grads_b{n}",
        functools.partial(M.per_example_grads, cfg=cfg),
        (_spec((p_t,)), _spec((d, c)), _spec((c,)), _spec((n,) + img), _spec((n,), I32)),
        params_meta + [_arg_meta("x", (n,) + img), _arg_meta("y", (n,), "i32")],
        [_arg_meta("G", (n, p_t)), _arg_meta("a", (n, d)), _arg_meta("probs", (n, c))],
    )

    emit(
        "cv_combine",
        functools.partial(M.cv_combine, cfg=cfg),
        (_spec((p_total,)), _spec((p_total,)), _spec((p_total,)), _spec((1,))),
        [_arg_meta("g_ct", (p_total,)), _arg_meta("g_cp", (p_total,)),
         _arg_meta("g_p", (p_total,)), _arg_meta("f", (1,))],
        [_arg_meta("g", (p_total,))],
    )

    return artifacts


def build(preset: str, out_dir: str, fs, micro: int | None, seed: int):
    cfg = M.PRESETS[preset]
    micro = micro or DEFAULT_MICRO[preset]
    os.makedirs(out_dir, exist_ok=True)
    print(f"[aot] preset={preset} micro={micro} fs={fs} -> {out_dir}")

    artifacts = lower_entries(cfg, micro, fs, out_dir)

    trunk, head_w, head_b = M.init_params(cfg, seed)
    np.asarray(trunk, dtype="<f4").tofile(os.path.join(out_dir, "init_trunk.bin"))
    np.asarray(head_w, dtype="<f4").tofile(os.path.join(out_dir, "init_head_w.bin"))
    np.asarray(head_b, dtype="<f4").tofile(os.path.join(out_dir, "init_head_b.bin"))

    layout, off = [], 0
    for name, shape, muon in M.trunk_layout(cfg):
        n = int(np.prod(shape))
        layout.append({"name": name, "shape": list(shape), "offset": off,
                       "len": n, "muon": muon})
        off += n

    manifest = {
        "preset": preset,
        "model": {
            "image": cfg.image, "patch": cfg.patch, "width": cfg.width,
            "depth": cfg.depth, "heads": cfg.heads, "classes": cfg.classes,
            "mlp_ratio": cfg.mlp_ratio, "label_smoothing": cfg.label_smoothing,
            "tokens": cfg.tokens, "patch_dim": cfg.patch_dim,
        },
        "predictor": {"rank": cfg.rank, "n_chunk": cfg.n_chunk,
                      "n_fit": cfg.n_fit, "feat_dim": cfg.feat_dim},
        "dims": {"trunk_params": M.trunk_size(cfg),
                 "total_params": M.trunk_size(cfg) + cfg.width * cfg.classes + cfg.classes},
        "batch": {"micro": micro, "fs": list(fs), "val": VAL_BATCH},
        "trunk_layout": layout,
        "artifacts": artifacts,
        "init": {"trunk": "init_trunk.bin", "head_w": "init_head_w.bin",
                 "head_b": "init_head_b.bin", "seed": seed},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"[aot] wrote manifest with {len(artifacts)} artifacts")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="small", choices=sorted(M.PRESETS))
    ap.add_argument("--out", default=None, help="output dir (default ../artifacts/<preset>)")
    ap.add_argument("--fs", default="0.25", help="comma-separated control fractions")
    ap.add_argument("--micro", type=int, default=None, help="micro-batch size override")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pallas-cheap", action="store_true",
                    help="use the pallas attention kernel in cheap_fwd "
                         "(slow under CPU interpret; for kernel-path testing)")
    args = ap.parse_args()
    if args.pallas_cheap:
        from . import model as _m
        _m.CHEAP_ATTENTION = "pallas"
    out = args.out or os.path.join("..", "artifacts", args.preset)
    fs = [float(s) for s in args.fs.split(",") if s]
    build(args.preset, out, fs, args.micro, args.seed)


if __name__ == "__main__":
    main()
