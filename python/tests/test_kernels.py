"""Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes and seeds — these are the L1 correctness signal
required before any HLO artifact is trusted.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, cv_combine, predict_grad as pg, ref

SETTINGS = dict(max_examples=20, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


@st.composite
def predictor_case(draw):
    m = draw(st.integers(1, 12))
    d = draw(st.integers(2, 24))
    c = draw(st.integers(2, 12))
    r = draw(st.integers(1, 8))
    p_t = draw(st.sampled_from([17, 256, 2048, 5000]))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, d, c, r, p_t, seed


@given(predictor_case())
@settings(**SETTINGS)
def test_predict_grad_matches_ref(case):
    m, d, c, r, p_t, seed = case
    g = _rng(seed)
    a = jnp.asarray(g.normal(size=(m, d)), jnp.float32)
    probs = jax.nn.softmax(jnp.asarray(g.normal(size=(m, c)), jnp.float32))
    y = jnp.asarray(g.integers(0, c, m), jnp.int32)
    hw = jnp.asarray(g.normal(size=(d, c)), jnp.float32)
    b = jnp.asarray(g.normal(size=(r, (d + 1) * d)) / d, jnp.float32)
    u = jnp.asarray(g.normal(size=(p_t, r)) / np.sqrt(r), jnp.float32)
    gt, gw, gb = pg.predict_grad(a, probs, y, hw, b, u, 0.05)
    np.testing.assert_allclose(
        gt, ref.predict_trunk_grad_ref(a, probs, y, hw, b, u, 0.05),
        rtol=5e-4, atol=5e-4)
    gw_ref, gb_ref = ref.head_grad_ref(a, probs, y, 0.05)
    np.testing.assert_allclose(gw, gw_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gb, gb_ref, rtol=1e-5, atol=1e-6)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(2, 40), st.integers(1, 16),
       st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_attention_matches_ref(b, h, t, dh, seed):
    g = _rng(seed)
    q, k, v = (jnp.asarray(g.normal(size=(b, h, t, dh)), jnp.float32) for _ in range(3))
    np.testing.assert_allclose(attention.mha(q, k, v), ref.mha_ref(q, k, v),
                               rtol=3e-5, atol=3e-5)


@given(st.sampled_from([1, 7, 65536, 65537, 200000]),
       st.floats(0.05, 1.0), st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_cv_combine_matches_ref(p, f, seed):
    g = _rng(seed)
    gct, gcp, gp_ = (jnp.asarray(g.normal(size=(p,)), jnp.float32) for _ in range(3))
    out = cv_combine.cv_combine(gct, gcp, gp_, jnp.asarray([f], jnp.float32))
    np.testing.assert_allclose(out, ref.cv_combine_ref(gct, gcp, gp_, f),
                               rtol=2e-5, atol=2e-5)


def test_cv_combine_perfect_predictor_is_identity():
    """If h == g exactly, eq. (1) must reduce to the plain average direction:
    g = f*g + (1-f)*(g_p) with the correction cancelling."""
    g = _rng(3)
    gct = jnp.asarray(g.normal(size=(1000,)), jnp.float32)
    gp_ = jnp.asarray(g.normal(size=(1000,)), jnp.float32)
    # predictor perfect on the control batch: g_cp == g_ct
    out = cv_combine.cv_combine(gct, gct, gp_, jnp.asarray([0.25], jnp.float32))
    np.testing.assert_allclose(out, 0.25 * gct + 0.75 * gp_, rtol=1e-6, atol=1e-6)


def test_cv_combine_f_one_is_true_gradient():
    g = _rng(4)
    gct, gcp, gp_ = (jnp.asarray(g.normal(size=(128,)), jnp.float32) for _ in range(3))
    out = cv_combine.cv_combine(gct, gcp, gp_, jnp.asarray([1.0], jnp.float32))
    np.testing.assert_allclose(out, gct, rtol=1e-6, atol=1e-6)


def test_predictor_exact_when_low_rank_holds():
    """Sanity for Sec. 4: when per-example gradients truly are U c with
    c = B vec([a;1]h^T), the kernel predictor reproduces the batch-mean
    gradient exactly (it's the same linear algebra)."""
    g = _rng(5)
    m, d, c, r, p_t = 6, 8, 5, 3, 1000
    a = jnp.asarray(g.normal(size=(m, d)), jnp.float32)
    probs = jax.nn.softmax(jnp.asarray(g.normal(size=(m, c)), jnp.float32))
    y = jnp.asarray(g.integers(0, c, m), jnp.int32)
    hw = jnp.asarray(g.normal(size=(d, c)), jnp.float32)
    b = jnp.asarray(g.normal(size=(r, (d + 1) * d)), jnp.float32)
    u = jnp.asarray(g.normal(size=(p_t, r)), jnp.float32)
    resid = ref.residual(probs, y, c, 0.05)
    h = resid @ hw.T
    a1 = ref.append_ones(a)
    per_ex = [u @ (b @ jnp.outer(a1[j], h[j]).reshape(-1)) for j in range(m)]
    want = jnp.mean(jnp.stack(per_ex), axis=0)
    got, _, _ = pg.predict_grad(a, probs, y, hw, b, u, 0.05)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
