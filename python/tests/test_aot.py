"""Artifact/manifest consistency: what aot.py emits is what the Rust
runtime expects to load."""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="tiny artifacts not built (run `make artifacts`)")


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as fh:
        return json.load(fh)


def test_manifest_core_fields(manifest):
    for key in ("preset", "model", "predictor", "dims", "batch",
                "trunk_layout", "artifacts", "init"):
        assert key in manifest, key


def test_trunk_layout_offsets_contiguous(manifest):
    off = 0
    for entry in manifest["trunk_layout"]:
        assert entry["offset"] == off
        n = 1
        for s in entry["shape"]:
            n *= s
        assert entry["len"] == n
        off += n
    assert off == manifest["dims"]["trunk_params"]


def test_init_bins_match_dims(manifest):
    d = manifest["model"]["width"]
    c = manifest["model"]["classes"]
    trunk = np.fromfile(os.path.join(ART, manifest["init"]["trunk"]), dtype="<f4")
    assert trunk.shape[0] == manifest["dims"]["trunk_params"]
    hw = np.fromfile(os.path.join(ART, manifest["init"]["head_w"]), dtype="<f4")
    assert hw.shape[0] == d * c
    hb = np.fromfile(os.path.join(ART, manifest["init"]["head_b"]), dtype="<f4")
    assert hb.shape[0] == c
    assert np.isfinite(trunk).all() and np.isfinite(hw).all()


def test_artifacts_exist_and_are_hlo_text(manifest):
    for name, meta in manifest["artifacts"].items():
        path = os.path.join(ART, meta["file"])
        assert os.path.exists(path), name
        with open(path) as fh:
            head = fh.read(200)
        assert "HloModule" in head, name


def test_expected_entry_points_present(manifest):
    micro = manifest["batch"]["micro"]
    names = set(manifest["artifacts"])
    assert f"train_grads_b{micro}" in names          # baseline / f=1
    assert "cv_combine" in names
    assert any(n.startswith("cheap_fwd_b") for n in names)
    assert any(n.startswith("predict_grad_b") for n in names)
    assert any(n.startswith("per_example_grads_b") for n in names)


def test_artifact_arg_metadata_types(manifest):
    for name, meta in manifest["artifacts"].items():
        for arg in meta["args"] + meta["outs"]:
            assert arg["dtype"] in ("f32", "i32"), (name, arg)
            assert all(isinstance(s, int) and s > 0 for s in arg["shape"])
