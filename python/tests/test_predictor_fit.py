"""Numpy mirror of the Rust predictor-fit pipeline (predictor/fit.rs).

Validates the *algorithm* the Rust side implements: Gram-trick SVD for the
rank-r basis U (Sec. 4, low-rank NTK assumption) plus kernel-ridge
regression in the dual for the bilinear coefficient matrix B, using the
factorized feature Gram  K_phi = (A1 A1^T) o (H H^T).

If these tests pass, the Rust implementation has a proven-correct spec to
match (its unit tests reuse the same synthetic constructions).
"""

import numpy as np
import pytest


def fit_u(G: np.ndarray, r: int):
    """Rank-r left-singular basis of G^T (examples are rows of G).

    G: (n, P_T) per-example trunk gradients. Returns U (P_T, r) with
    orthonormal columns, via the n x n Gram eigendecomposition
    (P_T >> n makes the direct SVD infeasible; this is what Rust does).
    """
    n = G.shape[0]
    K = G @ G.T                             # (n, n)
    w, V = np.linalg.eigh(K)                # ascending
    idx = np.argsort(w)[::-1][:r]
    w_r, V_r = w[idx], V[:, idx]
    w_r = np.maximum(w_r, 1e-12)
    U = G.T @ (V_r / np.sqrt(w_r))          # (P_T, r)
    return U


def fit_b_dual(A1: np.ndarray, H: np.ndarray, C: np.ndarray, lam: float):
    """Kernel ridge for B: c_j ~= B vec(a1_j h_j^T).

    Feature Gram factorizes: K_phi[i,j] = (a1_i . a1_j)(h_i . h_j).
    alpha = (K_phi + lam I)^-1 C  (n, r);  B = sum_j alpha_j (x) phi_j
    materialized as  B[i] = A1^T diag(alpha[:, i]) H  reshaped.
    """
    n, r = C.shape
    K_phi = (A1 @ A1.T) * (H @ H.T)
    alpha = np.linalg.solve(K_phi + lam * np.eye(n), C)   # (n, r)
    d1, d = A1.shape[1], H.shape[1]
    B = np.empty((r, d1 * d), dtype=A1.dtype)
    for i in range(r):
        B[i] = ((A1 * alpha[:, i][:, None]).T @ H).reshape(-1)
    return B


def synthetic_low_rank_problem(rng, n=64, d=8, p_t=500, r=3):
    """Gradients exactly in a rank-r subspace with bilinear coefficients."""
    U_true = np.linalg.qr(rng.normal(size=(p_t, r)))[0]
    B_true = rng.normal(size=(r, (d + 1) * d))
    A = rng.normal(size=(n, d)).astype(np.float64)
    H = rng.normal(size=(n, d)).astype(np.float64)
    A1 = np.concatenate([A, np.ones((n, 1))], axis=1)
    Phi = np.stack([np.outer(A1[j], H[j]).reshape(-1) for j in range(n)])
    Ctrue = Phi @ B_true.T                   # (n, r)
    G = Ctrue @ U_true.T                     # (n, p_t)
    return U_true, B_true, A1, H, Phi, Ctrue, G


def test_fit_u_spans_true_subspace():
    rng = np.random.default_rng(0)
    U_true, _, _, _, _, _, G = synthetic_low_rank_problem(rng)
    U = fit_u(G, 3)
    # Column spaces must coincide: projector distance ~ 0.
    P1 = U @ np.linalg.pinv(U)
    P2 = U_true @ U_true.T
    assert np.linalg.norm(P1 - P2) < 1e-6


def test_fit_u_columns_orthonormal():
    rng = np.random.default_rng(1)
    G = rng.normal(size=(32, 200))
    U = fit_u(G, 5)
    np.testing.assert_allclose(U.T @ U, np.eye(5), atol=1e-8)


def test_dual_ridge_recovers_predictions():
    """With tiny ridge, predicted c on the training set matches targets."""
    rng = np.random.default_rng(2)
    _, _, A1, H, Phi, Ctrue, _ = synthetic_low_rank_problem(rng)
    B = fit_b_dual(A1, H, Ctrue, lam=1e-8)
    np.testing.assert_allclose(Phi @ B.T, Ctrue, rtol=1e-4, atol=1e-4)


def test_end_to_end_predictor_recovers_mean_gradient():
    """Full pipeline: fit U and B from samples, then the batched predictor
    (three matmuls, same as the pallas kernel) reproduces the true mean
    gradient of held-out examples from the same low-rank family."""
    rng = np.random.default_rng(3)
    U_true, B_true, A1, H, Phi, Ctrue, G = synthetic_low_rank_problem(rng, n=80)
    U = fit_u(G, 3)
    Cproj = G @ U                            # targets in fitted basis
    B = fit_b_dual(A1, H, Cproj, lam=1e-8)
    # held-out batch from the same generative family
    m, d, p_t = 16, 8, 500
    A_new = rng.normal(size=(m, d))
    H_new = rng.normal(size=(m, d))
    A1_new = np.concatenate([A_new, np.ones((m, 1))], axis=1)
    G_new = np.stack([
        U_true @ (B_true @ np.outer(A1_new[j], H_new[j]).reshape(-1))
        for j in range(m)])
    want = G_new.mean(axis=0)
    F = A1_new.T @ H_new / m
    got = U @ (B @ F.reshape(-1))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_gram_factorization_identity():
    """K_phi = (A1 A1^T) o (H H^T) — the identity that makes the dual fit
    O(n^2 (D+C)) instead of O(n^2 D^2)."""
    rng = np.random.default_rng(4)
    n, d = 20, 6
    A1 = rng.normal(size=(n, d + 1))
    H = rng.normal(size=(n, d))
    Phi = np.stack([np.outer(A1[j], H[j]).reshape(-1) for j in range(n)])
    np.testing.assert_allclose(Phi @ Phi.T, (A1 @ A1.T) * (H @ H.T), rtol=1e-10)


def test_ridge_regularization_shrinks_norm():
    rng = np.random.default_rng(5)
    _, _, A1, H, _, Ctrue, _ = synthetic_low_rank_problem(rng)
    b_small = fit_b_dual(A1, H, Ctrue, lam=1e-8)
    b_big = fit_b_dual(A1, H, Ctrue, lam=1e3)
    assert np.linalg.norm(b_big) < np.linalg.norm(b_small)
