"""Layer-2 model checks: the paper's Sec. 4.3 algebra on the real ViT,
consistency of cheap vs full forward, and per-example-grad aggregation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def batch():
    g = np.random.default_rng(7)
    x = jnp.asarray(g.normal(size=(6, 3, CFG.image, CFG.image)), jnp.float32)
    y = jnp.asarray(g.integers(0, CFG.classes, 6), jnp.int32)
    return x, y


def test_trunk_layout_is_contiguous():
    off = 0
    for name, shape, _ in M.trunk_layout(CFG):
        n = int(np.prod(shape))
        off += n
    assert off == M.trunk_size(CFG)


def test_unflatten_round_trip(params):
    trunk, _, _ = params
    d = M.unflatten_trunk(trunk, CFG)
    rebuilt = jnp.concatenate([d[n].reshape(-1) for n, _, _ in M.trunk_layout(CFG)])
    np.testing.assert_array_equal(np.asarray(trunk), np.asarray(rebuilt))


def test_head_grad_formula_matches_autodiff(params, batch):
    """Sec. 4.3: the head gradient is exactly r (x) [a;1] — validated
    against jax.grad on the full ViT loss."""
    trunk, hw, hb = params
    x, y = batch
    _, _, ghw, ghb, a, probs = M.train_grads(trunk, hw, hb, x, y, cfg=CFG)
    gw_ref, gb_ref = ref.head_grad_ref(a, probs, y, CFG.label_smoothing)
    np.testing.assert_allclose(ghw, gw_ref, rtol=3e-4, atol=1e-6)
    np.testing.assert_allclose(ghb, gb_ref, rtol=3e-4, atol=1e-6)


def test_cheap_fwd_matches_train_forward(params, batch):
    """CheapForward (pallas attention) and the autodiff forward must agree —
    they are the same function, differently scheduled."""
    trunk, hw, hb = params
    x, y = batch
    _, _, _, _, a, probs = M.train_grads(trunk, hw, hb, x, y, cfg=CFG)
    a2, p2 = M.cheap_fwd(trunk, hw, hb, x, cfg=CFG)
    np.testing.assert_allclose(a, a2, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(probs, p2, rtol=5e-4, atol=5e-4)


def test_per_example_grads_average_to_batch_grad(params, batch):
    trunk, hw, hb = params
    x, y = batch
    _, g_tr, _, _, _, _ = M.train_grads(trunk, hw, hb, x, y, cfg=CFG)
    G, _, _ = M.per_example_grads(trunk, hw, hb, x, y, cfg=CFG)
    np.testing.assert_allclose(np.mean(np.asarray(G), axis=0), g_tr,
                               rtol=2e-3, atol=3e-5)


def test_loss_decreases_under_sgd(params, batch):
    """30 full-gradient steps on one batch must reduce the loss — basic
    trainability of the L2 model."""
    trunk, hw, hb = params
    x, y = batch
    lr = 0.05
    first = None
    for i in range(30):
        loss, g_tr, g_hw, g_hb, _, _ = M.train_grads(trunk, hw, hb, x, y, cfg=CFG)
        if first is None:
            first = float(loss)
        trunk = trunk - lr * g_tr
        hw = hw - lr * g_hw
        hb = hb - lr * g_hb
    assert float(loss) < first - 0.1, (first, float(loss))


def test_probs_are_normalized(params, batch):
    trunk, hw, hb = params
    x, _ = batch
    _, probs = M.cheap_fwd(trunk, hw, hb, x, cfg=CFG)
    np.testing.assert_allclose(np.sum(np.asarray(probs), axis=1), 1.0, rtol=1e-5)


def test_presets_have_expected_sizes():
    # Paper Sec. 7.1: width 192, 12 layers, 3 heads, patch 4 on 32x32.
    p = M.PRESETS["paper"]
    assert (p.width, p.depth, p.heads, p.patch, p.image) == (192, 12, 3, 4, 32)
    assert p.tokens == 65  # 64 patches + CLS, "64 tokens + 1 classification token"
    assert M.trunk_size(M.PRESETS["tiny"]) < M.trunk_size(M.PRESETS["small"])
