//! Compile-time stub of the `xla` (xla-rs) PJRT binding.
//!
//! The real crate links against the XLA C libraries, which are not present
//! in the offline build image. This stub mirrors exactly the API surface
//! `lgp::runtime` uses so the whole crate compiles and tests run; every
//! entry point that would touch a device fails fast with a clear error.
//! All artifact-gated tests and benches check for `manifest.json` before
//! constructing a runtime, so on stub builds they skip rather than fail.
//! See DESIGN.md ADR-002; swap the path dependency for the real binding
//! when the XLA toolchain is available.

use std::fmt;
use std::path::Path;

/// Error type matching the shape the runtime formats with `{e:?}`.
pub struct XlaError {
    pub msg: String,
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError {
        msg: format!(
            "{what}: PJRT/XLA is unavailable in this offline build (the `xla` \
             dependency is a stub — see DESIGN.md ADR-002)"
        ),
    }
}

pub struct PjRtClient {
    _private: (),
}

pub struct PjRtDevice {
    _private: (),
}

pub struct PjRtBuffer {
    _private: (),
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

pub struct HloModuleProto {
    _private: (),
}

pub struct XlaComputation {
    _private: (),
}

pub struct Literal {
    _private: (),
}

impl PjRtClient {
    /// The real binding spins up the CPU PJRT plugin here; the stub fails
    /// fast so `Runtime::load` surfaces one actionable message.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling computation"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("uploading host buffer"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("downloading buffer"))
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "parsing HLO text {}",
            path.as_ref().display()
        )))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("decomposing output tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("reading literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = format!("{err:?}");
        assert!(msg.contains("stub"), "{msg}");
    }
}
