//! Offline subset of the `anyhow` error-handling crate.
//!
//! The build environment has no crates.io access, so the repository vendors
//! the slice of anyhow's API that the `lgp` crate actually uses:
//! `anyhow::Error`, `anyhow::Result`, and the `anyhow!` / `bail!` /
//! `ensure!` macros, with the same `?`-conversion and `{:#}` chain
//! formatting semantics. See DESIGN.md ADR-002 for the rationale; swap
//! this path dependency for `anyhow = "1"` when building online.

use std::error::Error as StdError;
use std::fmt;

enum Repr {
    /// Ad-hoc message built by `anyhow!` / `bail!` / `ensure!`.
    Msg(String),
    /// A concrete error converted through `?` — keeps its source chain.
    Wrapped(Box<dyn StdError + Send + Sync + 'static>),
}

/// Dynamic error type: any `std::error::Error` converts into it via `?`.
pub struct Error {
    repr: Repr,
}

impl Error {
    /// Construct from a display-able message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { repr: Repr::Msg(message.to_string()) }
    }

    /// Construct from a concrete error, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { repr: Repr::Wrapped(Box::new(error)) }
    }

    /// The root-most error message (no chain).
    pub fn root_message(&self) -> String {
        match &self.repr {
            Repr::Msg(m) => m.clone(),
            Repr::Wrapped(e) => e.to_string(),
        }
    }

    fn source_chain(&self) -> Vec<String> {
        let mut out = Vec::new();
        if let Repr::Wrapped(e) = &self.repr {
            let mut cur = e.source();
            while let Some(s) = cur {
                out.push(s.to_string());
                cur = s.source();
            }
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if f.alternate() {
            for cause in self.source_chain() {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        let chain = self.source_chain();
        if !chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for cause in chain {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// The blanket conversion that powers `?`. Coherent because `Error` itself
// deliberately does not implement `std::error::Error` (same trade anyhow
// makes).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `Result` with `anyhow::Error` as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message, a format string, or any
/// display-able value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn macros_build_messages() {
        fn check(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            let x = 41;
            if x < 10 {
                bail!("too small: {x}");
            }
            Ok(x + 1)
        }
        assert_eq!(check(true).unwrap(), 42);
        let e = check(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
    }

    #[test]
    fn ensure_without_message_names_the_condition() {
        fn inner(n: usize) -> Result<()> {
            ensure!(n > 3);
            Ok(())
        }
        let e = inner(1).unwrap_err();
        assert!(e.to_string().contains("n > 3"), "{e}");
    }

    #[test]
    fn anyhow_from_display_value() {
        let e = anyhow!(String::from("plain string error"));
        assert_eq!(e.to_string(), "plain string error");
    }

    #[test]
    fn alternate_formatting_walks_chain() {
        let e = Error::new(io_err());
        let s = format!("{e:#}");
        assert!(s.contains("missing thing"));
    }
}
