//! Offline subset of the `anyhow` error-handling crate.
//!
//! The build environment has no crates.io access, so the repository vendors
//! the slice of anyhow's API that the `lgp` crate actually uses:
//! `anyhow::Error`, `anyhow::Result`, the [`Context`] extension trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros, with the same
//! `?`-conversion and `{:#}` chain formatting semantics. See DESIGN.md ADR-002 for the rationale; swap
//! this path dependency for `anyhow = "1"` when building online.

use std::error::Error as StdError;
use std::fmt;

enum Repr {
    /// Ad-hoc message built by `anyhow!` / `bail!` / `ensure!`.
    Msg(String),
    /// A concrete error converted through `?` — keeps its source chain.
    Wrapped(Box<dyn StdError + Send + Sync + 'static>),
    /// A message layered on top of another error by [`Context`].
    Context { msg: String, source: Box<Error> },
}

/// Dynamic error type: any `std::error::Error` converts into it via `?`.
pub struct Error {
    repr: Repr,
}

impl Error {
    /// Construct from a display-able message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { repr: Repr::Msg(message.to_string()) }
    }

    /// Construct from a concrete error, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { repr: Repr::Wrapped(Box::new(error)) }
    }

    /// Wrap this error with an outer context message (what [`Context`]
    /// methods build). The context becomes the headline; the wrapped error
    /// moves into the cause chain.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            repr: Repr::Context { msg: context.to_string(), source: Box::new(self) },
        }
    }

    /// Attempt to view the concrete error type this `Error` wraps,
    /// looking through any [`Context`] layers — the view real anyhow's
    /// `downcast_ref` gives, so swapping in `anyhow = "1"` keeps callers
    /// (the dist loop's `PeerLost`/`Stopped` dispatch, ADR-010) working.
    /// Ad-hoc `anyhow!` messages wrap no concrete type and return `None`.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        match &self.repr {
            Repr::Msg(_) => None,
            Repr::Wrapped(e) => e.downcast_ref::<E>(),
            Repr::Context { source, .. } => source.downcast_ref::<E>(),
        }
    }

    /// The root-most error message (no chain).
    pub fn root_message(&self) -> String {
        match &self.repr {
            Repr::Msg(m) => m.clone(),
            Repr::Wrapped(e) => e.to_string(),
            Repr::Context { msg, .. } => msg.clone(),
        }
    }

    fn source_chain(&self) -> Vec<String> {
        let mut out = Vec::new();
        match &self.repr {
            Repr::Msg(_) => {}
            Repr::Wrapped(e) => {
                let mut cur = e.source();
                while let Some(s) = cur {
                    out.push(s.to_string());
                    cur = s.source();
                }
            }
            Repr::Context { source, .. } => {
                out.push(source.root_message());
                out.extend(source.source_chain());
            }
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if f.alternate() {
            for cause in self.source_chain() {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        let chain = self.source_chain();
        if !chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for cause in chain {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// The blanket conversion that powers `?`. Coherent because `Error` itself
// deliberately does not implement `std::error::Error` (same trade anyhow
// makes).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `Result` with `anyhow::Error` as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach contextual messages to errors as they bubble up, mirroring
/// anyhow's `Context` extension trait.
///
/// The two `Result` impls are coherent because [`Error`] deliberately does
/// not implement `std::error::Error`, so `Result<T, Error>` never overlaps
/// the `E: StdError` blanket.
pub trait Context<T> {
    /// Wrap the error value with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error value with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// display-able value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn macros_build_messages() {
        fn check(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            let x = 41;
            if x < 10 {
                bail!("too small: {x}");
            }
            Ok(x + 1)
        }
        assert_eq!(check(true).unwrap(), 42);
        let e = check(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
    }

    #[test]
    fn ensure_without_message_names_the_condition() {
        fn inner(n: usize) -> Result<()> {
            ensure!(n > 3);
            Ok(())
        }
        let e = inner(1).unwrap_err();
        assert!(e.to_string().contains("n > 3"), "{e}");
    }

    #[test]
    fn anyhow_from_display_value() {
        let e = anyhow!(String::from("plain string error"));
        assert_eq!(e.to_string(), "plain string error");
    }

    #[test]
    fn alternate_formatting_walks_chain() {
        let e = Error::new(io_err());
        let s = format!("{e:#}");
        assert!(s.contains("missing thing"));
    }

    #[test]
    fn context_layers_over_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err()).context("reading the manifest")?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "reading the manifest");
        let s = format!("{e:#}");
        assert!(s.contains("reading the manifest: missing thing"), "{s}");
    }

    #[test]
    fn with_context_layers_over_anyhow_errors() {
        fn leaf() -> Result<()> {
            bail!("disk on fire")
        }
        let path = "/tmp/x";
        let e = leaf().with_context(|| format!("writing {path}")).unwrap_err();
        assert_eq!(e.to_string(), "writing /tmp/x");
        let s = format!("{e:#}");
        assert!(s.contains("writing /tmp/x: disk on fire"), "{s}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("disk on fire"), "{dbg}");
    }

    #[test]
    fn context_on_option_converts_none() {
        let v: Option<u32> = None;
        let e = v.context("slot missing").unwrap_err();
        assert_eq!(e.to_string(), "slot missing");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn downcast_ref_sees_through_context_layers() {
        let e = Error::new(io_err());
        assert_eq!(
            e.downcast_ref::<std::io::Error>().map(|e| e.kind()),
            Some(std::io::ErrorKind::NotFound)
        );
        let layered = Error::new(io_err()).context("outer");
        assert!(layered.downcast_ref::<std::io::Error>().is_some());
        assert!(layered.downcast_ref::<std::fmt::Error>().is_none());
        assert!(Error::msg("ad hoc").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn nested_context_keeps_full_chain() {
        let e = Error::new(io_err()).context("layer one").context("layer two");
        let s = format!("{e:#}");
        assert!(s.contains("layer two: layer one: missing thing"), "{s}");
    }
}
