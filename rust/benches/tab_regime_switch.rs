//! Bench: regenerate the Theorem 4 regime-switch table — ρ_switch(κ) and
//! the optimal control fraction f*(ρ, κ) — and cross-check f* against a
//! brute-force minimization of Q(f) = φ(f,ρ,κ)γ(f).
//!
//!   cargo bench --bench tab_regime_switch

use lgp::bench_support::Table;
use lgp::theory::{self, CostModel};

fn brute_force_fstar(rho: f64, kappa: f64, cost: &CostModel) -> f64 {
    let mut best = (f64::INFINITY, 1.0);
    for i in 1..=4000 {
        let f = i as f64 / 4000.0;
        let q = theory::q_objective(f, rho, kappa, cost);
        if q < best.0 {
            best = (q, f);
        }
    }
    best.1
}

fn main() {
    let cost = CostModel::default();
    println!("[THM4] regime switch rho_switch(kappa) and optimal f*(rho, kappa)\n");

    let mut t = Table::new(&["kappa", "rho_switch", "rho", "f* closed", "f* brute-force", "Q(f*)"]);
    let mut max_err: f64 = 0.0;
    for &k in &[0.8, 0.9, 1.0, 1.1, 1.2] {
        for &r in &[0.65, 0.7, 0.8, 0.9] {
            let closed = theory::f_star(r, k, &cost);
            let brute = brute_force_fstar(r, k, &cost);
            max_err = max_err.max((closed - brute).abs());
            t.row(vec![
                format!("{k:.1}"),
                format!("{:.4}", theory::rho_switch(k, &cost)),
                format!("{r:.2}"),
                format!("{closed:.4}"),
                format!("{brute:.4}"),
                format!("{:.4}", theory::q_objective(closed, r, k, &cost)),
            ]);
        }
    }
    t.print();
    assert!(max_err < 2.5e-4, "closed form vs brute force differ by {max_err}");
    println!("\nclosed-form f* matches brute-force minimization (max err {max_err:.1e}) ✓");

    // the paper's worked example
    let f = theory::f_star(0.8, 1.0, &cost);
    println!(
        "paper example: f*(rho=0.8, kappa=1) = {:.4} (paper: sqrt(0.28/1.38) ≈ 0.45) ✓",
        f
    );
    assert!((f - (0.28f64 / 1.38).sqrt()).abs() < 1e-9);
    println!(
        "paper quote:   rho_switch(1) = {:.4} (paper ≈ 0.6167) ✓",
        theory::rho_switch(1.0, &cost)
    );
}
