//! Bench: Proposition 2 — the exact variance formula for the debiased
//! control-variate estimator. Monte-Carlo over synthetic gradient
//! populations with controlled (ρ, κ) versus the closed form φ(f, ρ, κ).
//!
//!   cargo bench --bench var_inflation

use lgp::bench_support::{time_once, Table};
use lgp::theory;

fn main() {
    println!("[PROP2] variance inflation phi(f, rho, kappa): closed form vs Monte-Carlo\n");
    let mut t = Table::new(&[
        "f", "rho^", "kappa^", "phi closed", "phi MC", "rel err", "time",
    ]);
    let mut worst: f64 = 0.0;
    let cases = [
        (0.25, 0.95, 1.0),
        (0.25, 0.9, 1.0),
        (0.25, 0.775, 1.0), // Thm-3 break-even alignment at f = 1/4
        (0.25, 0.5, 1.0),
        (0.125, 0.9, 1.0),
        (0.5, 0.9, 1.0),
        (0.25, 0.9, 0.8),
        (0.25, 0.9, 1.3),
        (0.5, 0.6, 1.2),
    ];
    for (f, rho, kappa) in cases {
        let (mc, secs) = time_once(|| theory::monte_carlo_phi(32, 16, f, rho, kappa, 2500, 7));
        let rel = (mc.phi_empirical - mc.phi_closed_form).abs() / mc.phi_closed_form;
        worst = worst.max(rel);
        t.row(vec![
            format!("{f:.3}"),
            format!("{:.3}", mc.rho_realized),
            format!("{:.3}", mc.kappa_realized),
            format!("{:.4}", mc.phi_closed_form),
            format!("{:.4}", mc.phi_empirical),
            format!("{:.1}%", rel * 100.0),
            format!("{secs:.2}s"),
        ]);
    }
    t.print();
    assert!(worst < 0.2, "Monte-Carlo deviates {} from Prop. 2", worst);
    println!("\nworst relative error {:.1}% — Proposition 2 validated ✓", worst * 100.0);
    println!("(phi = 1 exactly at rho = kappa = 1: {:.6})", theory::phi(0.25, 1.0, 1.0));
}
