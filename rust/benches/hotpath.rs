//! Bench: hot-path micro-benchmarks for the perf pass (EXPERIMENTS.md
//! §Perf) — the host-side pieces that run every optimizer update, plus the
//! backend × shape kernel comparison that seeds `BENCH_kernels.json`.
//!
//!   cargo bench --bench hotpath
//!   LGP_BENCH_FAST=1 cargo bench --bench hotpath     (sub-second suite)
//!   LGP_BACKEND=micro cargo bench --bench hotpath    (pin the hot-path backend)

use lgp::bench_support::json_out::{write_bench_doc, BenchRecord};
use lgp::bench_support::{bench, fmt_time, kernels, Table};
use lgp::checkpoint::{self, state as ckstate, Checkpoint};
use lgp::coordinator::reduce::tree_reduce_grads;
use lgp::estimator::combine::cv_combine_into;
use lgp::model::params::{FlatGrad, ParamStore};
use lgp::predictor::fit::{fit_with_ws, FitBuffer};
use lgp::predictor::Predictor;
use lgp::tensor::{backend, linalg, BackendKind, Tensor, Workspace};
use lgp::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    // Optional backend pin for the hot-path section; default is the
    // calibration probe (the same startup path the trainer takes).
    let kind = match std::env::var("LGP_BACKEND") {
        Ok(v) => BackendKind::parse(&v)?,
        Err(_) => BackendKind::Auto,
    };
    let active = backend::set_active(kind);
    println!("[HOTPATH] active tensor backend: {}\n", active.name());

    // LGP_BENCH_FAST shrinks every section (iteration counts and the fit
    // problem size), not just the kernel sweep, so the whole binary stays
    // ~sub-second for smoke runs.
    let fast = std::env::var_os("LGP_BENCH_FAST").is_some();
    let (warm, iters) = if fast { (1, 3) } else { (3, 20) };

    let mut rng = Pcg64::seeded(9);
    let mut table = Table::new(&["hot path", "size", "mean", "p90", "throughput"]);
    // One long-lived arena for every workspace-aware section below — the
    // same steady-state footprint the trainer runs with (ADR-003).
    let mut ws = Workspace::new();

    // --- control-variate combine (runs once per micro-batch) -------------
    let p = if fast { 50_000usize } else { 250_000usize };
    let mk = |rng: &mut Pcg64| {
        let mut g = FlatGrad { trunk: vec![0.0; p], head_w: vec![0.0; 640], head_b: vec![0.0; 10] };
        rng.fill_normal(&mut g.trunk, 1.0);
        g
    };
    let (a, b, c) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
    // The trainer's fused in-place combine: refresh the control slab, then
    // one axpy-style pass — no allocation in the timed region.
    let mut out = a.clone();
    let s = bench(warm, iters, || {
        out.trunk.copy_from_slice(&a.trunk);
        out.head_w.copy_from_slice(&a.head_w);
        out.head_b.copy_from_slice(&a.head_b);
        cv_combine_into(&mut out, &b, &c, 0.25);
        std::hint::black_box(&out);
    });
    table.row(vec![
        "cv_combine_into (host)".into(),
        format!("{p} params"),
        fmt_time(s.mean),
        fmt_time(s.p90),
        format!("{:.1} GB/s", (p * 4 * 4) as f64 / s.mean / 1e9),
    ]);

    // --- host predictor (diagnostics path) --------------------------------
    let (d, r, pt, m) = (64usize, 16usize, if fast { 50_000usize } else { 250_000usize }, 48usize);
    let mut pred = Predictor::new(pt, d, r);
    let mut u = Tensor::zeros(&[pt, r]);
    let mut bm = Tensor::zeros(&[r, (d + 1) * d]);
    rng.fill_normal(&mut u.data, 0.1);
    rng.fill_normal(&mut bm.data, 0.1);
    pred.install(u, bm);
    let mut act = Tensor::zeros(&[m, d]);
    let mut h = Tensor::zeros(&[m, d]);
    rng.fill_normal(&mut act.data, 1.0);
    rng.fill_normal(&mut h.data, 1.0);
    let s = bench(warm, iters, || {
        std::hint::black_box(pred.predict_mean_trunk(&act, &h));
    });
    table.row(vec![
        "predict_mean_trunk (host)".into(),
        format!("m={m} P_T={pt} r={r}"),
        fmt_time(s.mean),
        fmt_time(s.p90),
        format!("{:.2} GFLOP/s", (2.0 * (pt * r + m * d * d) as f64) / s.mean / 1e9),
    ]);

    // --- Muon Newton–Schulz on a ViT-sized matrix --------------------------
    let g = {
        let mut t = Tensor::zeros(&[64, 192]);
        rng.fill_normal(&mut t.data, 1.0);
        t
    };
    let mut ns_out = Tensor::zeros(&[64, 192]);
    let s = bench(warm, iters, || {
        linalg::newton_schulz_into(active, &g, 5, &mut ns_out, &mut ws);
        std::hint::black_box(&ns_out);
    });
    table.row(vec![
        "newton_schulz x5 (Muon)".into(),
        "64x192".into(),
        fmt_time(s.mean),
        fmt_time(s.p90),
        format!("{:.2} GFLOP/s", (5.0 * 3.0 * 2.0 * 64.0 * 64.0 * 192.0) / s.mean / 1e9),
    ]);

    // --- matmul on the active backend ----------------------------------------
    let am = {
        let mut t = Tensor::zeros(&[256, 256]);
        rng.fill_normal(&mut t.data, 1.0);
        t
    };
    let mut cm = Tensor::zeros(&[256, 256]);
    let s = bench(warm, iters, || {
        active.matmul_into_ws(&am, &am, &mut cm, &mut ws);
        std::hint::black_box(&cm);
    });
    table.row(vec![
        format!("matmul 256^3 ({})", active.name()),
        "256x256x256".into(),
        fmt_time(s.mean),
        fmt_time(s.p90),
        format!("{:.2} GFLOP/s", 2.0 * 256f64.powi(3) / s.mean / 1e9),
    ]);

    // --- predictor fit (Gram SVD + dual ridge) ------------------------------
    let mut buf = FitBuffer::new(64);
    for _ in 0..64 {
        let mut gg = vec![0.0f32; if fast { 10_000 } else { 50_000 }];
        let mut aa = vec![0.0f32; d];
        let mut hh = vec![0.0f32; d];
        rng.fill_normal(&mut gg, 1.0);
        rng.fill_normal(&mut aa, 1.0);
        rng.fill_normal(&mut hh, 1.0);
        buf.push(&gg, &aa, &hh);
    }
    let mut pred2 = Predictor::new(if fast { 10_000 } else { 50_000 }, d, r);
    let s = bench(1, if fast { 2 } else { 5 }, || {
        fit_with_ws(active, &mut pred2, &buf, 1e-4, &mut ws).unwrap();
    });
    table.row(vec![
        "predictor fit".into(),
        format!("n=64 P_T={}k r=16", if fast { 10 } else { 50 }),
        fmt_time(s.mean),
        fmt_time(s.p90),
        "-".into(),
    ]);

    // --- checkpoint encode / atomic write / load+decode (ADR-008) -----------
    // The crash-safety artifact written every `--checkpoint-every` updates,
    // dominated by the params section at hot-path size. Timed in three
    // stages so the trajectory separates CPU work (section CRCs) from the
    // durability cost (tmp write + fsync + rename) and the recovery path
    // (directory scan + decode + restore).
    let mut ck_params = ParamStore {
        trunk: vec![0.0; p],
        head_w: vec![0.0; 640],
        head_b: vec![0.0; 10],
        width: 64,
        classes: 10,
    };
    rng.fill_normal(&mut ck_params.trunk, 0.02);
    rng.fill_normal(&mut ck_params.head_w, 0.02);
    rng.fill_normal(&mut ck_params.head_b, 0.02);
    const CK_FP: u64 = 0xbe7c;
    let build_ckpt = |ps: &ParamStore| {
        let mut ck = Checkpoint::new(CK_FP);
        ck.add("params", ckstate::encode_params(ps));
        ck
    };
    let artifact = build_ckpt(&ck_params).encode();
    let ck_bytes = artifact.len();
    let mut ckpt_records: Vec<BenchRecord> = Vec::new();

    let s = bench(warm, iters, || {
        std::hint::black_box(build_ckpt(&ck_params).encode());
    });
    table.row(vec![
        "ckpt encode (host)".into(),
        format!("{} KiB", ck_bytes / 1024),
        fmt_time(s.mean),
        fmt_time(s.p90),
        format!("{:.1} GB/s", ck_bytes as f64 / s.mean / 1e9),
    ]);
    ckpt_records.push(BenchRecord::from_summary("ckpt_encode", "-", &[ck_bytes], &s, None));

    let ck_dir = std::env::temp_dir().join("lgp_bench_ckpt");
    let _ = std::fs::remove_dir_all(&ck_dir);
    let s = bench(warm, iters, || {
        checkpoint::write_atomic(&ck_dir, &checkpoint::file_name(1), &artifact).unwrap();
    });
    table.row(vec![
        "ckpt write_atomic (fsync)".into(),
        format!("{} KiB", ck_bytes / 1024),
        fmt_time(s.mean),
        fmt_time(s.p90),
        format!("{:.2} GB/s", ck_bytes as f64 / s.mean / 1e9),
    ]);
    ckpt_records.push(BenchRecord::from_summary("ckpt_write_atomic", "-", &[ck_bytes], &s, None));

    let s = bench(warm, iters, || {
        let loaded = checkpoint::load_latest(&ck_dir, CK_FP).unwrap().unwrap();
        ckstate::decode_params(&mut ck_params, loaded.ckpt.section("params").unwrap()).unwrap();
        std::hint::black_box(&ck_params);
    });
    table.row(vec![
        "ckpt load+decode (resume)".into(),
        format!("{} KiB", ck_bytes / 1024),
        fmt_time(s.mean),
        fmt_time(s.p90),
        format!("{:.1} GB/s", ck_bytes as f64 / s.mean / 1e9),
    ]);
    ckpt_records.push(BenchRecord::from_summary("ckpt_load_decode", "-", &[ck_bytes], &s, None));
    let _ = std::fs::remove_dir_all(&ck_dir);

    // --- dist leaf exchange: loopback sockets vs in-process reduce (ADR-010) --
    // The same four accumulation leaves folded two ways: the left-deep
    // ADR-004 reduction alone (what one process does between scatter and
    // the optimizer step), and a full 2-process exchange over a real
    // loopback TCP pair — the follower frames + ships its leaves, the
    // leader folds all four in global slot order, scales, and broadcasts
    // the mean back. The gap between the two rows is the per-update price
    // of crossing a process boundary, which `lgp launch` pays every step.
    let mk_leaf = |rng: &mut Pcg64| {
        let mut g = FlatGrad { trunk: vec![0.0; p], head_w: vec![0.0; 640], head_b: vec![0.0; 10] };
        rng.fill_normal(&mut g.trunk, 1.0);
        lgp::dist::Leaf { grad: g, loss: 1.2, acc: 0.5, cost: 3.0, examples: 48 }
    };
    let leader_leaves: Vec<lgp::dist::Leaf> = (0..2).map(|_| mk_leaf(&mut rng)).collect();
    let follower_leaves: Vec<lgp::dist::Leaf> = (0..2).map(|_| mk_leaf(&mut rng)).collect();
    let mut dist_records: Vec<BenchRecord> = Vec::new();

    let all: Vec<FlatGrad> = leader_leaves
        .iter()
        .chain(follower_leaves.iter())
        .map(|l| l.grad.clone())
        .collect();
    let s = bench(warm, iters, || {
        let mut grad = tree_reduce_grads(all.clone()).unwrap();
        grad.scale(0.25);
        std::hint::black_box(&grad);
    });
    table.row(vec![
        "leaf reduce (in-process)".into(),
        format!("4x{p} params"),
        fmt_time(s.mean),
        fmt_time(s.p90),
        format!("{:.1} GB/s", (4 * p * 4) as f64 / s.mean / 1e9),
    ]);
    dist_records.push(BenchRecord::from_summary("dist_reduce_inprocess", "-", &[4, p], &s, None));

    let geom = lgp::dist::Geometry { fingerprint: CK_FP, procs: 2, accum: 4, seed: 0 };
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let fleaves = follower_leaves.clone();
    let follower = std::thread::spawn(move || {
        let mut d = lgp::dist::connect(&addr, 1, &geom).unwrap();
        let mut step = 0u64;
        // Mirror the leader until its Shutdown lands as a Stopped error.
        while d.exchange(step, fleaves.clone()).is_ok() {
            step += 1;
        }
    });
    let mut leader = lgp::dist::accept_followers(&listener, &geom, || Ok(()))?;
    let mut step = 0u64;
    let s = bench(warm, iters, || {
        let red = leader.exchange(step, leader_leaves.clone()).unwrap();
        step += 1;
        std::hint::black_box(&red);
    });
    leader.finish(lgp::dist::SHUTDOWN_COMPLETE, "bench done");
    drop(leader);
    follower.join().unwrap();
    // Per exchange: 2 follower leaves in + 1 mean gradient back out.
    table.row(vec![
        "leaf exchange (loopback)".into(),
        format!("4x{p} 2 procs"),
        fmt_time(s.mean),
        fmt_time(s.p90),
        format!("{:.2} GB/s", (3 * p * 4) as f64 / s.mean / 1e9),
    ]);
    dist_records.push(
        BenchRecord::from_summary("dist_exchange_loopback", "-", &[4, p], &s, None)
            .with_threads(2),
    );

    println!("[HOTPATH] host-side per-update costs\n");
    table.print();
    println!("\ncontext: one GPR update (accum=4) does 4 combines + 4 predictor");
    println!("device calls; a refit (every ~20 updates) does one fit. All host");
    println!("costs above must stay well under the device call costs (~30-120ms).");

    // --- backend × shape kernel comparison -> BENCH_kernels.json -------------
    let kcfg = kernels::KernelBenchConfig::from_env();
    let mut records = kernels::run(&kcfg);
    println!("\n[KERNELS] backend x shape comparison ({} records)\n", records.len());
    kernels::table(&records).print();

    // --- sharded update scatter/reduce (ADR-004) -> threads dimension --------
    // One synthetic update = accum square-matmul micro-tasks through the
    // real executor + fixed-topology reduction, swept over shard counts.
    let scfg = kernels::ShardedBenchConfig::from_env();
    let sharded = kernels::run_sharded(&scfg);
    println!(
        "\n[SHARDED] update throughput, accum={} n={} and dispatch shape accum={} n={} (micro backend)\n",
        scfg.accum, scfg.n, scfg.accum_dispatch, scfg.n_dispatch
    );
    kernels::table(&sharded).print();
    let max_t = scfg.shard_counts.iter().copied().max().unwrap_or(1);
    let cell = |name: &str, threads: usize, accum: usize, n: usize| {
        sharded
            .iter()
            .find(|r| r.name == name && r.threads == threads && r.shape == [accum, n, n])
    };
    if let (Some(t1), Some(tn)) = (
        cell("sharded_update", 1, scfg.accum, scfg.n),
        cell("sharded_update", max_t, scfg.accum, scfg.n),
    ) {
        if max_t > 1 && tn.mean_ns > 0.0 {
            println!(
                "\nspeedup at {} shards: {:.2}x updates/s over serial",
                max_t,
                t1.mean_ns / tn.mean_ns
            );
        }
    }
    // The pool's reason to exist: per-update spawn overhead is a visible
    // fraction of a *small* update, which the dispatch shape isolates.
    if let (Some(pool), Some(spawn)) = (
        cell("sharded_update", max_t, scfg.accum_dispatch, scfg.n_dispatch),
        cell("sharded_update_spawn", max_t, scfg.accum_dispatch, scfg.n_dispatch),
    ) {
        if max_t > 1 && pool.mean_ns > 0.0 {
            println!(
                "pool vs per-update spawn at {} shards (accum={} n={}): {:.2}x",
                max_t,
                scfg.accum_dispatch,
                scfg.n_dispatch,
                spawn.mean_ns / pool.mean_ns
            );
        }
    }
    records.extend(sharded);
    records.extend(ckpt_records);
    records.extend(dist_records);

    let doc = kernels::doc(&records);
    let path = write_bench_doc("BENCH_kernels.json", &doc)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
