//! Bench: regenerate the Theorem 3 break-even table (paper Sec. 5.3),
//! including the three quoted values, and time the closed form.
//!
//!   cargo bench --bench tab_breakeven

use lgp::bench_support::{bench, fmt_time, Table};
use lgp::theory::{self, CostModel};

fn main() {
    let cost = CostModel::default();
    println!("[THM3] break-even alignment rho*(f, kappa) — paper Theorem 3\n");
    let mut t = Table::new(&["f", "gamma(f)", "rho*(k=0.8)", "rho*(k=1.0)", "rho*(k=1.2)", "paper"]);
    let quotes: [(f64, &str); 3] = [(0.1, "0.876"), (0.2, "0.802"), (0.5, "0.689")];
    for &f in &[0.05, 0.1, 0.2, 0.25, 0.5, 0.75, 1.0] {
        let paper = quotes
            .iter()
            .find(|(pf, _)| (pf - f).abs() < 1e-9)
            .map_or("-", |(_, q)| q);
        t.row(vec![
            format!("{f:.2}"),
            format!("{:.3}", cost.gamma(f)),
            format!("{:.3}", theory::rho_star(f, 0.8, &cost)),
            format!("{:.3}", theory::rho_star(f, 1.0, &cost)),
            format!("{:.3}", theory::rho_star(f, 1.2, &cost)),
            paper.to_string(),
        ]);
    }
    t.print();

    // verification against the quoted values
    for (f, q) in quotes {
        let got = theory::rho_star(f, 1.0, &cost);
        let want: f64 = q.parse().unwrap();
        assert!((got - want).abs() < 5e-4, "rho*({f},1)={got} vs paper {want}");
    }
    println!("\nall paper-quoted values reproduced to 3 decimals ✓");

    // timing (the formula sits on the adaptive-f control path)
    let s = bench(1000, 5000, || {
        std::hint::black_box(theory::rho_star(
            std::hint::black_box(0.25),
            std::hint::black_box(1.05),
            &cost,
        ));
    });
    println!("rho_star closed form: {} per call", fmt_time(s.mean));
}
