//! Bench: the paper's cost model (Sec. 5.3) measured on this runtime.
//!
//! The paper assumes per-example costs Backward = 2, Forward = 1,
//! CheapForward = 0.7. With compiled artifacts present we time the actual
//! device entry points (train_grads = Forward+Backward, cheap_fwd =
//! CheapForward) and report the measured ratios plus the resulting
//! measured compute ratio γ̂(f) next to the analytic γ(f) — the numbers
//! Theorems 3/4 would use on this testbed.
//!
//! Without artifacts (stub xla build, see DESIGN.md ADR-002) the bench
//! falls back to a host-proxy mode: the forward pass is proxied by a
//! width-D matmul and the cheap forward by a width-D·√0.7 counterpart
//! (0.7× the flops, the paper's assumed ratio) on the calibrated tensor
//! backend, so the γ table and `BENCH_cost_model.json` are still produced
//! and the JSON trajectory never goes dark.
//!
//!   cargo bench --bench cost_model            (tiny preset or host proxy)
//!   LGP_BENCH_PRESET=small cargo bench --bench cost_model

use lgp::bench_support::json_out::{bench_doc, write_bench_doc, BenchRecord};
use lgp::bench_support::{bench, Table};
use lgp::model::ParamStore;
use lgp::runtime::Runtime;
use lgp::tensor::{backend, BackendKind, Tensor, Workspace};
use lgp::theory::CostModel;
use lgp::util::json::{num, obj, s, Json};
use lgp::util::rng::Pcg64;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("LGP_BENCH_PRESET").unwrap_or_else(|_| "tiny".into());
    let dir = PathBuf::from(format!("artifacts/{preset}"));
    let fast = std::env::var_os("LGP_BENCH_FAST").is_some();

    let (records, cheap_units, mode) = if dir.join("manifest.json").exists() {
        device_mode(&dir, &preset, fast)?
    } else {
        println!(
            "artifacts/{preset} not built (run `make artifacts`) — host-proxy cost model\n"
        );
        host_proxy_mode(fast)
    };

    // compute ratio table: paper constants vs measured CheapForward units
    let paper = CostModel::default();
    let measured = CostModel { forward: 1.0, backward: 2.0, cheap_forward: cheap_units };
    println!("\ncompute ratio gamma(f) = cost(GPR)/cost(vanilla)  [{mode}]:");
    let mut t = Table::new(&["f", "gamma paper", "gamma measured"]);
    let fs = [0.125, 0.25, 0.5, 1.0];
    let mut gamma_pairs = Vec::new();
    for &f in &fs {
        t.row(vec![
            format!("{f}"),
            format!("{:.3}", paper.gamma(f)),
            format!("{:.3}", measured.gamma(f)),
        ]);
        gamma_pairs.push((format!("{f}"), measured.gamma(f)));
    }
    t.print();
    println!(
        "\nmeasured CheapForward = {cheap_units:.2} units (paper assumes 0.7). \
         The measured break-even for f=0.25, kappa=1: rho* = {:.3} \
         (paper-units value: {:.3}).",
        lgp::theory::rho_star(0.25, 1.0, &measured),
        lgp::theory::rho_star(0.25, 1.0, &paper),
    );

    let derived = obj(vec![
        ("mode", s(mode)),
        ("preset", s(&preset)),
        ("cheap_forward_units", num(cheap_units)),
        (
            "gamma_measured",
            Json::Obj(gamma_pairs.into_iter().map(|(k, v)| (k, num(v))).collect()),
        ),
        ("rho_star_f025_k1", num(lgp::theory::rho_star(0.25, 1.0, &measured))),
    ]);
    let doc = bench_doc("cost_model", &records, Some(derived));
    let path = write_bench_doc("BENCH_cost_model.json", &doc)?;
    println!("\nwrote {}", path.display());
    Ok(())
}

/// Time the real PJRT artifacts (requires `make artifacts` + real xla).
fn device_mode(
    dir: &std::path::Path,
    preset: &str,
    fast: bool,
) -> anyhow::Result<(Vec<BenchRecord>, f64, &'static str)> {
    let rt = Runtime::load(dir)?;
    let m = rt.manifest.clone();
    let params = ParamStore::load_init(&m)?;
    let dev = rt.upload_params(&params)?;
    let mut rng = Pcg64::seeded(3);

    // Per-example batch sizes that exist in this manifest: use the full
    // micro-batch for train_grads; the f=0.5 prediction batch for cheap.
    let mb = m.micro_batch;
    let (_, mp) = m.split_sizes(0.5);
    let mut x = vec![0.0f32; mb * 3 * m.image * m.image];
    rng.fill_normal(&mut x, 1.0);
    let y: Vec<i32> = (0..mb).map(|_| rng.below(10) as i32).collect();
    let xc = x[..mp * 3 * m.image * m.image].to_vec();

    println!("[COST] measured per-iteration artifact costs ({preset} preset, m={mb})\n");
    let warm = if fast { 1 } else { 2 };
    let iters = if fast { 3 } else { 8 };
    let full = bench(warm, iters, || {
        rt.train_grads(&dev, &x, &y, mb).unwrap();
    });
    let cheap = bench(warm, iters, || {
        rt.cheap_fwd(&dev, &xc, mp).unwrap();
    });

    // per-example costs, normalizing Forward+Backward to 3.0 like the paper
    let full_per_ex = full.mean / mb as f64;
    let cheap_per_ex = cheap.mean / mp as f64;
    let cheap_units = 3.0 * cheap_per_ex / full_per_ex;

    let mut t = Table::new(&["procedure", "batch", "mean", "per-example", "paper units", "measured units"]);
    t.row(vec![
        "Forward+Backward".into(),
        format!("{mb}"),
        format!("{:.1}ms", full.mean_ms()),
        format!("{:.2}ms", full_per_ex * 1e3),
        "3.0".into(),
        "3.0 (def)".into(),
    ]);
    t.row(vec![
        "CheapForward".into(),
        format!("{mp}"),
        format!("{:.1}ms", cheap.mean_ms()),
        format!("{:.2}ms", cheap_per_ex * 1e3),
        "0.7".into(),
        format!("{cheap_units:.2}"),
    ]);
    t.print();

    let records = vec![
        BenchRecord::from_summary("train_grads", "device", &[mb], &full, None),
        BenchRecord::from_summary("cheap_fwd", "device", &[mp], &cheap, None),
    ];
    Ok((records, cheap_units, "device"))
}

/// No artifacts: proxy the forward / cheap-forward cost with host matmuls
/// on the calibrated tensor backend. The cheap proxy's width is sized so
/// its flop count is 0.7× the forward proxy's (the paper's assumed
/// CheapForward ratio); the *measured* ratio then reports how far actual
/// kernel efficiency deviates from the flop-count model, which is exactly
/// the quantity the device mode measures.
fn host_proxy_mode(fast: bool) -> (Vec<BenchRecord>, f64, &'static str) {
    let be = backend::set_active(BackendKind::Auto);
    println!("[COST] host-proxy mode on backend '{}'\n", be.name());
    let mut rng = Pcg64::seeded(3);
    let (m, d) = (64usize, 192usize);
    // flops scale with width²: dc = d·√0.7 gives the paper's 0.7 ratio.
    let dc = ((d as f64) * 0.7f64.sqrt()).round() as usize; // 161 for d=192
    let rand = |rng: &mut Pcg64, shape: &[usize]| {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    };
    let a_full = rand(&mut rng, &[m, d]);
    let w_full = rand(&mut rng, &[d, d]);
    let a_cheap = rand(&mut rng, &[m, dc]);
    let w_cheap = rand(&mut rng, &[dc, dc]);
    let mut c_full = Tensor::zeros(&[m, d]);
    let mut c_cheap = Tensor::zeros(&[m, dc]);
    // Steady-state entry points (shared workspace, reused outputs) so the
    // proxy measures the same code path the trainer runs (ADR-003).
    let mut ws = Workspace::new();

    let warm = if fast { 1 } else { 3 };
    let iters = if fast { 5 } else { 20 };
    let fwd = bench(warm, iters, || {
        be.matmul_into_ws(&a_full, &w_full, &mut c_full, &mut ws);
        std::hint::black_box(&c_full);
    });
    let cheap = bench(warm, iters, || {
        be.matmul_into_ws(&a_cheap, &w_cheap, &mut c_cheap, &mut ws);
        std::hint::black_box(&c_cheap);
    });

    // paper units: Forward = 1 by definition, CheapForward measured
    // relative to it.
    let cheap_units = cheap.mean / fwd.mean;

    let mut t = Table::new(&["proxy", "shape", "mean", "paper units", "measured units"]);
    t.row(vec![
        "forward_proxy".into(),
        format!("{m}x{d}·{d}x{d}"),
        format!("{:.1}µs", fwd.mean * 1e6),
        "1.0".into(),
        "1.0 (def)".into(),
    ]);
    t.row(vec![
        "cheap_forward_proxy".into(),
        format!("{m}x{dc}·{dc}x{dc}"),
        format!("{:.1}µs", cheap.mean * 1e6),
        "0.7".into(),
        format!("{cheap_units:.2}"),
    ]);
    t.print();

    let flops_full = 2.0 * m as f64 * d as f64 * d as f64;
    let flops_cheap = 2.0 * m as f64 * dc as f64 * dc as f64;
    let records = vec![
        BenchRecord::from_summary("forward_proxy", be.name(), &[m, d, d], &fwd, Some(flops_full)),
        BenchRecord::from_summary(
            "cheap_forward_proxy",
            be.name(),
            &[m, dc, dc],
            &cheap,
            Some(flops_cheap),
        ),
    ];
    (records, cheap_units, "host_proxy")
}
