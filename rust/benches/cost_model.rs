//! Bench: the paper's cost model (Sec. 5.3) measured on this runtime.
//!
//! The paper assumes per-example costs Backward = 2, Forward = 1,
//! CheapForward = 0.7. Here we time the actual artifacts (train_grads =
//! Forward+Backward, cheap_fwd = CheapForward) and report the measured
//! ratios plus the resulting measured compute ratio γ̂(f) next to the
//! analytic γ(f) — the numbers Theorems 3/4 would use on this testbed.
//!
//!   cargo bench --bench cost_model            (tiny preset)
//!   LGP_BENCH_PRESET=small cargo bench --bench cost_model

use lgp::bench_support::{bench, Table};
use lgp::model::ParamStore;
use lgp::runtime::Runtime;
use lgp::theory::CostModel;
use lgp::util::rng::Pcg64;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("LGP_BENCH_PRESET").unwrap_or_else(|_| "tiny".into());
    let dir = PathBuf::from(format!("artifacts/{preset}"));
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts/{preset} not built (run `make artifacts`)");
        return Ok(());
    }
    let rt = Runtime::load(&dir)?;
    let m = rt.manifest.clone();
    let params = ParamStore::load_init(&m)?;
    let dev = rt.upload_params(&params)?;
    let mut rng = Pcg64::seeded(3);

    // Per-example batch sizes that exist in this manifest: use the full
    // micro-batch for train_grads; the f=0.5 prediction batch for cheap.
    let mb = m.micro_batch;
    let (_, mp) = m.split_sizes(0.5);
    let mut x = vec![0.0f32; mb * 3 * m.image * m.image];
    rng.fill_normal(&mut x, 1.0);
    let y: Vec<i32> = (0..mb).map(|_| rng.below(10) as i32).collect();
    let xc = x[..mp * 3 * m.image * m.image].to_vec();

    println!("[COST] measured per-iteration artifact costs ({preset} preset, m={mb})\n");
    let warm = 2;
    let iters = 8;
    let full = bench(warm, iters, || {
        rt.train_grads(&dev, &x, &y, mb).unwrap();
    });
    let cheap = bench(warm, iters, || {
        rt.cheap_fwd(&dev, &xc, mp).unwrap();
    });

    // per-example costs, normalizing Forward+Backward to 3.0 like the paper
    let full_per_ex = full.mean / mb as f64;
    let cheap_per_ex = cheap.mean / mp as f64;
    let cheap_units = 3.0 * cheap_per_ex / full_per_ex;

    let mut t = Table::new(&["procedure", "batch", "mean", "per-example", "paper units", "measured units"]);
    t.row(vec![
        "Forward+Backward".into(),
        format!("{mb}"),
        format!("{:.1}ms", full.mean_ms()),
        format!("{:.2}ms", full_per_ex * 1e3),
        "3.0".into(),
        "3.0 (def)".into(),
    ]);
    t.row(vec![
        "CheapForward".into(),
        format!("{mp}"),
        format!("{:.1}ms", cheap.mean_ms()),
        format!("{:.2}ms", cheap_per_ex * 1e3),
        "0.7".into(),
        format!("{cheap_units:.2}"),
    ]);
    t.print();

    let paper = CostModel::default();
    let measured = CostModel { forward: 1.0, backward: 2.0, cheap_forward: cheap_units };
    println!("\ncompute ratio gamma(f) = cost(GPR)/cost(vanilla):");
    let mut t = Table::new(&["f", "gamma paper", "gamma measured"]);
    for &f in &[0.125, 0.25, 0.5, 1.0] {
        t.row(vec![
            format!("{f}"),
            format!("{:.3}", paper.gamma(f)),
            format!("{:.3}", measured.gamma(f)),
        ]);
    }
    t.print();
    println!(
        "\nmeasured CheapForward = {cheap_units:.2} units (paper assumes 0.7). \
         The measured break-even for f=0.25, kappa=1: rho* = {:.3} \
         (paper-units value: {:.3}).",
        lgp::theory::rho_star(0.25, 1.0, &measured),
        lgp::theory::rho_star(0.25, 1.0, &paper),
    );
    Ok(())
}
