//! Bench: Figure 1 — validation accuracy vs wall-clock, GPR vs baseline,
//! under an equal (short) wall-clock budget. This is the bench-sized
//! version of `examples/e2e_vit_cifar.rs`; it asserts the paper's
//! qualitative claim on this testbed: **GPR completes more optimizer
//! updates than the baseline in the same wall-clock budget** (its
//! iterations are cheaper), and reports the accuracy-vs-time rows.
//!
//! Regime note (recorded in EXPERIMENTS.md): the claim is about the
//! compute-bound regime. On the overhead-dominated `tiny` preset the 4
//! device calls per GPR micro-batch cost more than the saved backward —
//! the bench reports that honestly and only asserts the speedup on
//! presets where model compute dominates (small/paper), matching the
//! paper's A100 setting.
//!
//!   cargo bench --bench fig1_wallclock                 (small, ~3 min)
//!   LGP_BENCH_PRESET=tiny LGP_BENCH_BUDGET=15 cargo bench --bench fig1_wallclock

use lgp::bench_support::Table;
use lgp::prelude::*;
use lgp::util::env_parse;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("LGP_BENCH_PRESET").unwrap_or_else(|_| "small".into());
    // Malformed override values are hard errors, not silent defaults.
    let budget: f64 = env_parse::<f64>("LGP_BENCH_BUDGET")?
        .unwrap_or(if preset == "tiny" { 15.0 } else { 75.0 });
    let dir = PathBuf::from(format!("artifacts/{preset}"));
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts/{preset} not built (run `make artifacts`)");
        return Ok(());
    }

    println!("[FIG1] equal wall-clock budget ({budget}s) — GPR (f=1/4) vs baseline, {preset} preset\n");
    let base = SessionBuilder::new()
        .artifacts(dir)
        .f(0.25)
        .accum(4)
        .budget_secs(budget)
        .max_steps(0)
        .refit_every(20)
        .eval_every(5)
        .train_size(1500)
        .val_size(300)
        .aug_multiplier(2)
        .seed(0)
        .config()
        .clone();

    let mut rows: Vec<(Algo, usize, f64, f64, f64)> = Vec::new();
    let mut curves = Vec::new();
    for algo in [Algo::Baseline, Algo::Gpr] {
        let mut session = SessionBuilder::from_config(base.clone()).algo(algo).build()?;
        // run() warms up before starting the budget stopwatch, so XLA
        // compilation stays outside the budget (as in the paper's runs).
        session.run()?;
        rows.push((
            algo,
            session.step_count(),
            session.final_val_acc(),
            session.cost_units,
            session.examples_seen as f64,
        ));
        curves.push((
            algo,
            session
                .log
                .iter()
                .filter(|r| !r.val_acc.is_nan())
                .map(|r| (r.wall_secs, r.val_acc))
                .collect::<Vec<_>>(),
        ));
    }

    let mut t = Table::new(&["algo", "updates", "final val acc", "cost units", "examples"]);
    for (algo, steps, acc, cost, ex) in &rows {
        t.row(vec![
            format!("{algo:?}"),
            steps.to_string(),
            format!("{acc:.3}"),
            format!("{cost:.0}"),
            format!("{ex:.0}"),
        ]);
    }
    t.print();

    println!("\nval-acc-vs-time series (the Figure 1 shape):");
    let mut t = Table::new(&["time(s)", "baseline", "GPR"]);
    for i in 1..=6 {
        let tm = budget * i as f64 / 6.0;
        let pick = |algo: Algo| {
            curves
                .iter()
                .find(|(a, _)| *a == algo)
                .and_then(|(_, c)| c.iter().rev().find(|(ts, _)| *ts <= tm))
                .map_or("-".to_string(), |(_, v)| format!("{v:.3}"))
        };
        t.row(vec![format!("{tm:.1}"), pick(Algo::Baseline), pick(Algo::Gpr)]);
    }
    t.print();

    // the testable core of Figure 1 on this substrate: cheaper iterations
    let (_, base_steps, _, base_cost, _) = rows[0];
    let (_, gpr_steps, _, gpr_cost, _) = rows[1];
    println!(
        "\nupdates completed under equal budget: baseline {base_steps}, GPR {gpr_steps} \
         ({:.2}x)",
        gpr_steps as f64 / base_steps as f64
    );
    println!(
        "analytic cost per example: baseline {:.2}, GPR {:.2} (gamma(0.25) = 0.425)",
        base_cost / rows[0].4,
        gpr_cost / rows[1].4
    );
    if preset == "tiny" {
        // Overhead-dominated regime: 4 PJRT calls per GPR micro-batch vs 1
        // for the baseline outweigh the saved backward on a ~30k-param
        // model. This is expected and documented in EXPERIMENTS.md; the
        // paper's claim concerns compute-bound models.
        println!(
            "note: tiny preset is per-call-overhead dominated; the compute-bound \
             claim is asserted on small/paper presets."
        );
    } else {
        assert!(
            gpr_steps as f64 >= 1.15 * base_steps as f64,
            "GPR should complete markedly more updates ({gpr_steps} vs {base_steps})"
        );
        println!("GPR completes more updates per unit wall-clock ✓ (paper's mechanism for Fig. 1)");
    }
    Ok(())
}
