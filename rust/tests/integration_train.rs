//! Integration: full TrainSession runs (Algorithm 1 and 2) on tiny
//! artifacts — losses decrease, the predictor fits, alignment is tracked,
//! GPR with f=1 degenerates to the baseline update, checkpoints
//! round-trip. All runs go through the ADR-005 session API.

use lgp::config::{Algo, OptimKind, RunConfig};
use lgp::session::{SessionBuilder, TrainSession};
use std::path::PathBuf;

fn tiny_cfg() -> Option<RunConfig> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: tiny artifacts not built");
        return None;
    }
    Some(RunConfig {
        artifacts_dir: dir,
        algo: Algo::Gpr,
        f: 0.25,
        accum: 2,
        optimizer: OptimKind::Muon,
        lr: 0.02,
        weight_decay: 0.0,
        budget_secs: 0.0,
        max_steps: 30,
        refit_every: 10,
        ridge_lambda: 1e-4,
        train_size: 600,
        val_size: 150,
        aug_multiplier: 1,
        seed: 7,
        eval_every: 0,
        out_dir: std::env::temp_dir().join("lgp_it"),
        track_alignment: true,
        adaptive_f: false,
        backend: lgp::tensor::BackendKind::Blocked,
        // `LGP_SHARDS=2 cargo test -q` runs this whole suite through the
        // sharded executor (ADR-004) — bit-identical results, so every
        // assertion below holds unchanged. A malformed LGP_SHARDS is a
        // hard error, never a silent serial fallback.
        shards: lgp::config::shards_env_override().expect("LGP_SHARDS").unwrap_or(1),
        estimator: None,
        tangents: 8,
        checkpoint_dir: None,
        checkpoint_every: 0,
        checkpoint_keep: 0,
        resume: false,
    })
}

fn session(cfg: RunConfig) -> TrainSession {
    SessionBuilder::from_config(cfg).build().unwrap()
}

#[test]
fn baseline_training_reduces_loss() {
    let Some(mut cfg) = tiny_cfg() else { return };
    cfg.algo = Algo::Baseline;
    cfg.max_steps = 40;
    let mut t = session(cfg);
    t.run().unwrap();
    let first = t.log.first().unwrap().loss;
    let last = t.log.last().unwrap().loss;
    assert!(last < first - 0.05, "loss did not decrease: {first} -> {last}");
    assert!(t.final_val_acc() > 0.15, "val acc {}", t.final_val_acc());
}

#[test]
fn gpr_training_reduces_loss_and_tracks_alignment() {
    let Some(cfg) = tiny_cfg() else { return };
    let mut t = session(cfg);
    t.run().unwrap();
    let first = t.log.first().unwrap().loss;
    let last = t.log.last().unwrap().loss;
    assert!(last < first + 0.02, "GPR diverged: {first} -> {last}");
    // predictor fitted at least once and alignment is high (NTK structure)
    assert!(t.pred.fits >= 1);
    let a = t.tracker.snapshot().expect("alignment tracked");
    assert!(a.rho > 0.5, "rho suspiciously low: {}", a.rho);
    // GPR consumed fewer analytic cost units per example than vanilla 3/ex
    let per_ex = t.cost_units / t.examples_seen as f64;
    assert!(per_ex < 3.0, "GPR cost/example {per_ex} not below vanilla 3.0");
}

#[test]
fn gpr_with_f_one_matches_baseline_updates() {
    // f = 1: the whole micro-batch is control; eq. (1) collapses to the
    // true gradient, so GPR and baseline produce identical parameters.
    let Some(mut cfg) = tiny_cfg() else { return };
    cfg.f = 1.0;
    cfg.max_steps = 3;
    cfg.refit_every = 0; // fit still happens once; harmless at f=1
    cfg.track_alignment = false;
    let mut gpr = session(cfg.clone());
    gpr.run().unwrap();
    cfg.algo = Algo::Baseline;
    let mut base = session(cfg);
    base.run().unwrap();
    let diff: f32 = gpr
        .params
        .trunk
        .iter()
        .zip(&base.params.trunk)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(diff < 1e-4, "f=1 GPR differs from baseline by {diff}");
}

#[test]
fn checkpoint_round_trip_through_session() {
    let Some(mut cfg) = tiny_cfg() else { return };
    cfg.max_steps = 2;
    let dir = std::env::temp_dir().join("lgp_ckpt_test");
    let mut t = session(cfg);
    t.run().unwrap();
    t.params.save(&dir).unwrap();
    let mut copy = t.params.clone();
    copy.trunk.iter_mut().for_each(|v| *v = 0.0);
    copy.restore(&dir).unwrap();
    assert_eq!(copy.trunk, t.params.trunk);
}

#[test]
fn wall_clock_budget_stops_training() {
    let Some(mut cfg) = tiny_cfg() else { return };
    cfg.max_steps = 0;
    cfg.budget_secs = 2.0;
    cfg.eval_every = 0;
    let mut t = session(cfg);
    let t0 = std::time::Instant::now();
    t.run().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert!(t.step_count() > 0, "no steps completed");
    // budget (2s) + at most one step of overshoot + final eval slack
    assert!(dt < 25.0, "budget run took {dt}s");
}

#[test]
fn seeds_change_data_but_not_shapes() {
    let Some(mut cfg) = tiny_cfg() else { return };
    cfg.max_steps = 2;
    cfg.track_alignment = false;
    let mut a = session(cfg.clone());
    a.run().unwrap();
    cfg.seed = 8;
    let mut b = session(cfg);
    b.run().unwrap();
    assert_eq!(a.params.trunk.len(), b.params.trunk.len());
    assert_ne!(a.params.trunk, b.params.trunk, "different seeds, same params?");
}

#[test]
fn sharded_training_reduces_loss_like_serial() {
    // The parallel path through the full session: 2 shards, GPR with a
    // refit inside the window. (Bitwise equality with serial is pinned by
    // tests/shard_determinism.rs; this is the behavioral smoke.)
    let Some(mut cfg) = tiny_cfg() else { return };
    cfg.shards = 2;
    cfg.accum = 4;
    cfg.max_steps = 20;
    let mut t = session(cfg);
    assert_eq!(t.shards(), 2);
    t.run().unwrap();
    let first = t.log.first().unwrap().loss;
    let last = t.log.last().unwrap().loss;
    assert!(last < first + 0.02, "sharded GPR diverged: {first} -> {last}");
    assert!(t.pred.fits >= 1, "refit must run through the sharded gather");
}

#[test]
fn sgd_and_adamw_also_train() {
    for kind in [OptimKind::Sgd, OptimKind::AdamW, OptimKind::Momentum] {
        let Some(mut cfg) = tiny_cfg() else { return };
        cfg.algo = Algo::Baseline;
        cfg.optimizer = kind;
        cfg.lr = match kind {
            OptimKind::AdamW => 0.003,
            // momentum's effective lr is lr/(1-beta) = 20x -- keep small
            OptimKind::Momentum => 0.005,
            _ => 0.05,
        };
        cfg.max_steps = 20;
        let mut t = session(cfg);
        t.run().unwrap();
        let first = t.log.first().unwrap().loss;
        let last = t.log.last().unwrap().loss;
        assert!(
            last < first + 0.02,
            "{kind:?} diverged: {first} -> {last}"
        );
    }
}
