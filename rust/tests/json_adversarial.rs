//! Adversarial JSON corpus (ISSUE 9, DESIGN.md ADR-009): the config
//! surface is network-facing now, so every hostile document must come
//! back as a *structured* `JsonError` — offset included — or a clean
//! value. Never a panic, never a stack overflow, never unbounded
//! buffering. These run in-process against the same `Json::parse` +
//! `SessionBuilder::apply_json` pair the serve control plane routes
//! `POST /sessions` bodies through.
//!
//! (String literals below spell `\u` escapes with doubled backslashes;
//! the documents under test contain single-backslash JSON escapes.)

use lgp::session::SessionBuilder;
use lgp::util::json::Json;

/// Documents that must each fail with an error that names the byte
/// offset of the problem.
fn known_bad() -> Vec<String> {
    let mut docs: Vec<String> = [
        // truncated containers and separators
        "", " ", "{", "[", "}", "]", "{\"a\"", "{\"a\":", "{\"a\":1,", "{\"a\":1",
        "[1,", "[1 2]", "{\"a\" 1}", "{1:2}", ",", "[,]", "{,}",
        // broken strings and escapes
        "\"", "\"abc", "\"\\", "\"\\q\"", "\"\\u\"", "\"\\u00\"", "\"\\u123\"",
        "\"\\u+123\"", "\"\\uzzzz\"",
        // surrogate abuse: lone high, lone low, high + non-surrogate,
        // reversed pair, truncated pair
        "\"\\ud800\"", "\"\\udfff\"", "\"\\ud83d\\u0041\"", "\"\\udc00\\ud800\"",
        "\"\\ud83dxx\"",
        // broken literals and numbers
        "tru", "fals", "nul", "TRUE", "+1", "-", ".5", "1e", "1e+", "--1",
        // overflow: finite text, non-finite f64
        "1e999", "-1e999", "1e309",
        // trailing garbage after a complete value
        "{} {}", "[1]x", "1 2", "null,",
    ]
    .into_iter()
    .map(String::from)
    .collect();

    // Depth bombs: open-only, alternating, and fully closed — all far
    // past MAX_DEPTH. Before the depth limit these aborted the process
    // by exhausting the recursive-descent stack.
    for n in [1_000usize, 100_000] {
        docs.push("[".repeat(n));
        docs.push("{\"k\":[".repeat(n));
        docs.push(format!("{}1{}", "[".repeat(n), "]".repeat(n)));
    }
    docs
}

#[test]
fn every_known_bad_document_is_a_structured_error_with_an_offset() {
    for doc in known_bad() {
        let label: String = doc.chars().take(32).collect();
        let err = Json::parse(&doc)
            .map(|_| ())
            .expect_err(&format!("must reject: {label:?} (len {})", doc.len()));
        let msg = format!("{err}");
        assert!(
            msg.contains("json parse error at byte"),
            "error must name the offset: {label:?} -> {msg}"
        );
        assert!(err.pos <= doc.len(), "offset out of range for {label:?}: {msg}");
    }
}

#[test]
fn depth_bomb_offset_points_at_the_limit_not_the_end() {
    let doc = "[".repeat(100_000);
    let err = Json::parse(&doc).unwrap_err();
    assert!(format!("{err}").contains("nesting"), "{err}");
    assert!(
        err.pos < 200,
        "the error should fire at the depth limit, not after scanning 100k bytes: pos={}",
        err.pos
    );
}

/// Valid-but-weird documents: parsing may succeed or fail, but it must
/// return. (Each of these is fed through the full pipeline; the test
/// passing at all is the assertion — a panic or abort fails the run.)
#[test]
fn weird_documents_return_instead_of_crashing() {
    let mut docs: Vec<String> = [
        "01", "1.", "0.0e0", "-0", "9007199254740993", "1e-999",
        "\"\\u0000\"", "[\"\\ud83d\\ude00\"]", "{\"\":{\"\":{\"\":0}}}",
        "[[[[[[[[[[1]]]]]]]]]]",
    ]
    .into_iter()
    .map(String::from)
    .collect();
    // a 512 KiB string body — bounded work, no amplification
    docs.push(format!("\"{}\"", "a".repeat(512 * 1024)));
    // many siblings at one level: breadth is fine, only depth is capped
    docs.push(format!("[{}1]", "1,".repeat(50_000)));
    for doc in docs {
        let _ = Json::parse(&doc);
    }
}

/// The serve intake path: whatever the parser *does* accept must then
/// survive the strict `apply_json` — unknown fields, lossy numerics,
/// and non-object documents all come back as field-naming errors.
#[test]
fn apply_json_survives_the_corpus_and_rejects_with_field_names() {
    // Parseable-but-invalid configs, with the substring the error must name.
    for (doc, needle) in [
        (r#"{"shards": -1}"#, "shards"),
        (r#"{"max_steps": 1.5}"#, "max_steps"),
        (r#"{"seed": 1e30}"#, "seed"),
        (r#"{"tangents": true}"#, "tangents"),
        (r#"{"lr": "fast"}"#, "lr"),
        (r#"{"steps": 10}"#, "steps"),
        (r#"{"algo": "gprx"}"#, "gprx"),
        (r#"[1,2,3]"#, "object"),
        (r#""gpr""#, "object"),
        (r#"null"#, "object"),
        (r#"42"#, "object"),
    ] {
        let j = Json::parse(doc).expect(doc);
        let err = SessionBuilder::new().apply_json(&j).map(|_| ()).expect_err(doc);
        let msg = format!("{err:#}");
        assert!(msg.contains(needle), "{doc}: error must name the problem: {msg}");
    }
    // And a fully valid document still applies.
    let j = Json::parse(r#"{"algo":"gpr","max_steps":3,"seed":9,"shards":2}"#).unwrap();
    let b = SessionBuilder::new().apply_json(&j).unwrap();
    assert_eq!(b.config().max_steps, 3);
    assert_eq!(b.config().seed, 9);
    assert_eq!(b.config().shards, 2);
}
