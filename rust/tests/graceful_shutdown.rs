//! ADR-008 graceful shutdown: a shutdown request observed at an update
//! boundary writes a final checkpoint (even off the periodic schedule)
//! and exits the loop cleanly — and a later `--resume` continues the
//! interrupted trajectory bit for bit.
//!
//! Lives in its own integration binary: the shutdown flag is process
//! global (it models SIGINT), so this test must not share a process with
//! other `TrainSession::run` tests. The flag is raised from inside the
//! run by an observer — after `run()` has installed the handler and reset
//! the flag — exactly the ordering a real mid-run SIGINT has.

use lgp::config::{Algo, OptimKind, RunConfig};
use lgp::metrics::LogRow;
use lgp::observer::TrainObserver;
use lgp::session::{SessionBuilder, TrainSession};
use std::path::PathBuf;

fn tiny_cfg(ckpt_dir: Option<PathBuf>, resume: bool) -> Option<RunConfig> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: tiny artifacts not built");
        return None;
    }
    Some(RunConfig {
        artifacts_dir: dir,
        algo: Algo::Gpr,
        f: 0.25,
        accum: 4,
        optimizer: OptimKind::Muon,
        lr: 0.02,
        weight_decay: 0.0,
        budget_secs: 0.0,
        max_steps: 10,
        refit_every: 4,
        ridge_lambda: 1e-4,
        train_size: 600,
        val_size: 150,
        aug_multiplier: 1,
        seed: 7,
        eval_every: 0,
        out_dir: std::env::temp_dir().join("lgp_shutdown_out"),
        track_alignment: true,
        adaptive_f: false,
        backend: lgp::tensor::BackendKind::Blocked,
        shards: lgp::config::shards_env_override().expect("LGP_SHARDS").unwrap_or(1),
        estimator: None,
        tangents: 8,
        checkpoint_dir: ckpt_dir,
        checkpoint_every: 0, // no periodic schedule: only shutdown writes
        resume,
    })
}

fn session(cfg: RunConfig) -> TrainSession {
    SessionBuilder::from_config(cfg).build().unwrap()
}

/// Raises the process shutdown flag after a chosen step, from inside the
/// observer fan-out — the update-boundary poll sees it on the same step.
struct InterruptAt(usize);

impl TrainObserver for InterruptAt {
    fn on_step(&mut self, row: &LogRow) -> anyhow::Result<()> {
        if row.step == self.0 {
            lgp::util::shutdown::request();
        }
        Ok(())
    }
}

#[test]
fn shutdown_request_checkpoints_and_resume_rejoins_the_trajectory() {
    let Some(golden_cfg) = tiny_cfg(None, false) else { return };
    let mut golden = session(golden_cfg);
    golden.run().unwrap();
    let golden_loss: Vec<u64> = golden.log.iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(golden.step_count(), 10);

    let ckpt = std::env::temp_dir().join("lgp_shutdown_ckpt");
    let _ = std::fs::remove_dir_all(&ckpt);

    // "SIGINT" lands during step 4: the run stops there, leaving exactly
    // one artifact — the off-schedule final checkpoint.
    let Some(cfg) = tiny_cfg(Some(ckpt.clone()), false) else { return };
    let mut interrupted = SessionBuilder::from_config(cfg)
        .observer(Box::new(InterruptAt(4)))
        .build()
        .unwrap();
    interrupted.run().unwrap();
    assert_eq!(interrupted.step_count(), 4, "run must stop at the requested boundary");
    assert!(
        ckpt.join(lgp::checkpoint::file_name(4)).exists(),
        "graceful shutdown must write a final checkpoint off-schedule"
    );

    // A fresh session resumes from the shutdown artifact and finishes the
    // budget bit-identically to the never-interrupted run.
    let Some(cfg) = tiny_cfg(Some(ckpt.clone()), true) else { return };
    let mut resumed = session(cfg);
    resumed.run().unwrap();
    assert_eq!(resumed.step_count(), 10);
    assert_eq!(resumed.params.trunk, golden.params.trunk, "resumed trunk differs (bitwise)");
    assert_eq!(resumed.params.head_w, golden.params.head_w);
    assert_eq!(resumed.params.head_b, golden.params.head_b);
    let resumed_loss: Vec<u64> = resumed.log.iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(resumed_loss, golden_loss[4..].to_vec(), "post-resume loss trace differs");

    let _ = std::fs::remove_dir_all(&ckpt);
}
