//! ADR-008/ADR-009 graceful shutdown: a shutdown request observed at an
//! update boundary writes a final checkpoint (even off the periodic
//! schedule) and exits the loop cleanly — and a later `--resume`
//! continues the interrupted trajectory bit for bit. Since ISSUE 9 the
//! handler is re-installed on every `run`, so a long-lived multi-session
//! process survives *sequential* SIGINT cycles (the old `Once`-install
//! meant the second Ctrl-C hard-killed mid-checkpoint), and serve-hosted
//! sessions carry per-session `CancelToken`s that never touch the
//! process-global flag.
//!
//! Lives in its own integration binary: the SIGINT flag is process
//! global, so these tests must not share a process with other
//! `TrainSession::run` tests — and they serialize against each other
//! through `LOCK` because the default test harness is multi-threaded.
//! The flag is raised from inside the run by an observer — after `run()`
//! has installed the handler and reset the flag — exactly the ordering a
//! real mid-run SIGINT has; `raise_sigint` delivers the real signal
//! through the real handler.

use lgp::config::{Algo, OptimKind, RunConfig};
use lgp::metrics::LogRow;
use lgp::observer::TrainObserver;
use lgp::session::{SessionBuilder, TrainSession};
use lgp::util::shutdown::CancelToken;
use std::path::PathBuf;
use std::sync::Mutex;

static LOCK: Mutex<()> = Mutex::new(());

fn tiny_cfg(ckpt_dir: Option<PathBuf>, resume: bool) -> Option<RunConfig> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: tiny artifacts not built");
        return None;
    }
    Some(RunConfig {
        artifacts_dir: dir,
        algo: Algo::Gpr,
        f: 0.25,
        accum: 4,
        optimizer: OptimKind::Muon,
        lr: 0.02,
        weight_decay: 0.0,
        budget_secs: 0.0,
        max_steps: 10,
        refit_every: 4,
        ridge_lambda: 1e-4,
        train_size: 600,
        val_size: 150,
        aug_multiplier: 1,
        seed: 7,
        eval_every: 0,
        out_dir: std::env::temp_dir().join("lgp_shutdown_out"),
        track_alignment: true,
        adaptive_f: false,
        backend: lgp::tensor::BackendKind::Blocked,
        shards: lgp::config::shards_env_override().expect("LGP_SHARDS").unwrap_or(1),
        estimator: None,
        tangents: 8,
        checkpoint_dir: ckpt_dir,
        checkpoint_every: 0, // no periodic schedule: only shutdown writes
        checkpoint_keep: 0,
        resume,
    })
}

fn session(cfg: RunConfig) -> TrainSession {
    SessionBuilder::from_config(cfg).build().unwrap()
}

/// Deliver a real SIGINT to this process — through the installed handler,
/// not `shutdown::request()` — so the test exercises handler
/// (re-)installation, not just the flag. On non-Unix targets falls back
/// to the programmatic request.
fn raise_sigint() {
    #[cfg(unix)]
    {
        extern "C" {
            fn raise(sig: i32) -> i32;
        }
        unsafe {
            raise(2); // SIGINT; handled synchronously on this thread
        }
    }
    #[cfg(not(unix))]
    lgp::util::shutdown::request();
}

/// Raises the process shutdown flag after a chosen step, from inside the
/// observer fan-out — the update-boundary poll sees it on the same step.
struct InterruptAt(usize);

impl TrainObserver for InterruptAt {
    fn on_step(&mut self, row: &LogRow) -> anyhow::Result<()> {
        if row.step == self.0 {
            lgp::util::shutdown::request();
        }
        Ok(())
    }
}

/// Like [`InterruptAt`], but via a real SIGINT delivery.
struct SigintAt(usize);

impl TrainObserver for SigintAt {
    fn on_step(&mut self, row: &LogRow) -> anyhow::Result<()> {
        if row.step == self.0 {
            raise_sigint();
        }
        Ok(())
    }
}

/// Cancels a per-session token after a chosen step.
struct CancelAt(usize, CancelToken);

impl TrainObserver for CancelAt {
    fn on_step(&mut self, row: &LogRow) -> anyhow::Result<()> {
        if row.step == self.0 {
            self.1.cancel();
        }
        Ok(())
    }
}

#[test]
fn shutdown_request_checkpoints_and_resume_rejoins_the_trajectory() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some(golden_cfg) = tiny_cfg(None, false) else { return };
    let mut golden = session(golden_cfg);
    golden.run().unwrap();
    let golden_loss: Vec<u64> = golden.log.iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(golden.step_count(), 10);

    let ckpt = std::env::temp_dir().join("lgp_shutdown_ckpt");
    let _ = std::fs::remove_dir_all(&ckpt);

    // "SIGINT" lands during step 4: the run stops there, leaving exactly
    // one artifact — the off-schedule final checkpoint.
    let Some(cfg) = tiny_cfg(Some(ckpt.clone()), false) else { return };
    let mut interrupted = SessionBuilder::from_config(cfg)
        .observer(Box::new(InterruptAt(4)))
        .build()
        .unwrap();
    interrupted.run().unwrap();
    assert_eq!(interrupted.step_count(), 4, "run must stop at the requested boundary");
    assert!(
        ckpt.join(lgp::checkpoint::file_name(4)).exists(),
        "graceful shutdown must write a final checkpoint off-schedule"
    );

    // A fresh session resumes from the shutdown artifact and finishes the
    // budget bit-identically to the never-interrupted run.
    let Some(cfg) = tiny_cfg(Some(ckpt.clone()), true) else { return };
    let mut resumed = session(cfg);
    resumed.run().unwrap();
    assert_eq!(resumed.step_count(), 10);
    assert_eq!(resumed.params.trunk, golden.params.trunk, "resumed trunk differs (bitwise)");
    assert_eq!(resumed.params.head_w, golden.params.head_w);
    assert_eq!(resumed.params.head_b, golden.params.head_b);
    let resumed_loss: Vec<u64> = resumed.log.iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(resumed_loss, golden_loss[4..].to_vec(), "post-resume loss trace differs");

    let _ = std::fs::remove_dir_all(&ckpt);
    lgp::util::shutdown::reset();
}

/// The ISSUE-9 regression: two *sequential* SIGINT-interrupted runs in one
/// process must both shut down gracefully. Under the old `Once`-install,
/// cycle 1's handler re-armed SIG_DFL and was never re-registered, so the
/// second real SIGINT here hard-killed the whole test binary — there is
/// no way for this test to "fail politely" on regression, which is the
/// point.
#[test]
fn two_sequential_sigint_cycles_both_checkpoint() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let base = std::env::temp_dir().join("lgp_shutdown_two_cycles");
    let _ = std::fs::remove_dir_all(&base);

    for (cycle, stop_at) in [(1u32, 3usize), (2, 2)] {
        let dir = base.join(format!("cycle{cycle}"));
        let Some(cfg) = tiny_cfg(Some(dir.clone()), false) else { return };
        let mut sess = SessionBuilder::from_config(cfg)
            .observer(Box::new(SigintAt(stop_at)))
            .build()
            .unwrap();
        sess.run().unwrap();
        assert_eq!(sess.step_count(), stop_at, "cycle {cycle} must stop at step {stop_at}");
        assert!(
            dir.join(lgp::checkpoint::file_name(stop_at as u64)).exists(),
            "cycle {cycle}: graceful shutdown must write its final checkpoint"
        );
    }

    let _ = std::fs::remove_dir_all(&base);
    lgp::util::shutdown::reset();
}

/// Per-session cancellation (serve, ADR-009): a token-built session stops
/// gracefully — final checkpoint included — without ever touching the
/// process-global SIGINT flag, so concurrent hosted sessions and the
/// host's own Ctrl-C handling stay independent.
#[test]
fn cancel_token_checkpoints_without_touching_the_global_flag() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    lgp::util::shutdown::reset();
    let dir = std::env::temp_dir().join("lgp_shutdown_token_ckpt");
    let _ = std::fs::remove_dir_all(&dir);

    let token = CancelToken::new();
    let Some(cfg) = tiny_cfg(Some(dir.clone()), false) else { return };
    let mut sess = SessionBuilder::from_config(cfg)
        .cancel_token(token.clone())
        .observer(Box::new(CancelAt(3, token.clone())))
        .build()
        .unwrap();
    sess.run().unwrap();
    assert_eq!(sess.step_count(), 3, "run must stop at the cancelled boundary");
    assert!(
        dir.join(lgp::checkpoint::file_name(3)).exists(),
        "cancellation must still write the final checkpoint"
    );
    assert!(token.is_cancelled());
    assert!(
        !lgp::util::shutdown::requested(),
        "a per-session cancel must never set the process-global flag"
    );

    // The same-process global path is unaffected: a fresh global-flag run
    // still completes its full budget (the token is not consulted).
    let Some(cfg) = tiny_cfg(None, false) else { return };
    let mut after = session(cfg);
    after.run().unwrap();
    assert_eq!(after.step_count(), 10);

    let _ = std::fs::remove_dir_all(&dir);
}
