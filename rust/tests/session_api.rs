//! ADR-005 public-API contract: `SessionBuilder` validation and the
//! CLI ↔ builder golden equivalence.
//!
//! Two layers:
//!
//! 1. **Validation (always runs).** Misconfigurations — `f` outside
//!    (0, 1], `shards == 0`, `accum == 0`, conflicting budget/steps
//!    (neither set: the run would never terminate) — must fail at
//!    `build()` with their own message, *before* the artifact directory
//!    is touched, on both the builder path and the CLI-flag path.
//!
//! 2. **Golden run (artifact-gated).** The same tiny-preset run
//!    configured once through CLI flags (`session::cli::builder_from_args`,
//!    the exact path `lgp train` takes) and once through chainable
//!    setters must produce bit-identical parameters and loss traces —
//!    the CLI is a thin adapter, not a second code path.

use lgp::observer::{RefitEvent, RunSummary, TrainObserver};
use lgp::prelude::*;
use lgp::session::cli::builder_from_args;
use lgp::session::SessionBuilder;
use lgp::util::cli::Args;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

fn parse(s: &str) -> Args {
    Args::parse(s.split_whitespace().map(String::from)).unwrap()
}

// ---------------------------------------------------------------------------
// Layer 1: validation
// ---------------------------------------------------------------------------

#[test]
fn builder_rejects_f_outside_unit_interval() {
    for f in [0.0, -0.25, 1.5] {
        let err = SessionBuilder::new().f(f).build().unwrap_err();
        assert!(format!("{err}").contains("f must be in (0,1]"), "f={f}: {err}");
    }
    // f = 1 is the valid boundary: validation passes and the failure (if
    // any) comes from the missing artifacts, not the range check.
    let err = SessionBuilder::new().f(1.0).artifacts("no/such/dir").build().unwrap_err();
    assert!(!format!("{err}").contains("f must be"), "{err}");
}

#[test]
fn builder_rejects_zero_shards_and_zero_accum() {
    let err = SessionBuilder::new().shards(0).build().unwrap_err();
    assert!(format!("{err}").contains("shards must be >= 1"), "{err}");
    let err = SessionBuilder::new().accum(0).build().unwrap_err();
    assert!(format!("{err}").contains("accum must be >= 1"), "{err}");
}

#[test]
fn builder_rejects_conflicting_budget_and_steps() {
    // Neither a budget nor a step limit: the loop would never terminate.
    let err = SessionBuilder::new().max_steps(0).budget_secs(0.0).build().unwrap_err();
    assert!(format!("{err}").contains("budget or a step limit"), "{err}");
    // Either one alone satisfies the constraint (validation passes; any
    // error past that point is about the artifact directory).
    for b in [
        SessionBuilder::new().max_steps(1).budget_secs(0.0).artifacts("no/such/dir"),
        SessionBuilder::new().max_steps(0).budget_secs(1.0).artifacts("no/such/dir"),
    ] {
        let err = b.build().unwrap_err();
        assert!(!format!("{err}").contains("budget or a step limit"), "{err}");
    }
}

#[test]
fn cli_path_applies_the_same_validation() {
    let err = builder_from_args(&parse("train --f 1.5")).unwrap().build().unwrap_err();
    assert!(format!("{err}").contains("f must be in (0,1]"), "{err}");
    let err = builder_from_args(&parse("train --shards 0")).unwrap().build().unwrap_err();
    assert!(format!("{err}").contains("shards must be >= 1"), "{err}");
}

#[test]
fn explicit_estimator_f_is_validated() {
    let err = SessionBuilder::new()
        .estimator(Box::new(ControlVariate::new(2.0)))
        .build()
        .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("control fraction") && msg.contains("control-variate"), "{msg}");
}

#[test]
fn cli_and_builder_accumulate_identical_configs() {
    let args = parse(
        "train --preset small --algo baseline --f 0.5 --steps 7 --seed 9 \
         --backend blocked --shards 2 --accum 4 --lr 0.05 --refit-every 5 \
         --train-size 640 --val-size 160 --aug-mult 1 --eval-every 0 --no-alignment",
    );
    let from_cli = builder_from_args(&args).unwrap();
    let by_hand = SessionBuilder::new()
        .preset("small")
        .algo(Algo::Baseline)
        .f(0.5)
        .max_steps(7)
        .seed(9)
        .backend(BackendKind::Blocked)
        .shards(2)
        .accum(4)
        .lr(0.05)
        .refit_every(5)
        .train_size(640)
        .val_size(160)
        .aug_multiplier(1)
        .eval_every(0)
        .track_alignment(false);
    assert_eq!(from_cli.config(), by_hand.config());
}

// ---------------------------------------------------------------------------
// Layer 2: golden run, artifact-gated
// ---------------------------------------------------------------------------

fn tiny_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: tiny artifacts not built");
        return None;
    }
    Some(dir)
}

#[test]
fn cli_and_builder_tiny_runs_are_bit_identical() {
    let Some(dir) = tiny_dir() else { return };
    let flags = format!(
        "train --artifacts {} --algo gpr --f 0.25 --steps 4 --accum 2 --seed 7 \
         --train-size 600 --val-size 150 --aug-mult 1 --refit-every 2 \
         --eval-every 0 --backend blocked",
        dir.display()
    );
    let mut via_cli = builder_from_args(&parse(&flags)).unwrap().build().unwrap();
    via_cli.run().unwrap();

    let mut via_builder = SessionBuilder::new()
        .artifacts(dir)
        .algo(Algo::Gpr)
        .f(0.25)
        .max_steps(4)
        .accum(2)
        .seed(7)
        .train_size(600)
        .val_size(150)
        .aug_multiplier(1)
        .refit_every(2)
        .eval_every(0)
        .backend(BackendKind::Blocked)
        .build()
        .unwrap();
    via_builder.run().unwrap();

    assert_eq!(via_cli.params.trunk, via_builder.params.trunk, "trunk differs (bitwise)");
    assert_eq!(via_cli.params.head_w, via_builder.params.head_w);
    assert_eq!(via_cli.params.head_b, via_builder.params.head_b);
    let loss_cli: Vec<u64> = via_cli.log.iter().map(|r| r.loss.to_bits()).collect();
    let loss_bld: Vec<u64> = via_builder.log.iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(loss_cli, loss_bld, "loss traces differ (bitwise)");
}

#[test]
fn observers_see_the_whole_run() {
    let Some(dir) = tiny_dir() else { return };
    #[derive(Clone, Default)]
    struct Probe(Arc<Mutex<(usize, usize, usize, Option<RunSummary>)>>);
    impl TrainObserver for Probe {
        fn on_step(&mut self, _row: &LogRow) -> anyhow::Result<()> {
            self.0.lock().unwrap().0 += 1;
            Ok(())
        }
        fn on_eval(&mut self, _step: usize, _val: f64) -> anyhow::Result<()> {
            self.0.lock().unwrap().1 += 1;
            Ok(())
        }
        fn on_refit(&mut self, _ev: &RefitEvent) -> anyhow::Result<()> {
            self.0.lock().unwrap().2 += 1;
            Ok(())
        }
        fn on_end(&mut self, s: &RunSummary) -> anyhow::Result<()> {
            self.0.lock().unwrap().3 = Some(*s);
            Ok(())
        }
    }
    let probe = Probe::default();
    let mut session = SessionBuilder::new()
        .artifacts(dir)
        .algo(Algo::Gpr)
        .f(0.25)
        .max_steps(4)
        .accum(2)
        .seed(7)
        .train_size(600)
        .val_size(150)
        .aug_multiplier(1)
        .refit_every(2)
        .eval_every(0)
        .backend(BackendKind::Blocked)
        .observer(Box::new(probe.clone()))
        .build()
        .unwrap();
    session.run().unwrap();
    let (steps, evals, refits, summary) = probe.0.lock().unwrap().clone();
    assert_eq!(steps, 4, "one on_step per optimizer update");
    assert!(evals >= 1, "the final eval must be observed");
    assert!(refits >= 1, "the refit inside the window must be observed");
    let s = summary.expect("on_end fired");
    assert_eq!(s.steps, 4);
    assert_eq!(s.examples_seen, session.examples_seen);
}

#[test]
fn predicted_lgp_estimator_runs_end_to_end() {
    // The ablation estimator trains through the same session machinery —
    // the estimator seam is real, not a ControlVariate special case.
    let Some(dir) = tiny_dir() else { return };
    let mut session = SessionBuilder::new()
        .artifacts(dir)
        .estimator(Box::new(PredictedLgp::new(0.25)))
        .max_steps(6)
        .accum(2)
        .seed(7)
        .train_size(600)
        .val_size(150)
        .aug_multiplier(1)
        .refit_every(2)
        .eval_every(0)
        .backend(BackendKind::Blocked)
        .build()
        .unwrap();
    assert_eq!(session.estimator().name(), "predicted-lgp");
    session.run().unwrap();
    assert_eq!(session.step_count(), 6);
    assert!(session.pred.fits >= 1, "the biased blend still refits the predictor");
    assert!(session.log.iter().all(|r| r.loss.is_finite()));
}
