//! Statistical unbiasedness suite for the estimator zoo (ADR-006).
//!
//! Lemma 1 of the paper says eq. (1) is an unbiased estimator of the mean
//! gradient *for any predictor* — even a deliberately broken one. This
//! suite turns that claim (and its converse for the no-correction
//! ablation) into a deterministic Monte Carlo z-test:
//!
//! 1. Build a seeded [`Testbed`] population and compute the exact
//!    population gradient μ = ∇F.
//! 2. Fit the linear predictor, then **corrupt it** (scale the bilinear
//!    coefficients to 25%) so its predictions are badly biased.
//! 3. Sample each estimator `TRIALS` times on disjoint windows of one
//!    seeded index stream and compare the per-coordinate sample mean to
//!    μ via z = |mean − μ| / stderr.
//!
//! ControlVariate (with the corrupted predictor!), MultiTangentForward,
//! NeuralControlVariate and TrueBackprop must keep max|z| under a wide
//! normal-range bound; PredictedLgp — the same corrupted predictor minus
//! the control-variate correction — must blow far past it. Every draw is
//! seeded, so the verdict is bit-stable run to run.

use lgp::estimator::testbed::Testbed;
use lgp::estimator::{
    ControlVariate, GradientEstimator, MultiTangentForward, NeuralControlVariate, PredictedLgp,
    TrueBackprop,
};
use lgp::model::manifest::Manifest;
use lgp::predictor::fit::{fit_with, FitBuffer};
use lgp::predictor::Predictor;
use lgp::tensor::stats::mean_stderr;
use lgp::tensor::{Backend, Workspace};
use lgp::util::rng::Pcg64;

const SEED: u64 = 42;
const TRIALS: usize = 2500;
/// With ~100 coordinates and 2500 trials, the max of the null |z|'s sits
/// near 3; 6 leaves a wide margin against f32 accumulation noise.
const UNBIASED_MAX_Z: f64 = 6.0;
/// The corrupted predictor biases the blend by ~0.56·μ_trunk, which at
/// these trial counts is dozens of standard errors — 12 is conservative.
const BIASED_MIN_Z: f64 = 12.0;

struct Harness {
    tb: Testbed,
    man: Manifest,
    /// Linear predictor fitted on real gradients, then corrupted.
    pred: Predictor,
    /// The fit stream, kept so neural-cv trains on the same data.
    buf: FitBuffer,
    /// Exact population gradient, concat layout.
    mu: Vec<f32>,
}

fn harness() -> Harness {
    let tb = Testbed::new(SEED, 192, 12, 6, 4);
    let man = tb.manifest(8, 2);
    let mut buf = FitBuffer::new(man.n_fit);
    let mut fit_rng = Pcg64::new(SEED, 0x7a66);
    let idxs: Vec<usize> =
        (0..man.n_fit).map(|_| fit_rng.below(tb.n as u64) as usize).collect();
    tb.fill_fit_buffer(&mut buf, &idxs);
    let mut pred = Predictor::new(tb.trunk_params(), tb.width, man.rank);
    fit_with(Backend::blocked(), &mut pred, &buf, 1e-4).unwrap();
    // Corrupt the fit: trunk predictions shrink to 25% of the fitted
    // values. Lemma 1 says the control-variate rows must not care.
    for v in pred.b.data.iter_mut() {
        *v *= 0.25;
    }
    let mu = tb.population_grad().concat();
    Harness { tb, man, pred, buf, mu }
}

/// Monte Carlo max-|z| of `est` against the population gradient: TRIALS
/// slot estimates on disjoint windows of one seeded stream, then the
/// worst per-coordinate z-score. Deterministic for fixed SEED.
fn max_abs_z(h: &Harness, est: &dyn GradientEstimator, ready: bool) -> f64 {
    let plan = est.plan(&h.man, ready);
    let consumed = plan.consumed_per_slot();
    let mut rng = Pcg64::new(SEED, 0x7a31);
    let stream: Vec<usize> =
        (0..TRIALS * consumed).map(|_| rng.below(h.tb.n as u64) as usize).collect();
    let p = h.mu.len();
    let mut samples: Vec<Vec<f64>> = vec![Vec::with_capacity(TRIALS); p];
    for t in 0..TRIALS {
        let (g, _) = h.tb.slot_estimate(est, &plan, &h.pred, &stream, t * consumed).unwrap();
        for (c, v) in g.concat().iter().enumerate() {
            samples[c].push(*v as f64);
        }
    }
    let mut worst = 0.0f64;
    for c in 0..p {
        let (m, se) = mean_stderr(&samples[c]);
        let z = (m - h.mu[c] as f64).abs() / se.max(1e-12);
        worst = worst.max(z);
    }
    worst
}

#[test]
fn unbiased_zoo_members_match_the_population_gradient() {
    let h = harness();

    // TrueBackprop: the sanity anchor — a plain mini-batch mean.
    let tb_est = TrueBackprop;
    let z = max_abs_z(&h, &tb_est, false);
    assert!(z < UNBIASED_MAX_Z, "true-backprop max|z| = {z}");

    // ControlVariate with the *corrupted* predictor: Lemma 1 in action.
    let mut cv = ControlVariate::new(0.25);
    cv.bind(&h.man).unwrap();
    let z = max_abs_z(&h, &cv, true);
    assert!(z < UNBIASED_MAX_Z, "control-variate max|z| = {z}");

    // MultiTangentForward: unbiased because E[v vᵀ] = I.
    let mut mtf = MultiTangentForward::new(8, SEED);
    mtf.bind(&h.man).unwrap();
    let z = max_abs_z(&h, &mtf, false);
    assert!(z < UNBIASED_MAX_Z, "multi-tangent max|z| = {z}");

    // NeuralControlVariate: its own MLP fit, same eq.-(1) correction.
    let mut ncv = NeuralControlVariate::new(0.25).with_seed(SEED).with_mlp(8, 120, 0.05);
    ncv.bind(&h.man).unwrap();
    ncv.fit_own(Backend::blocked(), &h.buf, 1e-4, &mut Workspace::new()).unwrap();
    assert!(ncv.predictor_ready(0));
    let z = max_abs_z(&h, &ncv, true);
    assert!(z < UNBIASED_MAX_Z, "neural-cv max|z| = {z}");
}

#[test]
fn predicted_lgp_fails_the_same_z_bound() {
    let h = harness();
    // The identical corrupted predictor, minus the correction term: the
    // bias (1−f)(E[g_p] − μ) is now fully exposed. This is the Section 3
    // ablation measured, not asserted.
    let mut lgp_est = PredictedLgp::new(0.25);
    lgp_est.bind(&h.man).unwrap();
    let z = max_abs_z(&h, &lgp_est, true);
    assert!(
        z > BIASED_MIN_Z,
        "predicted-lgp should be detectably biased, but max|z| = {z}"
    );
}
