//! Integration: the theory module reproduces every number the paper quotes
//! in Section 5, and the Monte-Carlo validator confirms Proposition 2 at
//! the paper's own example points.

use lgp::theory::{self, CostModel};

const COST: CostModel = CostModel { forward: 1.0, backward: 2.0, cheap_forward: 0.7 };

#[test]
fn paper_quoted_rho_star_values() {
    // Theorem 3: ρ*(0.1,1)≈0.876, ρ*(0.2,1)≈0.802, ρ*(0.5,1)≈0.689.
    let cases = [(0.1, 0.876), (0.2, 0.802), (0.5, 0.689)];
    for (f, want) in cases {
        let got = theory::rho_star(f, 1.0, &COST);
        assert!((got - want).abs() < 5e-4, "rho*({f},1) = {got}, paper {want}");
    }
}

#[test]
fn paper_quoted_regime_switch() {
    // ρ_switch(1) = 1/2 + 0.7/6 ≈ 0.61667.
    let got = theory::rho_switch(1.0, &COST);
    assert!((got - 0.6166666).abs() < 1e-4, "{got}");
}

#[test]
fn paper_quoted_f_star_example() {
    // f*(ρ=0.8, κ=1) = sqrt(0.28/1.38) ≈ 0.45.
    let got = theory::f_star(0.8, 1.0, &COST);
    assert!((got - (0.28f64 / 1.38).sqrt()).abs() < 1e-9);
    assert!((got - 0.45).abs() < 0.005, "{got}");
}

#[test]
fn gamma_range_matches_paper() {
    // γ(f) ∈ (0.7/3, 1]
    assert!((COST.gamma(1.0) - 1.0).abs() < 1e-12);
    let tiny = COST.gamma(1e-9);
    assert!((tiny - 0.7 / 3.0).abs() < 1e-6);
    // monotone increasing in f
    let mut prev = 0.0;
    for i in 1..=20 {
        let g = COST.gamma(i as f64 / 20.0);
        assert!(g > prev);
        prev = g;
    }
}

#[test]
fn monte_carlo_validates_prop2_at_paper_operating_points() {
    // The Figure-1 configuration: f = 1/4. Check the variance identity at
    // alignments around the Thm 3 break-even for that f.
    for &(rho, kappa) in &[(0.775, 1.0), (0.9, 1.0), (0.8, 1.2)] {
        let mc = theory::monte_carlo_phi(32, 16, 0.25, rho, kappa, 2000, 11);
        let rel = (mc.phi_empirical - mc.phi_closed_form).abs() / mc.phi_closed_form;
        assert!(
            rel < 0.15,
            "(rho={rho}, kappa={kappa}): empirical {} vs closed {} (rel {rel})",
            mc.phi_empirical,
            mc.phi_closed_form
        );
    }
}

#[test]
fn break_even_is_consistent_with_q() {
    for &f in &[0.1, 0.25, 0.5] {
        for &k in &[0.9, 1.0, 1.1] {
            let rs = theory::rho_star(f, k, &COST);
            assert!(theory::is_break_even(f, rs + 1e-6, k, &COST));
            assert!(!theory::is_break_even(f, rs - 1e-3, k, &COST));
        }
    }
}

#[test]
fn perfect_predictor_strictly_dominates() {
    // ρ = κ = 1 ⇒ Q(f) = γ(f) < 1 for all f < 1 (paper Sec. 5.3).
    for i in 1..20 {
        let f = i as f64 / 20.0;
        let q = theory::q_objective(f, 1.0, 1.0, &COST);
        assert!((q - COST.gamma(f)).abs() < 1e-12);
        assert!(q < 1.0);
    }
}

#[test]
fn custom_cost_models_shift_break_even() {
    // A cheaper CheapForward lowers ρ*; an expensive one raises it.
    let cheap = CostModel { cheap_forward: 0.3, ..COST };
    let pricey = CostModel { cheap_forward: 1.0, ..COST };
    let mid = theory::rho_star(0.25, 1.0, &COST);
    assert!(theory::rho_star(0.25, 1.0, &cheap) < mid);
    assert!(theory::rho_star(0.25, 1.0, &pricey) > mid);
}
