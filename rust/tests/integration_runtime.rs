//! Integration: the Rust runtime loads and executes the AOT artifacts and
//! the numbers agree with the paper's algebra (Sec. 4.3) computed on the
//! Rust side. Requires tiny artifacts: `make artifacts`.

use lgp::model::Manifest;
use lgp::predictor::{residuals, Predictor};
use lgp::runtime::Runtime;
use lgp::tensor::{stats, Tensor};
use lgp::util::rng::Pcg64;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: tiny artifacts not built (run `make artifacts`)");
        None
    }
}

fn rand_batch(rng: &mut Pcg64, m: usize, img: usize, classes: usize) -> (Vec<f32>, Vec<i32>) {
    let mut x = vec![0.0f32; m * 3 * img * img];
    rng.fill_normal(&mut x, 1.0);
    let y = (0..m).map(|_| rng.below(classes as u64) as i32).collect();
    (x, y)
}

#[test]
fn runtime_loads_and_executes_all_entry_points() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let m = rt.manifest.clone();
    let params = lgp::model::ParamStore::load_init(&m).unwrap();
    let dev = rt.upload_params(&params).unwrap();
    let mut rng = Pcg64::seeded(1);

    // train_grads on the full micro-batch
    let (x, y) = rand_batch(&mut rng, m.micro_batch, m.image, m.classes);
    let out = rt.train_grads(&dev, &x, &y, m.micro_batch).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert_eq!(out.g_trunk.len(), m.trunk_params);
    assert_eq!(out.a.len(), m.micro_batch * m.width);
    assert_eq!(out.probs.len(), m.micro_batch * m.classes);
    assert!(out.g_trunk.iter().all(|v| v.is_finite()));
    // probabilities are normalized
    for row in out.probs.chunks(m.classes) {
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "probs row sums to {s}");
    }

    // cheap_fwd agrees with the train-path forward on the same inputs.
    // Use batch 8: it exists both as a train_grads size (control batch of
    // f=0.5) and as a cheap_fwd size (prediction batch of f=0.5).
    let (x8, y8) = rand_batch(&mut rng, 8, m.image, m.classes);
    let out8 = rt.train_grads(&dev, &x8, &y8, 8).unwrap();
    let (a2, p2) = rt.cheap_fwd(&dev, &x8, 8).unwrap();
    for (u, v) in out8.a.iter().zip(&a2) {
        assert!((u - v).abs() < 5e-3, "activations diverge: {u} vs {v}");
    }
    for (u, v) in out8.probs.iter().zip(&p2) {
        assert!((u - v).abs() < 5e-3);
    }

    // per_example_grads average to the batch gradient
    let n = m.n_chunk;
    let (xf, yf) = rand_batch(&mut rng, n, m.image, m.classes);
    let (rows, a_fit, _probs_fit) = rt.per_example_grads(&dev, &xf, &yf).unwrap();
    assert_eq!(rows.len(), n);
    assert_eq!(a_fit.len(), n * m.width);
    let tg = rt.train_grads(&dev, &xf, &yf, n);
    if let Ok(tg) = tg {
        let mut mean = vec![0.0f32; m.trunk_params];
        for r in &rows {
            for (mv, rv) in mean.iter_mut().zip(r) {
                *mv += rv / n as f32;
            }
        }
        let cos = stats::cosine(&mean, &tg.g_trunk);
        assert!(cos > 0.999, "per-example mean vs batch grad cosine {cos}");
    }

    // cv_combine matches the host formula
    let p_total = m.total_params;
    let mut g1 = vec![0.0f32; p_total];
    let mut g2 = vec![0.0f32; p_total];
    let mut g3 = vec![0.0f32; p_total];
    rng.fill_normal(&mut g1, 1.0);
    rng.fill_normal(&mut g2, 1.0);
    rng.fill_normal(&mut g3, 1.0);
    let f = 0.25f32;
    let dev_out = rt.cv_combine(&g1, &g2, &g3, f).unwrap();
    for i in 0..p_total {
        let want = f * g1[i] + (1.0 - f) * (g3[i] - (g2[i] - g1[i]));
        assert!((dev_out[i] - want).abs() < 1e-4);
    }
}

#[test]
fn device_predict_grad_matches_host_predictor() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let m = rt.manifest.clone();
    let params = lgp::model::ParamStore::load_init(&m).unwrap();
    let dev = rt.upload_params(&params).unwrap();
    let mut rng = Pcg64::seeded(2);

    // random (but installed) predictor state
    let mut pred = Predictor::new(m.trunk_params, m.width, m.rank);
    let mut u = Tensor::zeros(&[m.trunk_params, m.rank]);
    let mut b = Tensor::zeros(&[m.rank, m.feat_dim]);
    rng.fill_normal(&mut u.data, 0.05);
    rng.fill_normal(&mut b.data, 0.05);
    pred.install(u, b);
    let dev_pred = rt.upload_predictor(&pred, None).unwrap();

    // batch through cheap_fwd for realistic activations
    let (mc, _) = m.split_sizes(0.25);
    let (x, y) = rand_batch(&mut rng, mc, m.image, m.classes);
    let tg = rt.train_grads(&dev, &x, &y, mc).unwrap();
    let out = rt.predict_grad(&tg.a, &tg.probs, &y, &dev, &dev_pred, mc).unwrap();

    // host-side mirror of the same math
    let resid = residuals(&tg.probs, &y, m.classes, m.label_smoothing as f32);
    let h = Predictor::backprop_features(&resid, &params.head_w, m.width);
    let a_t = Tensor::from_vec(tg.a.clone(), &[mc, m.width]);
    let host_trunk = pred.predict_mean_trunk(&a_t, &h);
    let cos = stats::cosine(&host_trunk, &out.g_trunk);
    assert!(cos > 0.999, "device vs host predictor cosine: {cos}");
    let (gw_host, gb_host) = Predictor::head_grads(&a_t, &resid);
    for (u, v) in gw_host.iter().zip(&out.g_head_w) {
        assert!((u - v).abs() < 1e-3, "{u} vs {v}");
    }
    for (u, v) in gb_host.iter().zip(&out.g_head_b) {
        assert!((u - v).abs() < 1e-4);
    }

    // the paper's Sec 4.3 identity: device head grad == exact head grad
    // from train_grads (both are A^T R / m)
    for (u, v) in out.g_head_w.iter().zip(&tg.g_head_w) {
        assert!((u - v).abs() < 1e-3, "head grads disagree: {u} vs {v}");
    }
}

#[test]
fn manifest_split_sizes_have_artifacts() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    for &f in &m.fs.clone() {
        let (mc, mp) = m.split_sizes(f);
        assert!(m.artifact(&m.train_grads_name(mc)).is_ok(), "f={f}");
        assert!(m.artifact(&m.predict_grad_name(mc)).is_ok(), "f={f}");
        if mp > 0 {
            assert!(m.artifact(&m.cheap_fwd_name(mp)).is_ok(), "f={f}");
            assert!(m.artifact(&m.predict_grad_name(mp)).is_ok(), "f={f}");
        }
    }
}

#[test]
fn runtime_errors_are_descriptive() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let err = match rt.exe("no_such_artifact") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("no_such_artifact"), "{err}");
    // missing directory
    let msg = match Runtime::load(std::path::Path::new("/nonexistent/dir")) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected error"),
    };
    assert!(msg.contains("make artifacts"), "{msg}");
}
