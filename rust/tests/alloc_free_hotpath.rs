//! Zero-allocation contract of the steady-state hot path (ADR-003).
//!
//! Gated behind the `alloc-counter` feature, which installs a counting
//! global allocator (`util::alloc_track`):
//!
//! ```sh
//! cargo test --features alloc-counter --test alloc_free_hotpath
//! ```
//!
//! The test drives the exact host-side work one GPR optimizer update does
//! — per-example rows pushed into the (full) `FitBuffer` ring, the eq. 1
//! control-variate combine fused in place over preallocated gradient
//! slabs, and a Muon step (momentum blend + Newton–Schulz through the
//! workspace-aware kernels) — warms it up, then asserts the allocation
//! counter does not move across five further iterations.
//!
//! The multi-worker variant (ADR-004) runs the same steady-state loop on
//! several threads at once, each with its own per-shard state (`Workspace`
//! arena, `FitBuffer` ring, optimizer), and asserts the *global* counter
//! does not move while all workers iterate concurrently — per-worker
//! arena reuse holds and sharding introduces no cross-thread allocation
//! churn.
//!
//! The pool variant (ADR-007) pins the persistent worker pool's dispatch
//! protocol itself: once warm, a park → unpark → run → park round trip
//! with zero-sized task results performs no heap allocation at all — the
//! job descriptor lives on the dispatcher's stack, the completion
//! counters are pre-allocated in the pool, and a `Vec` of ZST results
//! never touches the allocator.

#![cfg(feature = "alloc-counter")]

use lgp::config::OptimKind;
use lgp::estimator::combine::cv_combine_into;
use lgp::model::manifest::{Manifest, TrunkParam};
use lgp::model::params::{FlatGrad, ParamStore};
use lgp::optim::{OptimConfig, Optimizer};
use lgp::predictor::fit::FitBuffer;
use lgp::tensor::Backend;
use lgp::util::alloc_track;
use lgp::util::rng::Pcg64;
use std::collections::BTreeMap;

const D: usize = 16;
const CLASSES: usize = 4;

/// The allocation counter is process-global, so the two steady-state
/// tests must not overlap (libtest runs tests on parallel threads) — each
/// takes this lock around its measured window.
static COUNTER_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Two Muon matrices (one needing the transposed Newton–Schulz path) plus
/// a non-matrix bias slot, so the step exercises both NS orientations and
/// the AdamW fallback.
fn manifest_and_params() -> (Manifest, ParamStore) {
    let layout = vec![
        TrunkParam { name: "w0".into(), shape: vec![24, 16], offset: 0, len: 384, muon: true },
        TrunkParam { name: "b0".into(), shape: vec![16], offset: 384, len: 16, muon: false },
        TrunkParam { name: "w1".into(), shape: vec![16, 24], offset: 400, len: 384, muon: true },
    ];
    let trunk_params = 784;
    let manifest = Manifest {
        dir: ".".into(),
        preset: "alloc-test".into(),
        image: 8,
        classes: CLASSES,
        width: D,
        label_smoothing: 0.0,
        rank: 2,
        n_chunk: 4,
        n_fit: 8,
        feat_dim: D,
        trunk_params,
        total_params: trunk_params + D * CLASSES + CLASSES,
        micro_batch: 8,
        fs: vec![0.25],
        val_batch: 8,
        trunk_layout: layout,
        artifacts: BTreeMap::new(),
        init_trunk: ".".into(),
        init_head_w: ".".into(),
        init_head_b: ".".into(),
    };
    let params = ParamStore {
        trunk: (0..trunk_params).map(|i| (i % 7) as f32 * 0.01 - 0.02).collect(),
        head_w: vec![0.05; D * CLASSES],
        head_b: vec![0.0; CLASSES],
        width: D,
        classes: CLASSES,
    };
    (manifest, params)
}

struct Loop {
    rng: Pcg64,
    buf: FitBuffer,
    grad_row: Vec<f32>,
    a_row: Vec<f32>,
    h_row: Vec<f32>,
    g: FlatGrad,
    g_cp: FlatGrad,
    g_p: FlatGrad,
    params: ParamStore,
    opt: Optimizer,
    manifest: Manifest,
}

impl Loop {
    fn new() -> Loop {
        let (manifest, params) = manifest_and_params();
        let opt = Optimizer::new(
            OptimKind::Muon,
            OptimConfig { lr: 0.02, backend: Backend::micro(), ..OptimConfig::default() },
            &params,
            &manifest,
        );
        let mut rng = Pcg64::seeded(7);
        let g = FlatGrad::zeros_like(&params);
        let mut g_cp = FlatGrad::zeros_like(&params);
        let mut g_p = FlatGrad::zeros_like(&params);
        rng.fill_normal(&mut g_cp.trunk, 0.1);
        rng.fill_normal(&mut g_p.trunk, 0.1);
        Loop {
            buf: FitBuffer::new(8),
            grad_row: vec![0.0; manifest.trunk_params],
            a_row: vec![0.0; D],
            h_row: vec![0.0; D],
            g,
            g_cp,
            g_p,
            params,
            opt,
            manifest,
            rng,
        }
    }

    /// One steady-state "micro-batch + combine + optimizer step": exactly
    /// the host-side work of one GPR update after warm-up.
    fn iteration(&mut self) {
        // micro-batch: per-example rows into the sliding-window ring
        for _ in 0..4 {
            self.rng.fill_normal(&mut self.grad_row, 1.0);
            self.rng.fill_normal(&mut self.a_row, 1.0);
            self.rng.fill_normal(&mut self.h_row, 1.0);
            self.buf.push(&self.grad_row, &self.a_row, &self.h_row);
        }
        // control gradient refreshed in place, then eq. 1 fused combine
        self.rng.fill_normal(&mut self.g.trunk, 0.1);
        self.rng.fill_normal(&mut self.g.head_w, 0.1);
        cv_combine_into(&mut self.g, &self.g_cp, &self.g_p, 0.25);
        // one Muon update (momentum + Newton–Schulz + AdamW fallback)
        self.opt.step(&mut self.params, &self.g, &self.manifest);
    }
}

#[test]
fn steady_state_hot_loop_is_allocation_free() {
    let _serial = COUNTER_LOCK.lock().unwrap();
    let mut hot = Loop::new();
    // Warm-up: fill the ring past capacity and let every arena (optimizer
    // workspace, micro-kernel panels) reach its steady footprint.
    for _ in 0..3 {
        hot.iteration();
    }
    assert!(hot.buf.is_full(), "ring must be in sliding-window steady state");

    let before = alloc_track::alloc_count();
    for _ in 0..5 {
        hot.iteration();
    }
    let after = alloc_track::alloc_count();
    assert_eq!(
        after - before,
        0,
        "steady-state micro-batch + combine + optimizer step allocated {} time(s)",
        after - before
    );

    // Sanity: the loop did real work (params moved, counter is live).
    assert!(alloc_track::alloc_count() > 0);
    assert!(hot.params.trunk.iter().any(|&w| w != 0.0));
}

#[test]
fn pool_dispatch_steady_state_is_allocation_free() {
    use lgp::coordinator::pool::WorkerPool;
    let _serial = COUNTER_LOCK.lock().unwrap();
    const SHARDS: usize = 3;
    const SLOTS: usize = 8;
    let pool = WorkerPool::new(SHARDS);
    let mut workers: Vec<u64> = vec![0; SHARDS];
    // Warm-up: first dispatches let the OS sync primitives and any lazy
    // per-thread state reach their steady footprint.
    for _ in 0..3 {
        pool.scatter(&mut workers, SLOTS, |w, slot| {
            *w = w.wrapping_add(slot as u64 + 1);
            Ok(())
        })
        .unwrap();
    }

    let before = alloc_track::alloc_count();
    for _ in 0..5 {
        pool.scatter(&mut workers, SLOTS, |w, slot| {
            *w = w.wrapping_add(slot as u64 + 1);
            Ok(())
        })
        .unwrap();
    }
    let after = alloc_track::alloc_count();
    assert_eq!(
        after - before,
        0,
        "pool park/unpark/dispatch round trips allocated {} time(s)",
        after - before
    );
    // Round-robin slot ownership reached every worker, so the parked
    // threads (not just the inline worker 0) were exercised.
    assert!(workers.iter().all(|&w| w > 0), "every pool worker must have run tasks");
}

#[test]
fn per_worker_steady_state_is_allocation_free_across_threads() {
    use std::sync::Barrier;
    let _serial = COUNTER_LOCK.lock().unwrap();
    const WORKERS: usize = 2;
    // Rendezvous points: A = all workers warmed (and the barrier's own
    // sync machinery exercised), B = 'before' snapshot taken, C = measured
    // window closed, D = 'after' snapshot taken (workers may only exit —
    // and let the thread runtime touch the heap — after D).
    let barrier = Barrier::new(WORKERS + 1);
    let (before, after) = std::thread::scope(|s| {
        let barrier = &barrier;
        let handles: Vec<_> = (0..WORKERS)
            .map(|_| {
                s.spawn(move || {
                    let mut hot = Loop::new();
                    for _ in 0..3 {
                        hot.iteration();
                    }
                    assert!(hot.buf.is_full(), "ring must reach steady state");
                    barrier.wait(); // A
                    barrier.wait(); // B
                    for _ in 0..5 {
                        hot.iteration();
                    }
                    barrier.wait(); // C
                    barrier.wait(); // D
                    assert!(hot.params.trunk.iter().any(|&w| w != 0.0));
                })
            })
            .collect();
        barrier.wait(); // A — everyone warm, spawn allocations behind us
        let before = alloc_track::alloc_count();
        barrier.wait(); // B — open the measured window
        barrier.wait(); // C — all workers done iterating
        let after = alloc_track::alloc_count();
        barrier.wait(); // D — release workers to exit
        for h in handles {
            h.join().unwrap();
        }
        (before, after)
    });
    assert_eq!(
        after - before,
        0,
        "{WORKERS} concurrent worker loops allocated {} time(s) in steady state",
        after - before
    );
}
