//! ADR-010 process-group determinism: a 2-process × 2-shard loopback run
//! must be bit-identical to `--shards 4` single-process (and to serial),
//! and a follower killed mid-run must leave the leader with a valid,
//! resumable final checkpoint that rejoins the golden trajectory.
//!
//! The leader runs in-process through the library API (so the test can
//! attach observers and read its state); the follower is the *real*
//! binary, spawned as `lgp train --dist-connect` exactly the way
//! `lgp launch` spawns it. Bit-identity is asserted on whole checkpoint
//! artifacts — params, optimizer, predictor, fit ring, estimator state,
//! data cursor, and the META scalar traces (loss EMA, cost units,
//! alignment tracker) all at once.
//!
//! Artifact-gated like the other session-level suites: skips cleanly when
//! artifacts/tiny has not been built. Lives in its own integration binary
//! because it spawns child processes and serializes through `LOCK`.

use lgp::config::{Algo, OptimKind, RunConfig};
use lgp::metrics::LogRow;
use lgp::observer::TrainObserver;
use lgp::session::{SessionBuilder, TrainSession};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};

static LOCK: Mutex<()> = Mutex::new(());

const STEPS: usize = 6;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: tiny artifacts not built");
        None
    }
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lgp_dist_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The shared run configuration. Every fingerprinted field here must
/// match the flags `spawn_follower` passes — the ADR-010 handshake
/// fingerprint is what *proves* they match (a drift hard-errors the
/// handshake instead of silently diverging the run).
fn tiny_cfg(shards: usize, ckpt_dir: Option<PathBuf>, resume: bool) -> Option<RunConfig> {
    Some(RunConfig {
        artifacts_dir: artifacts_dir()?,
        algo: Algo::Gpr,
        f: 0.25,
        accum: 4,
        optimizer: OptimKind::Muon,
        lr: 0.02,
        weight_decay: 0.0,
        budget_secs: 0.0,
        max_steps: STEPS,
        refit_every: 4,
        ridge_lambda: 1e-4,
        train_size: 600,
        val_size: 150,
        aug_multiplier: 1,
        seed: 7,
        eval_every: 0,
        out_dir: std::env::temp_dir().join("lgp_dist_out"),
        track_alignment: true,
        adaptive_f: false,
        backend: lgp::tensor::BackendKind::Blocked,
        shards,
        estimator: None,
        tangents: 8,
        checkpoint_dir: ckpt_dir,
        checkpoint_every: 0,
        checkpoint_keep: 0,
        resume,
    })
}

fn session(cfg: RunConfig) -> TrainSession {
    SessionBuilder::from_config(cfg).build().unwrap()
}

/// Snapshot the completed run's full state through the real artifact
/// path and return the bytes — the bit-identity comparison surface.
fn final_artifact(session: &mut TrainSession) -> Vec<u8> {
    let path = session.write_checkpoint().unwrap().expect("checkpoint dir is set");
    std::fs::read(path).unwrap()
}

/// Spawn the real binary as the rank-1 follower of a 2-process group,
/// flag-for-flag the way `lgp launch` would.
fn spawn_follower(addr: &str) -> Child {
    let art = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    let out = std::env::temp_dir().join("lgp_dist_follower_out");
    Command::new(env!("CARGO_BIN_EXE_lgp"))
        .arg("train")
        .args(["--artifacts", art.to_str().unwrap()])
        .args(["--algo", "gpr", "--f", "0.25", "--accum", "4"])
        .args(["--optimizer", "muon", "--lr", "0.02", "--weight-decay", "0"])
        .args(["--steps", "6", "--refit-every", "4", "--ridge", "0.0001"])
        .args(["--train-size", "600", "--val-size", "150", "--aug-mult", "1"])
        .args(["--seed", "7", "--eval-every", "0", "--backend", "blocked"])
        .args(["--tangents", "8", "--shards", "2"])
        .args(["--out", out.to_str().unwrap()])
        .args(["--dist-connect", addr, "--dist-procs", "2", "--dist-rank", "1"])
        .stdout(Stdio::null())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn follower")
}

/// Accept the follower, bailing early if it already died (same poll the
/// `lgp launch` supervisor runs during the handshake window).
fn accept_one(
    listener: &std::net::TcpListener,
    geom: &lgp::dist::Geometry,
    child: &mut Child,
) -> lgp::dist::DistSession {
    let accepted = lgp::dist::accept_followers(listener, geom, || {
        if let Some(status) = child.try_wait()? {
            anyhow::bail!("follower exited during handshake: {status}");
        }
        Ok(())
    });
    match accepted {
        Ok(d) => d,
        Err(e) => {
            let _ = child.kill();
            let _ = child.wait();
            panic!("dist handshake failed: {e:#}");
        }
    }
}

#[test]
fn two_proc_loopback_is_bit_identical_to_single_process() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if artifacts_dir().is_none() {
        return;
    }

    // Golden: one process, four shards.
    let golden_dir = scratch("golden");
    let Some(cfg) = tiny_cfg(4, Some(golden_dir.clone()), false) else { return };
    let mut golden = session(cfg);
    golden.run().unwrap();
    let golden_bytes = final_artifact(&mut golden);

    // Serial reference: one process, one shard.
    let serial_dir = scratch("serial");
    let Some(cfg) = tiny_cfg(1, Some(serial_dir.clone()), false) else { return };
    let mut serial = session(cfg);
    serial.run().unwrap();
    assert_eq!(
        final_artifact(&mut serial),
        golden_bytes,
        "--shards 4 must be bit-identical to serial (ADR-004 precondition)"
    );

    // Dist: 2 processes × 2 shards over loopback sockets.
    let dist_dir = scratch("group");
    let Some(cfg) = tiny_cfg(2, Some(dist_dir.clone()), false) else { return };
    let mut leader = session(cfg);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut child = spawn_follower(&addr);
    let geom = leader.dist_geometry(2);
    let d = accept_one(&listener, &geom, &mut child);
    leader.attach_dist(d).unwrap();
    leader.run().unwrap();
    let status = child.wait().unwrap();
    assert!(status.success(), "follower must exit clean on a completed run: {status}");
    assert_eq!(leader.step_count(), STEPS);
    assert_eq!(
        final_artifact(&mut leader),
        golden_bytes,
        "2 procs x 2 shards must be bit-identical to --shards 4 single-process"
    );

    for d in [golden_dir, serial_dir, dist_dir] {
        let _ = std::fs::remove_dir_all(&d);
    }
}

/// Kills the follower after a chosen leader step completes — from inside
/// the observer fan-out, so the next exchange hits a dead peer.
struct KillFollowerAt(usize, Arc<Mutex<Child>>);

impl TrainObserver for KillFollowerAt {
    fn on_step(&mut self, row: &LogRow) -> anyhow::Result<()> {
        if row.step == self.0 {
            let mut ch = self.1.lock().unwrap();
            let _ = ch.kill();
            let _ = ch.wait(); // reap now so the socket is fully closed
        }
        Ok(())
    }
}

#[test]
fn follower_death_leaves_a_valid_resumable_leader_checkpoint() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if artifacts_dir().is_none() {
        return;
    }

    // The uninterrupted single-process reference.
    let golden_dir = scratch("kill_golden");
    let Some(cfg) = tiny_cfg(4, Some(golden_dir.clone()), false) else { return };
    let mut golden = session(cfg);
    golden.run().unwrap();
    let golden_bytes = final_artifact(&mut golden);

    // Leader with a checkpoint dir; the follower is killed after step 2.
    let ckpt = scratch("kill_ckpt");
    let Some(cfg) = tiny_cfg(2, Some(ckpt.clone()), false) else { return };
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let child = Arc::new(Mutex::new(spawn_follower(&addr)));
    let mut leader = SessionBuilder::from_config(cfg)
        .observer(Box::new(KillFollowerAt(2, child.clone())))
        .build()
        .unwrap();
    let geom = leader.dist_geometry(2);
    let d = {
        let mut ch = child.lock().unwrap();
        accept_one(&listener, &geom, &mut ch)
    };
    leader.attach_dist(d).unwrap();

    let err = leader.run().expect_err("a lost peer must surface as a run error");
    assert!(
        err.downcast_ref::<lgp::dist::PeerLost>().is_some(),
        "expected PeerLost, got: {err:#}"
    );

    // The exchange-before-mutation contract: the leader stopped at a
    // completed update boundary and wrote a valid final checkpoint there.
    // The exact step depends on how far the SIGKILL raced the pipeline,
    // but it is strictly before the full budget.
    let loaded = lgp::checkpoint::load_latest(&ckpt, leader.fingerprint())
        .unwrap()
        .expect("peer loss must leave a final checkpoint behind");
    let stopped_at = loaded.step as usize;
    assert!(
        (2..STEPS).contains(&stopped_at),
        "leader should stop at a mid-run boundary, stopped at {stopped_at}"
    );
    assert_eq!(leader.step_count(), stopped_at);

    // A fresh single-process session resumes the leader's artifact and
    // finishes the budget bit-identically to the uninterrupted run.
    let Some(cfg) = tiny_cfg(4, Some(ckpt.clone()), true) else { return };
    let mut resumed = session(cfg);
    resumed.run().unwrap();
    assert_eq!(resumed.step_count(), STEPS);
    assert_eq!(
        final_artifact(&mut resumed),
        golden_bytes,
        "resume after peer loss must rejoin the golden trajectory bit for bit"
    );

    let _ = std::fs::remove_dir_all(&golden_dir);
    let _ = std::fs::remove_dir_all(&ckpt);
}
