//! ADR-008 resume-bit-identity contract: a run checkpointed at step k and
//! resumed from disk is bit-identical, from step k+1 onward, to a run that
//! was never interrupted.
//!
//! Two layers, mirroring tests/shard_determinism.rs:
//!
//! 1. **Host-model path (always runs).** The estimator-zoo trainer from
//!    the ADR-004/006 suites — real `exec::scatter`, fixed-topology
//!    reduce, host [`Testbed`] — interrupted halfway, checkpointed through
//!    the *real* artifact path (section codecs → container encode →
//!    `write_atomic` → `load_latest` → decode into freshly constructed
//!    objects), then resumed. Final trunk bits and the post-resume loss
//!    trace must equal the uninterrupted run's, for every estimator kind
//!    and every shard count.
//!
//! 2. **Full-session path (artifact-gated).** The same assertion through
//!    `TrainSession::run` with `--checkpoint-every` / `--resume`: a 6-step
//!    run that checkpoints, then a fresh session resuming to step 12,
//!    compared bitwise against an uninterrupted 12-step run. Skips cleanly
//!    on stub builds.
//!
//! Plus recovery-path coverage: a torn artifact under the newest step name
//! must fall back to the previous valid artifact, and (under the
//! `fault-inject` feature) every kill-point in the write protocol must
//! leave the directory resumable.

use lgp::checkpoint::{self, state as ckstate, Dec, Enc};
use lgp::config::{shards_env_override, EstimatorKind};
use lgp::coordinator::{exec, reduce};
use lgp::estimator::testbed::Testbed;
use lgp::estimator::{
    ControlVariate, GradientEstimator, MultiTangentForward, NeuralControlVariate, PredictedLgp,
    TrueBackprop, UpdatePlan,
};
use lgp::model::params::ParamStore;
use lgp::predictor::fit::{fit_with, FitBuffer};
use lgp::predictor::Predictor;
use lgp::tensor::{Backend, Workspace};
use lgp::util::rng::Pcg64;
use std::path::PathBuf;

const SEED: u64 = 11;
const ACC: usize = 4;
const UPDATES: usize = 12;
const HALF: usize = 6;

/// The host harness has no RunConfig; any fixed fingerprint works as long
/// as writer and reader agree (mismatch handling has its own test).
const FP: u64 = 0x00d5_ece8_a5e5_0bed;

fn shard_sweep() -> Vec<usize> {
    let mut counts = vec![1, 2, 4];
    if let Some(s) = shards_env_override().expect("LGP_SHARDS") {
        if !counts.contains(&s) {
            counts.push(s);
        }
    }
    counts
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lgp_resume_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ---------------------------------------------------------------------------
// Layer 1: the estimator-zoo trainer, interruptible at update boundaries
// ---------------------------------------------------------------------------

/// One zoo training rig — everything `run_zoo_host` (shard_determinism)
/// holds as locals, bundled so training can pause at an update boundary,
/// serialize, and resume in a freshly built rig.
struct Rig {
    tb: Testbed,
    est: Box<dyn GradientEstimator>,
    pred: Predictor,
    buf: FitBuffer,
    plan: UpdatePlan,
    consumed: usize,
    stream: Vec<usize>,
    cursor: usize,
    losses: Vec<u64>,
}

/// Deterministic setup, identical for the golden, interrupted, and resumed
/// runs: the stream is precomputed for the full schedule, so an
/// interrupted rig and its resume see the same positional data (ADR-004).
fn build_rig(kind: EstimatorKind) -> Rig {
    let mut tb = Testbed::new(SEED, 128, 12, 6, 4);
    let man = tb.manifest(8, 2);
    let mut est: Box<dyn GradientEstimator> = match kind {
        EstimatorKind::TrueBackprop => Box::new(TrueBackprop),
        EstimatorKind::ControlVariate => Box::new(ControlVariate::new(0.25)),
        EstimatorKind::PredictedLgp => Box::new(PredictedLgp::new(0.25)),
        EstimatorKind::MultiTangent => Box::new(MultiTangentForward::new(4, SEED)),
        EstimatorKind::NeuralCv => {
            Box::new(NeuralControlVariate::new(0.25).with_seed(SEED).with_mlp(6, 60, 0.05))
        }
    };
    est.bind(&man).unwrap();
    let mut pred = Predictor::new(tb.trunk_params(), tb.width, man.rank);
    let mut buf = FitBuffer::new(man.n_fit);
    let mut linear_fits = 0usize;
    if est.uses_predictor() {
        let idxs: Vec<usize> = (0..man.n_fit).map(|i| (i * 5) % tb.n).collect();
        tb.fill_fit_buffer(&mut buf, &idxs);
        if est.owns_predictor_fit() {
            est.fit_own(Backend::blocked(), &buf, 1e-4, &mut Workspace::new()).unwrap();
        } else {
            fit_with(Backend::blocked(), &mut pred, &buf, 1e-4).unwrap();
            linear_fits = 1;
        }
    }
    let plan = est.plan(&man, est.predictor_ready(linear_fits));
    let consumed = plan.consumed_per_slot();
    let mut rng = Pcg64::new(SEED, 0x7373);
    let stream: Vec<usize> =
        (0..UPDATES * ACC * consumed).map(|_| rng.below(tb.n as u64) as usize).collect();
    Rig { tb, est, pred, buf, plan, consumed, stream, cursor: 0, losses: Vec::new() }
}

/// Run `updates` optimizer updates through the real sharded executor,
/// starting wherever the rig's cursor points.
fn advance(rig: &mut Rig, updates: usize, shards: usize) {
    let mut workers: Vec<()> = vec![(); shards];
    let consumed = rig.consumed;
    for _ in 0..updates {
        let base = rig.cursor;
        let outs = {
            let (tbr, predr, streamr, planr) = (&rig.tb, &rig.pred, &rig.stream, &rig.plan);
            let est_ref: &dyn GradientEstimator = &*rig.est;
            exec::scatter(&mut workers, ACC, |_w, slot| {
                tbr.slot_estimate(est_ref, planr, predr, streamr, base + slot * consumed)
            })
            .unwrap()
        };
        let mut loss = 0.0f64;
        let mut leaves = Vec::with_capacity(ACC);
        for (g, l) in outs {
            loss += l as f64;
            leaves.push(g);
        }
        let mut grad = reduce::tree_reduce_grads(leaves).unwrap();
        grad.scale(1.0 / ACC as f32);
        rig.tb.sgd_step(&grad, 0.05);
        rig.losses.push((loss / ACC as f64).to_bits());
        rig.cursor += ACC * consumed;
    }
}

fn rig_params(rig: &Rig) -> ParamStore {
    ParamStore {
        trunk: rig.tb.trunk.clone(),
        head_w: rig.tb.head_w.clone(),
        head_b: rig.tb.head_b.clone(),
        width: rig.tb.width,
        classes: rig.tb.classes,
    }
}

/// Capture the rig's full mutable state through the session section
/// codecs — the same surface `TrainSession::build_checkpoint` uses. META
/// leads with the step count and DATA with (seed, cursor), mirroring the
/// session layout, so `checkpoint::reshard` accepts rig artifacts too.
fn encode_rig(rig: &Rig) -> Vec<u8> {
    let mut ck = checkpoint::Checkpoint::new(FP);
    let mut meta = Enc::new();
    meta.put_u64(rig.losses.len() as u64);
    ck.add(ckstate::META, meta.into_bytes());
    ck.add(ckstate::PARAMS, ckstate::encode_params(&rig_params(rig)));
    ck.add(ckstate::PREDICTOR, ckstate::encode_predictor(&rig.pred));
    ck.add(ckstate::FITBUF, ckstate::encode_fitbuf(&rig.buf));
    ck.add(ckstate::ESTIMATOR, ckstate::encode_estimator(&*rig.est));
    let mut data = Enc::new();
    data.put_u64(SEED);
    data.put_u64(rig.cursor as u64);
    ck.add(ckstate::DATA, data.into_bytes());
    ck.encode()
}

/// Restore a freshly built rig from a decoded artifact — the resumed
/// "process" went through normal construction first, exactly like
/// `SessionBuilder::build` + `resume_latest`.
fn restore_rig(rig: &mut Rig, ck: &checkpoint::Checkpoint) {
    let mut ps = rig_params(rig);
    ckstate::decode_params(&mut ps, ck.section(ckstate::PARAMS).unwrap()).unwrap();
    rig.tb.trunk = ps.trunk;
    rig.tb.head_w = ps.head_w;
    rig.tb.head_b = ps.head_b;
    ckstate::decode_predictor(&mut rig.pred, ck.section(ckstate::PREDICTOR).unwrap()).unwrap();
    ckstate::decode_fitbuf(&mut rig.buf, ck.section(ckstate::FITBUF).unwrap()).unwrap();
    ckstate::decode_estimator(&mut *rig.est, ck.section(ckstate::ESTIMATOR).unwrap()).unwrap();
    let mut data = Dec::new(ck.section(ckstate::DATA).unwrap(), ckstate::DATA);
    assert_eq!(data.take_u64().unwrap(), SEED, "rig artifacts pin the data seed");
    rig.cursor = data.take_u64().unwrap() as usize;
    data.finish().unwrap();
}

#[test]
fn kill_and_resume_is_bit_identical_for_every_estimator() {
    for &kind in EstimatorKind::ALL {
        // The uninterrupted reference: 12 updates straight through.
        let mut golden = build_rig(kind);
        advance(&mut golden, UPDATES, 1);
        assert!(golden.tb.trunk.iter().all(|v| v.is_finite()), "{kind:?}");

        for shards in shard_sweep() {
            let dir = scratch(&format!("zoo_{kind:?}_{shards}"));

            // "Process one": train halfway, checkpoint, die.
            {
                let mut first = build_rig(kind);
                advance(&mut first, HALF, shards);
                assert_eq!(
                    first.losses,
                    golden.losses[..HALF].to_vec(),
                    "{kind:?} shards={shards}: pre-kill trace diverged from golden"
                );
                checkpoint::write_atomic(&dir, &checkpoint::file_name(HALF as u64), &encode_rig(&first))
                    .unwrap();
            }

            // "Process two": fresh construction, restore, finish the run.
            let mut resumed = build_rig(kind);
            let loaded = checkpoint::load_latest(&dir, FP).unwrap().expect("artifact written");
            assert_eq!(loaded.step, HALF as u64);
            restore_rig(&mut resumed, &loaded.ckpt);
            assert_eq!(resumed.cursor, HALF * ACC * resumed.consumed);
            advance(&mut resumed, UPDATES - HALF, shards);

            assert_eq!(
                resumed.tb.trunk, golden.tb.trunk,
                "{kind:?} shards={shards}: resumed trunk differs (bitwise)"
            );
            assert_eq!(resumed.tb.head_w, golden.tb.head_w, "{kind:?} shards={shards}: head_w");
            assert_eq!(resumed.tb.head_b, golden.tb.head_b, "{kind:?} shards={shards}: head_b");
            assert_eq!(
                resumed.losses,
                golden.losses[HALF..].to_vec(),
                "{kind:?} shards={shards}: post-resume loss trace differs"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

/// ADR-010: `checkpoint::reshard` is a validated byte-identity. An
/// N -> M -> N round trip must reproduce the artifact exactly, for every
/// estimator in the zoo, and resuming from the twice-resharded artifact —
/// under a *different* shard count — must rejoin the golden trajectory
/// bit for bit. This is the executable form of the ADR-004/008 claim that
/// checkpoints are shard-neutral.
#[test]
fn reshard_round_trip_is_byte_stable_and_resumes_bit_identically() {
    use lgp::checkpoint::reshard;

    for &kind in EstimatorKind::ALL {
        let mut golden = build_rig(kind);
        advance(&mut golden, UPDATES, 1);

        let dir = scratch(&format!("reshard_{kind:?}"));
        let mut first = build_rig(kind);
        advance(&mut first, HALF, 1);
        let original = encode_rig(&first);
        let input =
            checkpoint::write_atomic(&dir, &checkpoint::file_name(HALF as u64), &original)
                .unwrap();

        let m_dir = dir.join("to_m");
        let n_dir = dir.join("back_to_n");
        let r1 = reshard::reshard_file(&input, &m_dir, 1, 4).unwrap();
        assert_eq!(r1.step, HALF as u64, "{kind:?}");
        assert_eq!(r1.cursor as usize, HALF * ACC * first.consumed, "{kind:?}");
        let r2 = reshard::reshard_file(&r1.path, &n_dir, 4, 1).unwrap();
        assert_eq!(
            std::fs::read(&r2.path).unwrap(),
            original,
            "{kind:?}: N->M->N round trip must be byte-stable"
        );

        let mut resumed = build_rig(kind);
        let loaded = checkpoint::load_latest(&n_dir, FP).unwrap().expect("resharded artifact");
        assert_eq!(loaded.step, HALF as u64);
        restore_rig(&mut resumed, &loaded.ckpt);
        advance(&mut resumed, UPDATES - HALF, 2);
        assert_eq!(
            resumed.tb.trunk, golden.tb.trunk,
            "{kind:?}: resume after reshard differs (bitwise)"
        );
        assert_eq!(resumed.losses, golden.losses[HALF..].to_vec(), "{kind:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn torn_newest_artifact_falls_back_and_resume_stays_bit_identical() {
    let kind = EstimatorKind::ControlVariate;
    let mut golden = build_rig(kind);
    advance(&mut golden, UPDATES, 1);

    let dir = scratch("torn");
    let mut first = build_rig(kind);
    advance(&mut first, HALF, 1);
    let bytes = encode_rig(&first);
    checkpoint::write_atomic(&dir, &checkpoint::file_name(HALF as u64), &bytes).unwrap();
    // A truncated artifact under a newer step name (a crash mode the
    // atomic protocol itself can't produce, but recovery must absorb):
    // load_latest skips it and falls back to the newest *valid* artifact.
    std::fs::write(dir.join(checkpoint::file_name(9)), &bytes[..bytes.len() / 2]).unwrap();

    let loaded = checkpoint::load_latest(&dir, FP).unwrap().expect("fallback artifact");
    assert_eq!(loaded.step, HALF as u64, "must fall back past the torn step-9 artifact");

    let mut resumed = build_rig(kind);
    restore_rig(&mut resumed, &loaded.ckpt);
    advance(&mut resumed, UPDATES - HALF, 1);
    assert_eq!(resumed.tb.trunk, golden.tb.trunk, "resume after fallback must stay bitwise");
    assert_eq!(resumed.losses, golden.losses[HALF..].to_vec());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wrong_fingerprint_is_a_hard_error_not_a_silent_fresh_start() {
    let dir = scratch("fp");
    let mut rig = build_rig(EstimatorKind::TrueBackprop);
    advance(&mut rig, 1, 1);
    checkpoint::write_atomic(&dir, &checkpoint::file_name(1), &encode_rig(&rig)).unwrap();
    let err = checkpoint::load_latest(&dir, FP ^ 0xff).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("incompatible"), "{msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Every kill-point in the write → fsync → rename sequence must leave the
/// checkpoint directory resumable: either the old artifact (kill before
/// rename) or the new one (kill after) loads, and training resumed from
/// it rejoins the golden trajectory bit for bit.
#[cfg(feature = "fault-inject")]
#[test]
fn every_kill_point_leaves_the_directory_resumable() {
    use lgp::checkpoint::fault::{self, Fault, KillPoint};

    let kind = EstimatorKind::PredictedLgp;
    let mut golden = build_rig(kind);
    advance(&mut golden, UPDATES, 1);

    let cases = [
        (Fault::ShortWrite { bytes: 40 }, "short-write"),
        (Fault::Kill(KillPoint::AfterTmpWrite), "after-tmp-write"),
        (Fault::Kill(KillPoint::AfterTmpSync), "after-tmp-sync"),
        (Fault::Kill(KillPoint::AfterRename), "after-rename"),
    ];
    for (fault, tag) in cases {
        let dir = scratch(&format!("kill_{tag}"));

        // A clean artifact at step 3, then a crash while writing step 6.
        let mut first = build_rig(kind);
        advance(&mut first, 3, 1);
        checkpoint::write_atomic(&dir, &checkpoint::file_name(3), &encode_rig(&first)).unwrap();
        advance(&mut first, 3, 1);
        fault::arm(fault);
        let died = checkpoint::write_atomic(&dir, &checkpoint::file_name(6), &encode_rig(&first));
        fault::disarm();
        assert!(died.is_err(), "{tag}: injected crash must surface as an error");

        // The directory must still hold a loadable artifact; which step
        // survived depends on whether the crash hit before the rename.
        let loaded = checkpoint::load_latest(&dir, FP)
            .unwrap()
            .unwrap_or_else(|| panic!("{tag}: no loadable artifact left behind"));
        let expect_step = if matches!(fault, Fault::Kill(KillPoint::AfterRename)) { 6 } else { 3 };
        assert_eq!(loaded.step, expect_step, "{tag}");

        let mut resumed = build_rig(kind);
        restore_rig(&mut resumed, &loaded.ckpt);
        advance(&mut resumed, UPDATES - expect_step as usize, 1);
        assert_eq!(resumed.tb.trunk, golden.tb.trunk, "{tag}: resumed trunk differs (bitwise)");
        assert_eq!(resumed.losses, golden.losses[expect_step as usize..].to_vec(), "{tag}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ---------------------------------------------------------------------------
// Layer 2: the full TrainSession, when artifacts exist
// ---------------------------------------------------------------------------

mod session_level {
    use lgp::config::{Algo, OptimKind, RunConfig};
    use lgp::session::{SessionBuilder, TrainSession};
    use std::path::PathBuf;

    fn tiny_cfg(ckpt_dir: Option<PathBuf>, every: usize, resume: bool) -> Option<RunConfig> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: tiny artifacts not built");
            return None;
        }
        Some(RunConfig {
            artifacts_dir: dir,
            algo: Algo::Gpr,
            f: 0.25,
            accum: 4,
            optimizer: OptimKind::Muon,
            lr: 0.02,
            weight_decay: 0.0,
            budget_secs: 0.0,
            max_steps: 12,
            refit_every: 4, // refits on both sides of the step-6 cut
            ridge_lambda: 1e-4,
            train_size: 600,
            val_size: 150,
            aug_multiplier: 1,
            seed: 7,
            eval_every: 0,
            out_dir: std::env::temp_dir().join("lgp_resume_session_out"),
            track_alignment: true,
            adaptive_f: false,
            backend: lgp::tensor::BackendKind::Blocked,
            shards: lgp::config::shards_env_override().expect("LGP_SHARDS").unwrap_or(1),
            estimator: None,
            tangents: 8,
            checkpoint_dir: ckpt_dir,
            checkpoint_every: every,
            checkpoint_keep: 0,
            resume,
        })
    }

    fn session(cfg: RunConfig) -> TrainSession {
        SessionBuilder::from_config(cfg).build().unwrap()
    }

    #[test]
    fn session_resume_is_bit_identical_to_uninterrupted_run() {
        let Some(golden_cfg) = tiny_cfg(None, 0, false) else { return };
        let mut golden = session(golden_cfg);
        golden.run().unwrap();
        let golden_loss: Vec<u64> = golden.log.iter().map(|r| r.loss.to_bits()).collect();
        assert_eq!(golden_loss.len(), 12);

        let ckpt = super::scratch("session");

        // Interrupted run: dies (max_steps) at step 6, artifact on disk.
        let Some(mut cfg) = tiny_cfg(Some(ckpt.clone()), 3, false) else { return };
        cfg.max_steps = 6;
        let mut first = session(cfg);
        first.run().unwrap();
        assert!(
            ckpt.join(lgp::checkpoint::file_name(6)).exists(),
            "periodic schedule must have written the step-6 artifact"
        );

        // Fresh session, --resume: restores step 6, trains to 12.
        let Some(cfg) = tiny_cfg(Some(ckpt.clone()), 3, true) else { return };
        let mut resumed = session(cfg);
        resumed.run().unwrap();

        assert_eq!(resumed.params.trunk, golden.params.trunk, "resumed trunk differs (bitwise)");
        assert_eq!(resumed.params.head_w, golden.params.head_w, "head_w differs");
        assert_eq!(resumed.params.head_b, golden.params.head_b, "head_b differs");
        // The resumed session's log covers steps 7..=12 only; its loss
        // bits (EMA state restored from the artifact) must equal the
        // golden run's tail. val_acc is patched by the final eval in both
        // runs, so compare loss bits, not whole rows.
        let resumed_loss: Vec<u64> = resumed.log.iter().map(|r| r.loss.to_bits()).collect();
        assert_eq!(resumed_loss, golden_loss[6..].to_vec(), "post-resume loss trace differs");
        assert_eq!(resumed.step_count(), 12);

        let _ = std::fs::remove_dir_all(&ckpt);
    }

    #[test]
    fn resume_with_empty_directory_starts_fresh() {
        let empty = super::scratch("session_empty");
        let Some(cfg) = tiny_cfg(Some(empty.clone()), 0, true) else { return };
        let mut t = session(cfg);
        t.run().unwrap();
        assert_eq!(t.step_count(), 12, "an empty checkpoint dir must not block a fresh run");
        let _ = std::fs::remove_dir_all(&empty);
    }

    #[test]
    fn checkpoint_keep_bounds_directory_growth_without_breaking_resume() {
        let ckpt = super::scratch("session_keep");
        // Checkpoint every 2 steps for 12 steps = 6 artifacts unpruned;
        // keep 2 must leave exactly steps 10 and 12 on disk.
        let Some(mut cfg) = tiny_cfg(Some(ckpt.clone()), 2, false) else { return };
        cfg.checkpoint_keep = 2;
        let mut t = session(cfg);
        t.run().unwrap();
        let mut steps: Vec<u64> = std::fs::read_dir(&ckpt)
            .unwrap()
            .filter_map(|e| lgp::checkpoint::parse_step(e.unwrap().file_name().to_str()?))
            .collect();
        steps.sort_unstable();
        assert_eq!(steps, vec![10, 12], "retention must keep exactly the newest 2 artifacts");

        // The pruned directory still resumes from its newest artifact.
        let Some(mut cfg) = tiny_cfg(Some(ckpt.clone()), 2, true) else { return };
        cfg.max_steps = 14;
        cfg.checkpoint_keep = 2;
        let mut resumed = session(cfg);
        resumed.run().unwrap();
        assert_eq!(resumed.step_count(), 14);
        assert_eq!(resumed.log.len(), 2, "resume must restore step 12 and run 13..=14");
        let _ = std::fs::remove_dir_all(&ckpt);
    }
}
