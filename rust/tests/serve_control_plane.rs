//! End-to-end smoke of the serve control plane (ISSUE 9, DESIGN.md
//! ADR-009): a real `TcpListener` on an ephemeral port, real HTTP/1.1
//! over loopback. The training smoke (submit → stream events → cancel →
//! final checkpoint on disk) is gated on the tiny artifacts like every
//! other session-level test; the hostile-input sweep is not — the HTTP
//! surface must hold up with no artifacts at all.

use lgp::serve::{Registry, Server};
use lgp::util::json::{self, Json};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn spawn_server() -> (SocketAddr, Arc<Registry>) {
    Server::bind("127.0.0.1:0").unwrap().spawn().unwrap()
}

/// One request over a fresh connection; returns the raw close-delimited
/// response (status line, headers, body).
fn request_raw(addr: SocketAddr, raw: &[u8]) -> String {
    let mut c = TcpStream::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    c.write_all(raw).unwrap();
    let mut out = String::new();
    c.read_to_string(&mut out).unwrap();
    out
}

fn status_of(resp: &str) -> u16 {
    resp.split(' ').nth(1).unwrap_or("0").parse().unwrap_or(0)
}

fn body_of(resp: &str) -> String {
    resp.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default()
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    let resp = request_raw(addr, format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes());
    (status_of(&resp), body_of(&resp))
}

fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
    let resp = request_raw(
        addr,
        format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    );
    (status_of(&resp), body_of(&resp))
}

fn tiny_artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: tiny artifacts not built");
        None
    }
}

/// Builds the POST body through the JSON writer so paths stay escaped.
fn config_doc(
    artifacts: &Path,
    ckpt: &Path,
    max_steps: usize,
    budget_secs: f64,
    checkpoint_every: usize,
) -> String {
    json::obj(vec![
        ("artifacts_dir", json::s(&artifacts.display().to_string())),
        ("algo", json::s("gpr")),
        ("optimizer", json::s("muon")),
        ("backend", json::s("blocked")),
        ("f", json::num(0.25)),
        ("accum", json::num(4.0)),
        ("lr", json::num(0.02)),
        ("max_steps", json::num(max_steps as f64)),
        ("budget_secs", json::num(budget_secs)),
        ("refit_every", json::num(4.0)),
        ("train_size", json::num(600.0)),
        ("val_size", json::num(150.0)),
        ("seed", json::num(7.0)),
        ("shards", json::num(1.0)),
        ("checkpoint_dir", json::s(&ckpt.display().to_string())),
        ("checkpoint_every", json::num(checkpoint_every as f64)),
        ("out_dir", json::s(&std::env::temp_dir().join("lgp_serve_out").display().to_string())),
    ])
    .to_string()
}

/// Polls `GET /sessions/:id` until the status matches (or fails fast on
/// an unexpected `failed`).
fn wait_status(addr: SocketAddr, id: u64, want: &str, deadline: Duration) -> Json {
    let t0 = Instant::now();
    loop {
        let (code, body) = get(addr, &format!("/sessions/{id}"));
        assert_eq!(code, 200, "{body}");
        let j = Json::parse(&body).unwrap_or_else(|e| panic!("bad status doc {body}: {e}"));
        let st = j.get("status").and_then(Json::as_str).unwrap_or("?").to_string();
        if st == want {
            return j;
        }
        assert!(st != "failed" || want == "failed", "session failed unexpectedly: {body}");
        assert!(t0.elapsed() < deadline, "timed out waiting for {want:?}, last: {body}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// The verify.sh serve smoke: ephemeral port, submit a tiny session,
/// follow the live JSONL stream, cancel mid-run, and assert the
/// cancelled run left exactly its ADR-008 final checkpoint on disk.
#[test]
fn submit_stream_cancel_and_final_checkpoint_end_to_end() {
    let Some(artifacts) = tiny_artifacts() else { return };
    let (addr, _reg) = spawn_server();
    let deadline = Duration::from_secs(300);

    let (code, body) = get(addr, "/healthz");
    assert_eq!(code, 200, "{body}");

    // --- short run to completion ---------------------------------------
    let ckpt_done = std::env::temp_dir().join("lgp_serve_ckpt_done");
    let _ = std::fs::remove_dir_all(&ckpt_done);
    let (code, body) = post(addr, "/sessions", &config_doc(&artifacts, &ckpt_done, 5, 0.0, 2));
    assert_eq!(code, 201, "{body}");
    let id = Json::parse(&body).unwrap().get("id").and_then(Json::as_u64).expect(&body);
    let done = wait_status(addr, id, "done", deadline);
    assert_eq!(done.get("steps").and_then(Json::as_usize), Some(5), "{body}");

    // Finished sessions replay their retained stream and terminate.
    let (code, stream) = get(addr, &format!("/sessions/{id}/events"));
    assert_eq!(code, 200);
    assert!(stream.contains(r#""event":"step""#), "{stream}");
    assert!(stream.contains(r#""event":"checkpoint""#), "{stream}");
    assert!(stream.contains(r#""event":"end""#), "{stream}");

    // The list endpoint sees it too.
    let (code, list) = get(addr, "/sessions");
    assert_eq!(code, 200);
    assert!(Json::parse(&list).unwrap().as_arr().unwrap().len() >= 1, "{list}");

    // --- cancel mid-run --------------------------------------------------
    let ckpt_cancel = std::env::temp_dir().join("lgp_serve_ckpt_cancel");
    let _ = std::fs::remove_dir_all(&ckpt_cancel);
    // Long budget, no periodic checkpoints: only a graceful stop writes.
    let (code, body) =
        post(addr, "/sessions", &config_doc(&artifacts, &ckpt_cancel, 200_000, 600.0, 0));
    assert_eq!(code, 201, "{body}");
    let id2 = Json::parse(&body).unwrap().get("id").and_then(Json::as_u64).expect(&body);

    // Attach to the live chunked stream and wait for the first step.
    let mut es = TcpStream::connect(addr).unwrap();
    es.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
    es.write_all(format!("GET /sessions/{id2}/events HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes())
        .unwrap();
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let t0 = Instant::now();
    while !String::from_utf8_lossy(&buf).contains(r#""event":"step""#) {
        assert!(
            t0.elapsed() < deadline,
            "no step event on the live stream: {}",
            String::from_utf8_lossy(&buf)
        );
        match es.read(&mut tmp) {
            Ok(0) => panic!("stream ended early: {}", String::from_utf8_lossy(&buf)),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => panic!("stream read error: {e}"),
        }
    }

    // Cancel over a second connection; the token routes through the same
    // graceful path as SIGINT.
    let (code, body) = post(addr, &format!("/sessions/{id2}/cancel"), "");
    assert_eq!(code, 202, "{body}");

    // The stream must now drain: final checkpoint event, end event, EOF.
    loop {
        assert!(t0.elapsed() < deadline, "stream did not close after cancel");
        match es.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => panic!("stream read error after cancel: {e}"),
        }
    }
    let text = String::from_utf8_lossy(&buf);
    assert!(text.contains(r#""event":"checkpoint""#), "cancel must checkpoint: {text}");
    assert!(text.contains(r#""event":"end""#), "{text}");

    let st = wait_status(addr, id2, "cancelled", deadline);
    let steps = st.get("steps").and_then(Json::as_usize).expect("cancelled status has steps");
    assert!(steps >= 1, "{st:?}");

    // Exactly one artifact — the off-schedule final checkpoint at the
    // cancelled step — and it decodes.
    let mut found: Vec<u64> = std::fs::read_dir(&ckpt_cancel)
        .unwrap()
        .filter_map(|e| {
            lgp::checkpoint::parse_step(&e.unwrap().file_name().to_string_lossy())
        })
        .collect();
    found.sort_unstable();
    assert_eq!(found, vec![steps as u64], "only the graceful-stop artifact should exist");

    let _ = std::fs::remove_dir_all(&ckpt_done);
    let _ = std::fs::remove_dir_all(&ckpt_cancel);
}

/// The adversarial sweep from the HTTP side: every hostile request gets
/// a structured error and the server keeps serving. Runs without
/// artifacts — nothing here ever reaches a training thread.
#[test]
fn hostile_requests_get_structured_errors_and_the_server_survives() {
    let (addr, _reg) = spawn_server();

    // Bad JSON → 400 naming the byte offset.
    let (code, body) = post(addr, "/sessions", "{\"algo\": ");
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("byte"), "{body}");

    // Unknown / lossy config fields → 400 naming the field.
    let (code, body) = post(addr, "/sessions", r#"{"stepz": 5}"#);
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("stepz"), "{body}");
    let (code, body) = post(addr, "/sessions", r#"{"shards": -1}"#);
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("shards"), "{body}");

    // Depth bomb → 400, not a stack overflow.
    let (code, _) = post(addr, "/sessions", &"[".repeat(50_000));
    assert_eq!(code, 400);

    // Declared-oversized body → 413 before any buffering.
    let resp = request_raw(
        addr,
        format!("POST /sessions HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 8 * 1024 * 1024)
            .as_bytes(),
    );
    assert_eq!(status_of(&resp), 413, "{resp}");

    // Oversized request head → 431 with the read bounded.
    let resp = request_raw(
        addr,
        format!("GET /{} HTTP/1.1\r\nHost: t\r\n\r\n", "a".repeat(64 * 1024)).as_bytes(),
    );
    assert_eq!(status_of(&resp), 431, "{resp}");

    // Unknown routes, ids, and methods → 404.
    assert_eq!(get(addr, "/nope").0, 404);
    assert_eq!(get(addr, "/sessions/999").0, 404);
    assert_eq!(get(addr, "/sessions/notanid").0, 404);
    assert_eq!(post(addr, "/healthz", "").0, 404);

    // Raw garbage (no parseable request line) → structured 400, and the
    // server is still alive.
    let resp = request_raw(addr, b"\x01\x02garbage\r\n\r\n");
    assert_eq!(status_of(&resp), 400, "{resp}");
    let (code, body) = get(addr, "/healthz");
    assert_eq!(code, 200, "server must survive the corpus: {body}");
}

/// A config that parses and applies but cannot build (missing artifacts
/// dir) is accepted at POST time and surfaces asynchronously as status
/// `failed` with the build error — the HTTP surface never blocks on
/// artifact loading.
#[test]
fn build_failures_surface_as_failed_status_not_hung_requests() {
    let (addr, _reg) = spawn_server();
    let missing = std::env::temp_dir().join("lgp_serve_no_such_artifacts");
    let ckpt = std::env::temp_dir().join("lgp_serve_failed_ckpt");
    let (code, body) = post(addr, "/sessions", &config_doc(&missing, &ckpt, 3, 0.0, 0));
    assert_eq!(code, 201, "{body}");
    let id = Json::parse(&body).unwrap().get("id").and_then(Json::as_u64).unwrap();
    let st = wait_status(addr, id, "failed", Duration::from_secs(60));
    let err = st.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(!err.is_empty(), "failed status must carry the build error: {st:?}");
    // The failure is also the stream's terminal event.
    let (code, stream) = get(addr, &format!("/sessions/{id}/events"));
    assert_eq!(code, 200);
    assert!(stream.contains(r#""event":"error""#), "{stream}");
}
