//! Property-based tests (hand-rolled generator harness — no proptest crate
//! in the offline set). Each property runs CASES randomized trials from a
//! seeded PCG64; failures print the violating seed for reproduction.

use lgp::estimator::combine::{cv_combine, split_indices};
use lgp::estimator::forward::multi_tangent_project;
use lgp::coordinator::{exec, reduce};
use lgp::data::loader::DataPipeline;
use lgp::model::params::FlatGrad;
use lgp::tensor::{linalg, matmul, stats, Tensor};
use lgp::theory::{self, CostModel};
use lgp::util::rng::Pcg64;

const CASES: u64 = 60;

fn rand_grad(rng: &mut Pcg64, n: usize) -> FlatGrad {
    let mut g = FlatGrad {
        trunk: vec![0.0; n],
        head_w: vec![0.0; 4],
        head_b: vec![0.0; 2],
    };
    rng.fill_normal(&mut g.trunk, 1.0);
    rng.fill_normal(&mut g.head_w, 1.0);
    rng.fill_normal(&mut g.head_b, 1.0);
    g
}

/// Property: the combine is *exactly* linear — combining equals combining
/// componentwise, and f=1 gives g_ct regardless of the predictions.
#[test]
fn prop_cv_combine_linear_identities() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 100);
        let n = 1 + rng.below(64) as usize;
        let f = rng.range_f32(0.05, 1.0);
        let ct = rand_grad(&mut rng, n);
        let cp = rand_grad(&mut rng, n);
        let p = rand_grad(&mut rng, n);
        let g = cv_combine(&ct, &cp, &p, f);
        for i in 0..n {
            let want = f * ct.trunk[i] + (1.0 - f) * (p.trunk[i] - (cp.trunk[i] - ct.trunk[i]));
            assert!((g.trunk[i] - want).abs() < 1e-5, "seed {seed}");
        }
        let g1 = cv_combine(&ct, &cp, &p, 1.0);
        assert_eq!(g1.trunk, ct.trunk, "seed {seed}");
        // perfect predictor on control: correction vanishes
        let gp = cv_combine(&ct, &ct, &p, f);
        for i in 0..n {
            let want = f * ct.trunk[i] + (1.0 - f) * p.trunk[i];
            assert!((gp.trunk[i] - want).abs() < 1e-5, "seed {seed}");
        }
    }
}

/// Property (ADR-006): when the predictor's output on the control part is
/// bitwise identical to its output on the prediction part, eq. (1)'s
/// correction `(1−f)(g_p − g_cp)` is exactly ±0.0 and the combine returns
/// the control gradient bit-for-bit — for every f, including f = 0, where
/// the estimate is carried *entirely* by the correction term.
#[test]
fn prop_cv_combine_identical_predictions_is_bitwise_identity() {
    let bits_eq = |a: &[f32], b: &[f32]| a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 108);
        let n = 1 + rng.below(64) as usize;
        let ct = rand_grad(&mut rng, n);
        let p = rand_grad(&mut rng, n);
        for f in [0.0f32, 0.25, 0.6, 1.0] {
            let g = cv_combine(&ct, &p, &p, f);
            assert!(bits_eq(&g.trunk, &ct.trunk), "seed {seed} f={f}");
            assert!(bits_eq(&g.head_w, &ct.head_w), "seed {seed} f={f}");
            assert!(bits_eq(&g.head_b, &ct.head_b), "seed {seed} f={f}");
        }
        // Contrast: distinct predictions at f < 1 must move the estimate.
        let cp = rand_grad(&mut rng, n);
        let g = cv_combine(&ct, &cp, &p, 0.25);
        assert!(!bits_eq(&g.trunk, &ct.trunk), "seed {seed}");
    }
}

/// Property (ADR-006): the multi-tangent forward estimate is invariant to
/// the *order* of its tangent seeds — `multi_tangent_project` sorts them
/// before accumulating, so any permutation produces a bitwise-identical
/// projection. This is what makes the estimator shard-invariant: shard
/// scheduling can never reorder a slot's tangents.
#[test]
fn prop_multi_tangent_projection_permutation_invariant() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 109);
        let n = 1 + rng.below(48) as usize;
        let k = 1 + rng.below(12) as usize;
        let g0 = rand_grad(&mut rng, n);
        let seeds: Vec<u64> = (0..k).map(|_| rng.next_u64()).collect();
        let mut shuffled = seeds.clone();
        for i in (1..shuffled.len()).rev() {
            let j = rng.below((i + 1) as u64) as usize;
            shuffled.swap(i, j);
        }
        let mut a = g0.clone();
        multi_tangent_project(&mut a, &seeds);
        let mut b = g0.clone();
        multi_tangent_project(&mut b, &shuffled);
        for (x, y) in a.trunk.iter().zip(&b.trunk) {
            assert_eq!(x.to_bits(), y.to_bits(), "seed {seed}");
        }
        for (x, y) in a.head_w.iter().zip(&b.head_w) {
            assert_eq!(x.to_bits(), y.to_bits(), "seed {seed}");
        }
        for (x, y) in a.head_b.iter().zip(&b.head_b) {
            assert_eq!(x.to_bits(), y.to_bits(), "seed {seed}");
        }
        // The projection is an estimate, not the identity.
        assert_ne!(a.trunk, g0.trunk, "seed {seed}");
    }
}

/// Property (Lemma 1): over a random population with an arbitrarily biased
/// predictor, the *expected* combined gradient equals the population mean
/// of the true gradient. Monte-Carlo over micro-batch draws.
#[test]
fn prop_cv_estimator_unbiased() {
    for seed in 0..6 {
        let mut rng = Pcg64::new(seed, 101);
        let dim = 24;
        let pop = 48usize;
        // population of (g, h) with a deliberate bias in h
        let mut gs = Vec::new();
        let mut hs = Vec::new();
        for _ in 0..pop {
            let mut g = vec![0.0f32; dim];
            let mut b = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let h: Vec<f32> = g.iter().zip(&b).map(|(gv, bv)| 0.5 * gv + bv + 2.0).collect();
            gs.push(g);
            hs.push(h);
        }
        let mu: Vec<f64> = (0..dim)
            .map(|i| gs.iter().map(|g| g[i] as f64).sum::<f64>() / pop as f64)
            .collect();
        // estimator: sample mc control + mp prediction examples i.i.d.
        let (m, f) = (8usize, 0.25f32);
        let mc = 2usize;
        let mp = m - mc;
        let trials = 20_000;
        let mut est_mean = vec![0.0f64; dim];
        for _ in 0..trials {
            let mut gct = vec![0.0f32; dim];
            let mut gcp = vec![0.0f32; dim];
            let mut gp = vec![0.0f32; dim];
            for _ in 0..mc {
                let j = rng.below(pop as u64) as usize;
                for i in 0..dim {
                    gct[i] += gs[j][i] / mc as f32;
                    gcp[i] += hs[j][i] / mc as f32;
                }
            }
            for _ in 0..mp {
                let j = rng.below(pop as u64) as usize;
                for i in 0..dim {
                    gp[i] += hs[j][i] / mp as f32;
                }
            }
            for i in 0..dim {
                let g = f * gct[i] + (1.0 - f) * (gp[i] - (gcp[i] - gct[i]));
                est_mean[i] += g as f64 / trials as f64;
            }
        }
        // estimator mean ~= population mean despite the biased predictor
        for i in 0..dim {
            assert!(
                (est_mean[i] - mu[i]).abs() < 0.08,
                "seed {seed} dim {i}: {} vs {}",
                est_mean[i],
                mu[i]
            );
        }
    }
}

/// Property (ADR-004): the fixed-topology tree reduction over leaves
/// computed through the sharded executor equals the serial left-fold sum
/// *exactly* (bitwise), for arbitrary shard counts, leaf counts and
/// gradient lengths. The leaf is a pure function of its slot, so the only
/// way shard count could leak into the result is through reduction order
/// — which the fixed topology forbids.
#[test]
fn prop_tree_reduction_equals_serial_left_fold() {
    for seed in 0..24 {
        let mut rng = Pcg64::new(seed, 300);
        let slots = 1 + rng.below(12) as usize;
        let n = 1 + rng.below(80) as usize;
        let leaf_of = |slot: usize| {
            let mut r = Pcg64::new(seed ^ 0xABCD, 400 + slot as u64);
            let mut g = FlatGrad {
                trunk: vec![0.0; n],
                head_w: vec![0.0; 4],
                head_b: vec![0.0; 2],
            };
            r.fill_normal(&mut g.trunk, 1.0);
            r.fill_normal(&mut g.head_w, 1.0);
            r.fill_normal(&mut g.head_b, 1.0);
            g
        };
        // Serial reference: plain left fold, no executor involved.
        let mut want = leaf_of(0);
        for s in 1..slots {
            want.axpy(1.0, &leaf_of(s));
        }
        for shards in [1usize, 2, 3, 4, 7] {
            let mut workers = vec![(); shards];
            let leaves =
                exec::scatter(&mut workers, slots, |_w, slot| Ok(leaf_of(slot))).unwrap();
            let got = reduce::tree_reduce_grads(leaves).unwrap();
            assert_eq!(got.trunk, want.trunk, "seed {seed} shards {shards}");
            assert_eq!(got.head_w, want.head_w, "seed {seed} shards {shards}");
            assert_eq!(got.head_b, want.head_b, "seed {seed} shards {shards}");
        }
        // The raw-slice form agrees with the FlatGrad form bitwise.
        let leaves: Vec<FlatGrad> = (0..slots).map(leaf_of).collect();
        let refs: Vec<&[f32]> = leaves.iter().map(|l| l.trunk.as_slice()).collect();
        let mut out = vec![f32::NAN; n];
        reduce::tree_reduce_into(&mut out, &refs);
        assert_eq!(out, want.trunk, "seed {seed}");
    }
}

/// Property (ADR-004): the round-robin slot assignment induces per-shard
/// stream position ranges that are disjoint and exhaustive over one
/// update's consumption window, for every (slots, per-slot size, shard
/// count, base offset).
#[test]
fn prop_shard_position_ranges_partition_the_stream() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 301);
        let slots = 1 + rng.below(16) as usize;
        let m = 1 + rng.below(24) as usize;
        let shards = 1 + rng.below(6) as usize;
        let base = rng.below(10_000) as usize;
        let nw = exec::effective_workers(shards, slots);
        let mut covered = vec![0usize; slots * m];
        for slot in 0..slots {
            let w = exec::worker_of_slot(slot, nw);
            assert!(w < nw, "seed {seed}");
            for p in base + slot * m..base + (slot + 1) * m {
                covered[p - base] += 1;
            }
        }
        // Disjoint + exhaustive: every position in the window exactly once.
        assert!(covered.iter().all(|&c| c == 1), "seed {seed}: {covered:?}");
    }
}

/// Property (ADR-004): the sharded `DataPipeline` reshuffles identically
/// per epoch regardless of shard count — every view, however many exist
/// and in whatever order they read, serves the serial stream's index at
/// every position, and each epoch's index set is a full permutation.
#[test]
fn prop_sharded_pipeline_reshuffles_identically_per_epoch() {
    for seed in 0..12 {
        let mut rng = Pcg64::new(seed, 302);
        let n = 8 + rng.below(40) as usize;
        let epochs = 3usize;
        let mut p = DataPipeline::build(n.max(16), 8, 8, 4, 1, seed);
        let n = p.train.len();
        let serial: Vec<usize> = p.next_indices(epochs * n);
        // Each epoch is a permutation of 0..n.
        for e in 0..epochs {
            let mut idx: Vec<usize> = serial[e * n..(e + 1) * n].to_vec();
            idx.sort_unstable();
            assert_eq!(idx, (0..n).collect::<Vec<_>>(), "seed {seed} epoch {e}");
        }
        // Consecutive epochs actually reshuffle (astronomically unlikely
        // to collide for n >= 16).
        assert_ne!(serial[..n], serial[n..2 * n], "seed {seed}");
        for shards in [1usize, 2, 5] {
            let mut views: Vec<_> = (0..shards).map(|_| p.make_view()).collect();
            let m = 1 + (seed as usize % 7);
            for pos in 0..epochs * n {
                // The owner shard of this position's slot reads it.
                let slot = pos / m;
                let v = &mut views[exec::worker_of_slot(slot, shards)];
                assert_eq!(
                    v.index_at(pos),
                    serial[pos],
                    "seed {seed} shards {shards} pos {pos}"
                );
            }
        }
    }
}

/// Property: split_indices partitions its input for every (m, f).
#[test]
fn prop_split_partitions() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 102);
        let m = 1 + rng.below(256) as usize;
        let f = rng.next_f64().max(1e-3);
        let idx: Vec<usize> = (0..m).map(|_| rng.below(10_000) as usize).collect();
        let (c, p) = split_indices(&idx, f);
        assert!(!c.is_empty(), "seed {seed}");
        assert_eq!(c.len() + p.len(), m, "seed {seed}");
        let mut joined = c.clone();
        joined.extend(&p);
        assert_eq!(joined, idx, "seed {seed}");
    }
}

/// Property (Prop. 2 / Thm 3 consistency): φ(f*, ρ, κ)·γ(f*) ≤ φ(f, ρ, κ)·γ(f)
/// on a dense grid, and φ(f, ρ*, κ)·γ(f) = 1 exactly.
#[test]
fn prop_theory_consistency() {
    let cost = CostModel::default();
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 103);
        let rho = rng.range_f32(-0.5, 0.999) as f64;
        let kappa = rng.range_f32(0.3, 2.0) as f64;
        let fstar = theory::f_star(rho, kappa, &cost);
        assert!(fstar > 0.0 && fstar <= 1.0, "seed {seed}");
        let qstar = theory::q_objective(fstar, rho, kappa, &cost);
        for i in 1..=100 {
            let f = i as f64 / 100.0;
            assert!(
                qstar <= theory::q_objective(f, rho, kappa, &cost) + 1e-9,
                "seed {seed} f={f}"
            );
        }
        for &f in &[0.1, 0.25, 0.5, 0.9] {
            let rs = theory::rho_star(f, kappa, &cost);
            if rs <= 1.0 {
                let q = theory::q_objective(f, rs, kappa, &cost);
                assert!((q - 1.0).abs() < 1e-9, "seed {seed} f={f}");
            }
        }
    }
}

/// Property: Jacobi eigh reconstructs random PSD matrices and the
/// eigenvalues are non-negative, for many sizes/seeds.
#[test]
fn prop_eigh_reconstruction() {
    for seed in 0..20 {
        let mut rng = Pcg64::new(seed, 104);
        let n = 2 + rng.below(24) as usize;
        let cols = n + rng.below(8) as usize;
        let mut a = Tensor::zeros(&[n, cols]);
        rng.fill_normal(&mut a.data, 1.0);
        let sym = matmul::gram(&a);
        let (w, v) = linalg::eigh_jacobi(&sym);
        let mut vd = v.clone();
        for i in 0..n {
            for j in 0..n {
                vd.data[i * n + j] *= w[j];
            }
        }
        let rec = matmul::matmul(&vd, &v.t());
        let scale = 1.0 + sym.frob_norm();
        for (x, y) in rec.data.iter().zip(&sym.data) {
            assert!((x - y).abs() < 5e-3 * scale, "seed {seed}: {x} vs {y}");
        }
        assert!(w.iter().all(|&e| e > -1e-3 * scale), "seed {seed}");
    }
}

/// Property: cosine is invariant to positive scaling and flips sign under
/// negation (the Sec. 5.3 monitoring metric's defining behaviour).
#[test]
fn prop_cosine_scale_invariance() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 105);
        let n = 2 + rng.below(100) as usize;
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let c0 = stats::cosine(&a, &b);
        let s = rng.range_f32(0.1, 100.0);
        let a_scaled: Vec<f32> = a.iter().map(|v| v * s).collect();
        assert!((stats::cosine(&a_scaled, &b) - c0).abs() < 1e-4, "seed {seed}");
        let a_neg: Vec<f32> = a.iter().map(|v| -v).collect();
        assert!((stats::cosine(&a_neg, &b) + c0).abs() < 1e-4, "seed {seed}");
        assert!((-1.0001..=1.0001).contains(&c0), "seed {seed}");
    }
}

/// Property: Monte-Carlo variance of the debiased estimator tracks the
/// closed-form φ across random (f, ρ, κ) — Proposition 2 end-to-end.
#[test]
fn prop_variance_matches_phi() {
    for seed in 0..4 {
        let mut rng = Pcg64::new(seed, 106);
        let f = [0.125, 0.25, 0.5][rng.below(3) as usize];
        let rho = rng.range_f32(0.3, 0.95) as f64;
        let kappa = rng.range_f32(0.7, 1.4) as f64;
        let mc = theory::monte_carlo_phi(24, 16, f, rho, kappa, 1200, seed * 7 + 1);
        let rel = (mc.phi_empirical - mc.phi_closed_form).abs() / mc.phi_closed_form;
        assert!(
            rel < 0.2,
            "seed {seed}: f={f} rho={rho:.2} kappa={kappa:.2}: {} vs {}",
            mc.phi_empirical,
            mc.phi_closed_form
        );
    }
}

/// Property: Newton–Schulz output is close in direction to the input's
/// polar factor for random matrices (Muon correctness envelope).
#[test]
fn prop_newton_schulz_direction() {
    for seed in 0..20 {
        let mut rng = Pcg64::new(seed, 107);
        let m = 2 + rng.below(12) as usize;
        let n = 2 + rng.below(12) as usize;
        let mut g = Tensor::zeros(&[m, n]);
        rng.fill_normal(&mut g.data, 1.0);
        let o = linalg::newton_schulz(&g, 5);
        // NS never changes the sign of <G, O>: the update stays descent-
        // aligned with the raw gradient.
        let align = stats::cosine(&g.data, &o.data);
        assert!(align > 0.0, "seed {seed}: align {align}");
        // bounded entries (singular values in the NS band)
        let fro = o.frob_norm();
        let max_fro = (m.min(n) as f32).sqrt() * 1.6;
        assert!(fro <= max_fro, "seed {seed}: {fro} > {max_fro}");
    }
}
