//! Property tests: every tensor backend agrees with the naive reference
//! kernels to 1e-4 relative tolerance across rectangular and degenerate
//! shapes (hand-rolled generator harness, same style as `proptests.rs` —
//! no proptest crate in the offline set) — including the workspace
//! (`*_into_ws`) entry points on dirty outputs with a shared arena — the
//! calibration probe picks a valid backend, the bench JSON pipeline
//! (kernel suite -> schema validation, the path `bench-report` exercises)
//! works in fast mode, and the perf-regression compare gate validates the
//! committed kernel trajectory when present.

use lgp::bench_support::json_out::{bench_doc, bench_out_dir, BenchRecord};
use lgp::bench_support::{compare, kernels, schema, Summary};
use lgp::predictor::fit::{fit_with, FitBuffer};
use lgp::predictor::Predictor;
use lgp::tensor::{backend, linalg, simd, Backend, BackendKind, Tensor, Workspace};
use lgp::util::json::Json;
use lgp::util::rng::Pcg64;

const CASES: u64 = 40;

fn rand_t(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(&mut t.data, 1.0);
    t
}

/// |x - y| <= tol * (1 + |y|): relative with an absolute floor so
/// near-zero entries do not blow up the ratio.
fn assert_rel_close(got: &Tensor, want: &Tensor, tol: f32, what: &str) {
    assert_eq!(got.shape, want.shape, "{what}: shape mismatch");
    for (i, (x, y)) in got.data.iter().zip(&want.data).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

/// The shape grid every property sweeps: square, rectangular, degenerate
/// (0-dim, 1×n, n×1) and non-multiples of the register (4) and j-tile
/// (256/512) sizes.
const MATMUL_SHAPES: &[(usize, usize, usize)] = &[
    (0, 3, 2),
    (3, 0, 2),
    (3, 2, 0),
    (1, 1, 1),
    (1, 17, 1),
    (1, 5, 9),
    (9, 5, 1),
    (4, 4, 4),
    (5, 7, 3),
    (17, 33, 9),
    (31, 2, 63),
    (33, 47, 65),
    (64, 64, 64),
    (10, 300, 7),
];

#[test]
fn prop_matmul_all_backends_match_reference() {
    let oracle = Backend::naive();
    // One arena across every seed/backend/shape: the workspace kernels
    // must be correct with recycled (dirty) scratch, not just fresh.
    let mut ws = Workspace::new();
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 200);
        let &(m, k, n) = &MATMUL_SHAPES[(seed as usize) % MATMUL_SHAPES.len()];
        let a = rand_t(&mut rng, &[m, k]);
        let b = rand_t(&mut rng, &[k, n]);
        let want = oracle.matmul(&a, &b);
        for be in Backend::all() {
            let got = be.matmul(&a, &b);
            assert_rel_close(&got, &want, 1e-4, &format!("seed {seed} matmul {}", be.name()));
            // matmul_into with a reused (dirty) output must agree too.
            let mut c = Tensor::filled(&[m, n], f32::NAN);
            be.matmul_into(&a, &b, &mut c);
            assert_rel_close(&c, &want, 1e-4, &format!("seed {seed} matmul_into {}", be.name()));
            // ...and the workspace entry point with shared scratch.
            let mut c2 = Tensor::filled(&[m, n], f32::NAN);
            be.matmul_into_ws(&a, &b, &mut c2, &mut ws);
            assert_rel_close(
                &c2,
                &want,
                1e-4,
                &format!("seed {seed} matmul_into_ws {}", be.name()),
            );
        }
    }
}

#[test]
fn prop_gram_all_backends_match_reference() {
    let shapes: &[(usize, usize)] = &[
        (0, 4),
        (4, 0),
        (1, 1),
        (1, 13),
        (13, 1),
        (2, 9),
        (9, 2),
        (15, 15),
        (33, 17),
        (64, 48),
    ];
    let oracle = Backend::naive();
    let mut ws = Workspace::new();
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 201);
        let &(n, d) = &shapes[(seed as usize) % shapes.len()];
        let a = rand_t(&mut rng, &[n, d]);
        let want_t = oracle.gram_t(&a);
        let want = oracle.gram(&a);
        for be in Backend::all() {
            assert_rel_close(
                &be.gram_t(&a),
                &want_t,
                1e-4,
                &format!("seed {seed} gram_t {}", be.name()),
            );
            assert_rel_close(
                &be.gram(&a),
                &want,
                1e-4,
                &format!("seed {seed} gram {}", be.name()),
            );
            // Workspace forms on dirty outputs: every stale cell must be
            // overwritten on degenerate and non-tile-multiple shapes too.
            let mut ct = Tensor::filled(&[d, d], f32::NAN);
            be.gram_t_into_ws(&a, &mut ct, &mut ws);
            assert_rel_close(
                &ct,
                &want_t,
                1e-4,
                &format!("seed {seed} gram_t_into_ws {}", be.name()),
            );
            let mut cg = Tensor::filled(&[n, n], f32::NAN);
            be.gram_into_ws(&a, &mut cg, &mut ws);
            assert_rel_close(
                &cg,
                &want,
                1e-4,
                &format!("seed {seed} gram_into_ws {}", be.name()),
            );
        }
    }
}

#[test]
fn prop_dot_matches_f64_reference() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 202);
        let len = (rng.below(700)) as usize; // includes 0 and odd tails
        let mut a = vec![0.0f32; len];
        let mut b = vec![0.0f32; len];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let want: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
        for be in Backend::all() {
            let got = be.dot(&a, &b) as f64;
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()) * (1.0 + (len as f64).sqrt()),
                "seed {seed} {} len {len}: {got} vs {want}",
                be.name()
            );
        }
    }
}

#[test]
fn newton_schulz_agrees_across_backends() {
    let mut rng = Pcg64::seeded(303);
    for &(m, n) in &[(6usize, 6usize), (5, 11), (11, 5)] {
        let g = rand_t(&mut rng, &[m, n]);
        let want = linalg::newton_schulz_with(Backend::naive(), &g, 5);
        // Every non-reference backend, simd included when the host has it.
        for be in Backend::all().into_iter().filter(|b| b.name() != "naive") {
            let got = linalg::newton_schulz_with(be, &g, 5);
            // five matmul-squaring rounds amplify f32 noise; the contract
            // is agreement well inside Muon's update scale.
            assert_rel_close(&got, &want, 1e-3, be.name());
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD backend: ULP-level agreement and banding bitwise-identity (ADR-007)
// ---------------------------------------------------------------------------

/// Monotonic integer key for f32 ordering: adjacent representable floats
/// differ by 1, and +0.0/-0.0 both map to 0.
fn ulp_key(f: f32) -> i64 {
    let b = f.to_bits() as i32 as i64;
    if b < 0 {
        (i32::MIN as i64) - b
    } else {
        b
    }
}

/// ULPs between two finite floats; `u32::MAX` when either is NaN.
fn ulp_diff(x: f32, y: f32) -> u32 {
    if x == y {
        return 0;
    }
    if x.is_nan() || y.is_nan() {
        return u32::MAX;
    }
    (ulp_key(x) - ulp_key(y)).unsigned_abs().min(u32::MAX as u64) as u32
}

/// SIMD tolerance: the AVX2 kernels reassociate sums (8-lane FMA trees
/// vs the scalar backends' serial accumulation), so exact equality is
/// not the contract — agreement to a few hundred ULPs *or* 1e-4
/// relative is, and in practice the observed gap is far smaller.
fn assert_ulp_close(got: &Tensor, want: &Tensor, what: &str) {
    assert_eq!(got.shape, want.shape, "{what}: shape mismatch");
    for (i, (x, y)) in got.data.iter().zip(&want.data).enumerate() {
        let ok = ulp_diff(*x, *y) <= 256 || (x - y).abs() <= 1e-4 * (1.0 + y.abs());
        assert!(ok, "{what}[{i}]: {x} vs {y} ({} ulps)", ulp_diff(*x, *y));
    }
}

/// The simd backend against micro and naive on every kernel form the hot
/// paths use, dirty workspace outputs included. Skips (passes) cleanly on
/// hosts without AVX2+FMA — `Backend::simd()` would silently hand back
/// micro there, which would make this test vacuous, not wrong, but the
/// explicit skip keeps the log honest.
#[test]
fn prop_simd_matches_scalar_backends_within_ulps() {
    if !simd::simd_available() {
        eprintln!("simd ULP suite: skipped — host lacks avx2+fma (features: {})", simd::cpu_features());
        return;
    }
    let sd = Backend::simd();
    assert_eq!(sd.name(), "simd");
    let mut ws = Workspace::new();
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 210);
        let &(m, k, n) = &MATMUL_SHAPES[(seed as usize) % MATMUL_SHAPES.len()];
        let a = rand_t(&mut rng, &[m, k]);
        let b = rand_t(&mut rng, &[k, n]);
        for oracle in [Backend::micro(), Backend::naive()] {
            let want = oracle.matmul(&a, &b);
            let what = format!("seed {seed} simd-vs-{}", oracle.name());
            assert_ulp_close(&sd.matmul(&a, &b), &want, &format!("{what} matmul"));
            let mut c = Tensor::filled(&[m, n], f32::NAN);
            sd.matmul_into_ws(&a, &b, &mut c, &mut ws);
            assert_ulp_close(&c, &want, &format!("{what} matmul_into_ws"));
        }
        // gram_t / gram on the a operand reshaped as (rows, d).
        let (rows, d) = (m.max(1), k.max(1));
        let g = rand_t(&mut rng, &[rows, d]);
        for oracle in [Backend::micro(), Backend::naive()] {
            let what = format!("seed {seed} simd-vs-{}", oracle.name());
            assert_ulp_close(&sd.gram_t(&g), &oracle.gram_t(&g), &format!("{what} gram_t"));
            let mut ct = Tensor::filled(&[d, d], f32::NAN);
            sd.gram_t_into_ws(&g, &mut ct, &mut ws);
            assert_ulp_close(&ct, &oracle.gram_t(&g), &format!("{what} gram_t_into_ws"));
            let mut cg = Tensor::filled(&[rows, rows], f32::NAN);
            sd.gram_into_ws(&g, &mut cg, &mut ws);
            assert_ulp_close(&cg, &oracle.gram(&g), &format!("{what} gram_into_ws"));
        }
        // dot: f64 reference with length-scaled tolerance, like the
        // cross-backend dot property above.
        let len = (rng.below(700)) as usize;
        let mut x = vec![0.0f32; len];
        let mut y = vec![0.0f32; len];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut y, 1.0);
        let want: f64 = x.iter().zip(&y).map(|(p, q)| *p as f64 * *q as f64).sum();
        let got = sd.dot(&x, &y) as f64;
        assert!(
            (got - want).abs() <= 1e-4 * (1.0 + want.abs()) * (1.0 + (len as f64).sqrt()),
            "seed {seed} simd dot len {len}: {got} vs {want}"
        );
    }
}

/// The banding-invariance contract behind the worker pool (ADR-007):
/// `matmul_rows` / `gram_t_rows` produce rows **bitwise identical** to
/// the same rows of a full kernel call, under any row partition — odd
/// splits, width-1 bands, empty bands. This is what makes pooled
/// intra-shard kernels bit-identical to serial execution.
#[test]
fn prop_row_bands_are_bitwise_identical_to_full_kernels() {
    let mut ws = Workspace::new();
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 211);
        let &(m, k, n) = &MATMUL_SHAPES[(seed as usize) % MATMUL_SHAPES.len()];
        let a = rand_t(&mut rng, &[m, k]);
        let b = rand_t(&mut rng, &[k, n]);
        // Deliberately ragged cut points, clamped into range and sorted.
        let cuts: Vec<usize> = {
            let mut c = vec![0, m.min(1), m / 3, m.saturating_sub(1), m, m / 2];
            c.sort_unstable();
            c.dedup();
            c
        };
        for be in Backend::all() {
            let mut full = Tensor::zeros(&[m, n]);
            be.matmul_into_ws(&a, &b, &mut full, &mut ws);
            let mut banded = vec![f32::NAN; m * n];
            for w in cuts.windows(2) {
                let (r0, r1) = (w[0], w[1]);
                be.matmul_rows(&a, &b, r0, r1, &mut banded[r0 * n..r1 * n], &mut ws);
            }
            assert_eq!(
                banded,
                full.data,
                "seed {seed} {} matmul bands not bitwise identical",
                be.name()
            );

            let d = k; // gram_t over a: (m, k) -> (k, k)
            let mut gfull = Tensor::zeros(&[d, d]);
            be.gram_t_into_ws(&a, &mut gfull, &mut ws);
            let gcuts: Vec<usize> = {
                let mut c = vec![0, d.min(1), d / 2, d];
                c.sort_unstable();
                c.dedup();
                c
            };
            let mut grows = vec![f32::NAN; d * d];
            for w in gcuts.windows(2) {
                let (i0, i1) = (w[0], w[1]);
                be.gram_t_rows(&a, i0, i1, &mut grows[i0 * d..i1 * d], &mut ws);
            }
            // gram_t_rows computes only the upper-triangle cells j >= i
            // of its band (the mirror runs after all bands land), so
            // compare exactly those against the mirrored full result.
            for i in 0..d {
                for j in i..d {
                    assert_eq!(
                        grows[i * d + j].to_bits(),
                        gfull.data[i * d + j].to_bits(),
                        "seed {seed} {} gram_t band ({i},{j}) not bitwise identical",
                        be.name()
                    );
                }
            }
        }
    }
}

#[test]
fn predictor_fit_agrees_across_backends() {
    // Same synthetic family as predictor::fit's unit tests: exactly
    // low-rank gradients. All backends must recover the same subspace —
    // compared through predictions, which are basis-invariant.
    let (p_t, d, r) = (160usize, 5usize, 2usize);
    let mut rng = Pcg64::seeded(404);
    let mut u_true = Tensor::zeros(&[p_t, r]);
    rng.fill_normal(&mut u_true.data, (1.0 / p_t as f32).sqrt());
    let mut b_true = Tensor::zeros(&[r, (d + 1) * d]);
    rng.fill_normal(&mut b_true.data, 1.0);

    let sample = |rng: &mut Pcg64| {
        let mut a = vec![0.0f32; d];
        let mut h = vec![0.0f32; d];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut h, 1.0);
        let mut phi = vec![0.0f32; (d + 1) * d];
        for i in 0..d {
            for k in 0..d {
                phi[i * d + k] = a[i] * h[k];
            }
        }
        phi[d * d..].copy_from_slice(&h);
        let c = lgp::tensor::matmul::matvec(&b_true, &phi);
        let g = lgp::tensor::matmul::matvec(&u_true, &c);
        (g, a, h)
    };

    let mut buf = FitBuffer::new(32);
    let mut probes = Vec::new();
    for i in 0..36 {
        let (g, a, h) = sample(&mut rng);
        if i < 32 {
            buf.push(&g, &a, &h);
        } else {
            probes.push((a, h));
        }
    }

    let mut predictions = Vec::new();
    for be in Backend::all() {
        let mut pred = Predictor::new(p_t, d, r);
        let report = fit_with(be, &mut pred, &buf, 1e-7).unwrap();
        assert!(report.energy_captured > 0.99, "{}: {report:?}", be.name());
        assert!(report.rel_error < 0.05, "{}: {report:?}", be.name());
        let got: Vec<Vec<f32>> = probes
            .iter()
            .map(|(a, h)| pred.predict_one_trunk(a, h))
            .collect();
        predictions.push((be.name(), got));
    }
    let (_, reference) = &predictions[0];
    for (name, got) in &predictions[1..] {
        for (gv, rv) in got.iter().zip(reference) {
            for (x, y) in gv.iter().zip(rv) {
                assert!((x - y).abs() <= 1e-2 * (1.0 + y.abs()), "{name}: {x} vs {y}");
            }
        }
    }
}

#[test]
fn calibration_probe_picks_valid_backend() {
    let report = backend::calibrate();
    // The candidate set is the portable concrete backends plus simd on
    // hosts with AVX2+FMA (ADR-007).
    let candidates = BackendKind::available();
    assert!(
        candidates.contains(&report.chosen),
        "probe chose {:?}",
        report.chosen
    );
    assert_eq!(report.timings.len(), candidates.len());
    for (kind, secs) in &report.timings {
        assert!(candidates.contains(kind));
        assert!(secs.is_finite() && *secs > 0.0, "{kind:?} timed at {secs}");
    }
    // Auto resolution produces a usable handle that computes correctly.
    let be = Backend::of(BackendKind::Auto);
    let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
    let c = be.matmul(&a, &Tensor::eye(2));
    assert_eq!(c.data, a.data);
}

// ---------------------------------------------------------------------------
// Bench JSON pipeline smoke tests (the `cargo test`-visible wiring of the
// bench-report validator)
// ---------------------------------------------------------------------------

#[test]
fn kernel_bench_fast_mode_emits_schema_valid_json() {
    let records = kernels::run(&kernels::KernelBenchConfig::fast());
    let doc = kernels::doc(&records);
    let report = schema::validate(&doc).expect("fast kernel suite must emit valid documents");
    assert_eq!(report.bench, "kernels");
    assert_eq!(report.records, records.len());
    for be in ["naive", "blocked", "micro"] {
        assert!(report.backends.iter().any(|b| b == be), "missing {be}");
    }

    // Round-trip through disk exactly like the bench binary + bench-report.
    let dir = std::env::temp_dir().join("lgp_bench_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_kernels.json");
    std::fs::write(&path, doc.to_string()).unwrap();
    let file_report = schema::validate_file(&path).unwrap();
    assert_eq!(file_report.records, records.len());
}

/// Deep-copy a bench document with every record's `mean_ns` scaled — the
/// synthetic-regression fixture generator for the gate tests.
fn scaled_mean_ns(doc: &Json, factor: f64) -> Json {
    let mut doc = doc.clone();
    if let Json::Obj(m) = &mut doc {
        if let Some(Json::Arr(records)) = m.get_mut("records") {
            for rec in records {
                if let Json::Obj(r) = rec {
                    if let Some(Json::Num(v)) = r.get_mut("mean_ns") {
                        *v *= factor;
                    }
                }
            }
        }
    }
    doc
}

/// Tier-1 wiring of the perf-regression gate: when both the committed
/// baseline (`BENCH_kernels.baseline.json`) and the current trajectory
/// (`BENCH_kernels.json`) exist at the repo root, the >10% ns/op gate must
/// pass — and must demonstrably fail on a synthetic 20%-slower fixture.
/// Skips (does not fail) when either file is absent, so fresh clones that
/// have not run `cargo bench` are unaffected.
#[test]
fn perf_gate_validates_committed_kernel_trajectory() {
    // Escape hatch for cross-host comparisons: absolute ns/op measured on
    // a slower machine than the committed trajectory's host would trip
    // the gate with no real regression. Set LGP_SKIP_PERF_GATE=1 there
    // (or promote a new locally-measured baseline; EXPERIMENTS.md
    // §Compare gate).
    if std::env::var_os("LGP_SKIP_PERF_GATE").is_some() {
        eprintln!("perf gate: skipped via LGP_SKIP_PERF_GATE");
        return;
    }
    let root = bench_out_dir();
    let base = root.join("BENCH_kernels.baseline.json");
    let new = root.join("BENCH_kernels.json");
    if !base.exists() || !new.exists() {
        eprintln!(
            "perf gate: skipping — need both {} and {} (run `cargo bench --bench hotpath`)",
            base.display(),
            new.display()
        );
        return;
    }
    let rep = compare::compare_files(&base, &new, compare::DEFAULT_THRESHOLD)
        .expect("committed kernel trajectory must be comparable against its baseline");
    assert!(
        rep.passed(),
        "perf gate failed vs committed baseline: regressed {:?}, missing {:?}",
        rep.regressions().iter().map(|c| c.key.clone()).collect::<Vec<_>>(),
        rep.missing
    );

    // The gate has teeth: a 20%-slower copy of the baseline trips it.
    let text = std::fs::read_to_string(&base).unwrap();
    let doc = Json::parse(&text).unwrap();
    let slower = scaled_mean_ns(&doc, 1.2);
    let rep = compare::compare_docs(&doc, &slower, compare::DEFAULT_THRESHOLD).unwrap();
    assert!(!rep.passed(), "20%-slower fixture must trip the 10% gate");
    assert_eq!(
        rep.regressions().len(),
        rep.cells.len(),
        "every scaled cell should read as regressed"
    );
}

#[test]
fn schema_rejects_truncated_and_tampered_documents() {
    let summary = Summary::from_samples(vec![1e-6, 2e-6]);
    let rec = BenchRecord::from_summary("matmul", "naive", &[2, 2, 2], &summary, Some(16.0));
    let good = bench_doc("custom", &[rec], None);
    assert!(schema::validate(&good).is_ok());

    // Tamper: wrong schema id.
    let mut text = good.to_string();
    text = text.replace("lgp.bench.v1", "lgp.bench.v999");
    let doc = Json::parse(&text).unwrap();
    assert!(schema::validate(&doc).is_err());

    // Tamper: drop a required record field.
    let text = good.to_string().replace("\"mean_ns\"", "\"renamed_ns\"");
    let doc = Json::parse(&text).unwrap();
    assert!(schema::validate(&doc).is_err());

    // Truncated file on disk.
    let dir = std::env::temp_dir().join("lgp_bench_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_truncated.json");
    let full = good.to_string();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    assert!(schema::validate_file(&path).is_err());
}
