//! ADR-004 determinism contract: `--shards N` is bit-identical to serial.
//!
//! Two layers of coverage:
//!
//! 1. **Host-model path (always runs).** A miniature end-to-end trainer —
//!    the real sharded machinery (`DataPipeline` views, `exec::scatter`,
//!    the fixed-topology `reduce`, the Muon `Optimizer`) around a host
//!    linear-softmax model standing in for the PJRT micro-batch call
//!    (which the offline `xla` stub cannot execute). Three optimizer
//!    steps on the synthetic dataset at shards = 1, 2, 4 must produce
//!    bit-identical parameter vectors and loss traces — through both the
//!    one-shot scoped-thread executor and the persistent worker pool
//!    (ADR-007), with the pool additionally reused across whole runs.
//!
//! 2. **Full-session path (artifact-gated).** When the AOT artifacts are
//!    built, the same assertion runs through `TrainSession::run` itself
//!    (the ADR-005 API replacing the old `Trainer`) — GPR with a refit
//!    inside the window, so the sharded chunk collection is exercised
//!    too. Skips cleanly on stub builds, like every other artifact-gated
//!    integration test.
//!
//! `LGP_SHARDS=K cargo test -q` adds K to the sweep in both layers, so
//! the tier-1 smoke invocation exercises the requested width.

use lgp::config::{shards_env_override, Algo, EstimatorKind, OptimKind, RunConfig};
use lgp::coordinator::{exec, pool::WorkerPool, reduce};
use lgp::data::loader::{DataPipeline, ShardDataView};
use lgp::estimator::testbed::Testbed;
use lgp::estimator::{
    ControlVariate, GradientEstimator, MultiTangentForward, NeuralControlVariate, PredictedLgp,
    TrueBackprop,
};
use lgp::model::manifest::{Manifest, TrunkParam};
use lgp::model::params::{FlatGrad, ParamStore};
use lgp::optim::{OptimConfig, Optimizer};
use lgp::predictor::fit::{fit_with, FitBuffer};
use lgp::predictor::Predictor;
use lgp::session::SessionBuilder;
use lgp::tensor::{Backend, Workspace};
use lgp::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Shard counts under test: the spec'd 1/2/4 sweep plus any `LGP_SHARDS`
/// override from the harness.
fn shard_sweep() -> Vec<usize> {
    let mut counts = vec![1, 2, 4];
    if let Some(s) = shards_env_override().expect("LGP_SHARDS") {
        if !counts.contains(&s) {
            counts.push(s);
        }
    }
    counts
}

// ---------------------------------------------------------------------------
// Layer 1: host linear-softmax model through the real sharded machinery
// ---------------------------------------------------------------------------

const CLASSES: usize = 5;
const SIDE: usize = 8;
const FEAT: usize = 3 * SIDE * SIDE;
const MICRO: usize = 8;
const ACCUM: usize = 8;

fn host_manifest() -> Manifest {
    let trunk_params = CLASSES * FEAT;
    Manifest {
        dir: ".".into(),
        preset: "shard-determinism".into(),
        image: SIDE,
        classes: CLASSES,
        width: 4,
        label_smoothing: 0.0,
        rank: 2,
        n_chunk: 4,
        n_fit: 8,
        feat_dim: FEAT,
        trunk_params,
        total_params: trunk_params + 4 * CLASSES + CLASSES,
        micro_batch: MICRO,
        fs: vec![0.25],
        val_batch: 8,
        trunk_layout: vec![TrunkParam {
            name: "w".into(),
            shape: vec![CLASSES, FEAT],
            offset: 0,
            len: trunk_params,
            muon: true,
        }],
        artifacts: BTreeMap::new(),
        init_trunk: ".".into(),
        init_head_w: ".".into(),
        init_head_b: ".".into(),
    }
}

/// Mean softmax cross-entropy gradient of a linear model W (C, FEAT) on
/// one micro-batch — fixed loop order, so the result is a pure bitwise
/// function of (W, batch) no matter which thread runs it.
fn micro_grad(w_mat: &[f32], x: &[f32], y: &[i32]) -> (Vec<f32>, f32) {
    let m = y.len();
    let mut grad = vec![0.0f32; CLASSES * FEAT];
    let mut logits = [0.0f32; CLASSES];
    let mut loss = 0.0f32;
    for j in 0..m {
        let xj = &x[j * FEAT..(j + 1) * FEAT];
        for c in 0..CLASSES {
            let row = &w_mat[c * FEAT..(c + 1) * FEAT];
            let mut s = 0.0f32;
            for (a, b) in row.iter().zip(xj) {
                s += a * b;
            }
            logits[c] = s;
        }
        let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for c in 0..CLASSES {
            z += (logits[c] - mx).exp();
        }
        let yj = y[j] as usize;
        loss += z.ln() + mx - logits[yj];
        for c in 0..CLASSES {
            let p = (logits[c] - mx).exp() / z;
            let r = p - if c == yj { 1.0 } else { 0.0 };
            let gr = &mut grad[c * FEAT..(c + 1) * FEAT];
            for (g, xv) in gr.iter_mut().zip(xj) {
                *g += r * xv;
            }
        }
    }
    let inv = 1.0 / m as f32;
    for g in grad.iter_mut() {
        *g *= inv;
    }
    (grad, loss * inv)
}

struct HostWorker {
    view: ShardDataView,
    x: Vec<f32>,
    y: Vec<i32>,
}

/// Three Muon steps of the host model at a given shard count; returns the
/// final trunk parameters and the per-step loss trace. `pool` selects the
/// dispatch path: `None` scatters through the one-shot scoped-thread
/// executor (`exec::scatter`), `Some` through a caller-owned persistent
/// worker pool — reused across every step, like `TrainSession` runs it
/// (ADR-007). Both must be bit-identical to serial.
fn run_host_with(
    shards: usize,
    steps: usize,
    pool: Option<&WorkerPool>,
) -> (Vec<f32>, Vec<f64>) {
    let manifest = host_manifest();
    let mut params = ParamStore {
        trunk: vec![0.0; CLASSES * FEAT],
        head_w: vec![0.0; 4 * CLASSES],
        head_b: vec![0.0; CLASSES],
        width: 4,
        classes: CLASSES,
    };
    Pcg64::seeded(21).fill_normal(&mut params.trunk, 0.05);
    let mut opt = Optimizer::new(
        OptimKind::Muon,
        OptimConfig { lr: 0.02, backend: Backend::blocked(), ..OptimConfig::default() },
        &params,
        &manifest,
    );
    let mut data = DataPipeline::build(64, 16, SIDE, CLASSES, 1, 7);
    let mut workers: Vec<HostWorker> = (0..shards)
        .map(|_| HostWorker { view: data.make_view(), x: Vec::new(), y: Vec::new() })
        .collect();

    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let base = data.cursor();
        let trunk = &params.trunk;
        let task = |w: &mut HostWorker, slot: usize| {
            w.view.batch_at(base + slot * MICRO, MICRO, &mut w.x, &mut w.y);
            let (g, loss) = micro_grad(trunk, &w.x, &w.y);
            Ok((g, loss))
        };
        let outs = match pool {
            Some(p) => p.scatter(&mut workers, ACCUM, task).unwrap(),
            None => exec::scatter(&mut workers, ACCUM, task).unwrap(),
        };
        data.advance(ACCUM * MICRO);

        let mut loss_sum = 0.0f64;
        let mut leaves = Vec::with_capacity(ACCUM);
        for (g, loss) in outs {
            loss_sum += loss as f64;
            leaves.push(FlatGrad {
                trunk: g,
                head_w: vec![0.0; 4 * CLASSES],
                head_b: vec![0.0; CLASSES],
            });
        }
        let mut grad = reduce::tree_reduce_grads(leaves).unwrap();
        grad.scale(1.0 / ACCUM as f32);
        opt.step(&mut params, &grad, &manifest);
        losses.push(loss_sum / ACCUM as f64);
    }
    (params.trunk, losses)
}

fn run_host(shards: usize, steps: usize) -> (Vec<f32>, Vec<f64>) {
    run_host_with(shards, steps, None)
}

#[test]
fn host_model_shards_are_bit_identical_to_serial() {
    let (trunk1, loss1) = run_host(1, 3);
    assert!(trunk1.iter().all(|v| v.is_finite()));
    assert!(loss1.iter().all(|v| v.is_finite() && *v > 0.0));
    // The run did real work: parameters moved off their init.
    let mut init = vec![0.0f32; CLASSES * FEAT];
    Pcg64::seeded(21).fill_normal(&mut init, 0.05);
    assert_ne!(trunk1, init, "three optimizer steps must move the weights");

    for shards in shard_sweep() {
        let (trunk_n, loss_n) = run_host(shards, 3);
        assert_eq!(
            trunk_n, trunk1,
            "shards={shards}: parameter vector differs from serial (bitwise)"
        );
        assert_eq!(
            loss_n.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            loss1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "shards={shards}: loss trace differs from serial (bitwise)"
        );
    }
}

#[test]
fn host_model_sharding_is_repeatable() {
    // Same shard count twice: thread scheduling must not leak into the
    // result at all.
    let (a, la) = run_host(4, 3);
    let (b, lb) = run_host(4, 3);
    assert_eq!(a, b);
    assert_eq!(la, lb);
}

#[test]
fn pooled_dispatch_is_bit_identical_and_pool_reuse_is_deterministic() {
    // The ADR-007 path: the persistent parked pool must match both the
    // serial run and the per-update-spawn executor bit for bit — and a
    // *reused* pool (the session keeps one alive across every update)
    // must not accumulate any state that leaks into results.
    let bits = |ls: &[f64]| ls.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    let (serial, loss_serial) = run_host(1, 3);
    for shards in shard_sweep() {
        let pool = WorkerPool::new(shards);
        let (a, la) = run_host_with(shards, 3, Some(&pool));
        assert_eq!(a, serial, "shards={shards}: pooled trunk differs from serial (bitwise)");
        assert_eq!(
            bits(&la),
            bits(&loss_serial),
            "shards={shards}: pooled loss trace differs from serial"
        );
        // Second full run through the *same* pool instance: parked-thread
        // reuse across many dispatches stays deterministic.
        let (b, lb) = run_host_with(shards, 3, Some(&pool));
        assert_eq!(b, serial, "shards={shards}: pool reuse changed the trunk");
        assert_eq!(bits(&lb), bits(&loss_serial), "shards={shards}: pool reuse changed the loss");
    }
}

// ---------------------------------------------------------------------------
// Layer 1b: the full estimator zoo through the same sharded machinery
// ---------------------------------------------------------------------------

/// A short training run of one zoo member on the host [`Testbed`]
/// through the real scatter/reduce executor. Returns the final trunk
/// parameters and the per-update loss trace (as bits).
fn run_zoo_host(kind: EstimatorKind, shards: usize, updates: usize) -> (Vec<f32>, Vec<u64>) {
    const SEED: u64 = 11;
    const ACC: usize = 4;
    let mut tb = Testbed::new(SEED, 128, 12, 6, 4);
    let man = tb.manifest(8, 2);
    let mut est: Box<dyn GradientEstimator> = match kind {
        EstimatorKind::TrueBackprop => Box::new(TrueBackprop),
        EstimatorKind::ControlVariate => Box::new(ControlVariate::new(0.25)),
        EstimatorKind::PredictedLgp => Box::new(PredictedLgp::new(0.25)),
        EstimatorKind::MultiTangent => Box::new(MultiTangentForward::new(4, SEED)),
        EstimatorKind::NeuralCv => {
            Box::new(NeuralControlVariate::new(0.25).with_seed(SEED).with_mlp(6, 60, 0.05))
        }
    };
    est.bind(&man).unwrap();
    let mut pred = Predictor::new(tb.trunk_params(), tb.width, man.rank);
    let mut linear_fits = 0usize;
    if est.uses_predictor() {
        let mut buf = FitBuffer::new(man.n_fit);
        let idxs: Vec<usize> = (0..man.n_fit).map(|i| (i * 5) % tb.n).collect();
        tb.fill_fit_buffer(&mut buf, &idxs);
        if est.owns_predictor_fit() {
            est.fit_own(Backend::blocked(), &buf, 1e-4, &mut Workspace::new()).unwrap();
        } else {
            fit_with(Backend::blocked(), &mut pred, &buf, 1e-4).unwrap();
            linear_fits = 1;
        }
    }
    let plan = est.plan(&man, est.predictor_ready(linear_fits));
    let consumed = plan.consumed_per_slot();
    let mut rng = Pcg64::new(SEED, 0x7373);
    let stream: Vec<usize> =
        (0..updates * ACC * consumed).map(|_| rng.below(tb.n as u64) as usize).collect();
    let mut workers: Vec<()> = vec![(); shards];
    let mut losses = Vec::with_capacity(updates);
    let mut cursor = 0usize;
    for _ in 0..updates {
        let base = cursor;
        let outs = {
            let (tbr, predr, streamr) = (&tb, &pred, &stream);
            let est_ref: &dyn GradientEstimator = &*est;
            exec::scatter(&mut workers, ACC, |_w, slot| {
                tbr.slot_estimate(est_ref, &plan, predr, streamr, base + slot * consumed)
            })
            .unwrap()
        };
        let mut loss = 0.0f64;
        let mut leaves = Vec::with_capacity(ACC);
        for (g, l) in outs {
            loss += l as f64;
            leaves.push(g);
        }
        let mut grad = reduce::tree_reduce_grads(leaves).unwrap();
        grad.scale(1.0 / ACC as f32);
        tb.sgd_step(&grad, 0.05);
        losses.push((loss / ACC as f64).to_bits());
        cursor += ACC * consumed;
    }
    (tb.trunk.clone(), losses)
}

#[test]
fn estimator_zoo_shards_are_bit_identical_to_serial() {
    // Every zoo member (ADR-006), not just the GPR path: slot estimates
    // are pure functions of (model, stream, position) — multi-tangent's
    // seeded tangents and neural-cv's host predictor included — so shard
    // scheduling must never leak into the parameters.
    for &kind in EstimatorKind::ALL {
        let (trunk1, loss1) = run_zoo_host(kind, 1, 3);
        assert!(trunk1.iter().all(|v| v.is_finite()), "{kind:?}");
        for shards in shard_sweep() {
            let (trunk_n, loss_n) = run_zoo_host(kind, shards, 3);
            assert_eq!(trunk_n, trunk1, "{kind:?} shards={shards}: trunk differs (bitwise)");
            assert_eq!(loss_n, loss1, "{kind:?} shards={shards}: loss trace differs");
        }
    }
}

// ---------------------------------------------------------------------------
// Layer 2: the full TrainSession, when artifacts exist
// ---------------------------------------------------------------------------

fn tiny_cfg(shards: usize) -> Option<RunConfig> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: tiny artifacts not built");
        return None;
    }
    Some(RunConfig {
        artifacts_dir: dir,
        algo: Algo::Gpr,
        f: 0.25,
        accum: 8,
        optimizer: OptimKind::Muon,
        lr: 0.02,
        weight_decay: 0.0,
        budget_secs: 0.0,
        max_steps: 3,
        refit_every: 2, // refit inside the 3-step window: sharded gather runs
        ridge_lambda: 1e-4,
        train_size: 600,
        val_size: 150,
        aug_multiplier: 1,
        seed: 7,
        eval_every: 0,
        out_dir: std::env::temp_dir().join("lgp_shard_det"),
        track_alignment: true,
        adaptive_f: false,
        backend: lgp::tensor::BackendKind::Blocked,
        shards,
        estimator: None,
        tangents: 8,
        checkpoint_dir: None,
        checkpoint_every: 0,
        checkpoint_keep: 0,
        resume: false,
    })
}

#[test]
fn session_shards_are_bit_identical_to_serial() {
    let Some(cfg1) = tiny_cfg(1) else { return };
    let mut serial = SessionBuilder::from_config(cfg1).build().unwrap();
    serial.run().unwrap();
    let loss1: Vec<u64> = serial.log.iter().map(|r| r.loss.to_bits()).collect();

    for shards in shard_sweep() {
        let Some(cfg) = tiny_cfg(shards) else { return };
        let mut t = SessionBuilder::from_config(cfg).build().unwrap();
        assert_eq!(t.shards(), shards);
        t.run().unwrap();
        assert_eq!(t.params.trunk, serial.params.trunk, "shards={shards}: trunk differs");
        assert_eq!(t.params.head_w, serial.params.head_w, "shards={shards}: head_w differs");
        assert_eq!(t.params.head_b, serial.params.head_b, "shards={shards}: head_b differs");
        let loss_n: Vec<u64> = t.log.iter().map(|r| r.loss.to_bits()).collect();
        assert_eq!(loss_n, loss1, "shards={shards}: loss trace differs");
    }
}
