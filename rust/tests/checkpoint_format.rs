//! ADR-008 artifact format properties, proptest-style (seeded loops over
//! randomized contents — the repo's dependency-free stand-in for a
//! proptest crate):
//!
//! - container encode → decode → re-encode is byte-identical for random
//!   fingerprints, section names, and payloads;
//! - every single-byte corruption of an artifact is detected, and payload
//!   corruption names the section it hit;
//! - each estimator's `save_state` payload survives decode into a freshly
//!   constructed estimator and re-encodes byte-identically;
//! - optimizer moments (all four kinds, Muon's matrix momentum included)
//!   round-trip byte-identically after real update steps.

use lgp::checkpoint::{state as ckstate, Checkpoint};
use lgp::config::OptimKind;
use lgp::estimator::testbed::Testbed;
use lgp::estimator::{
    ControlVariate, GradientEstimator, MultiTangentForward, NeuralControlVariate, PredictedLgp,
};
use lgp::metrics::Alignment;
use lgp::model::params::ParamStore;
use lgp::optim::{OptimConfig, Optimizer};
use lgp::predictor::fit::FitBuffer;
use lgp::tensor::{Backend, Workspace};
use lgp::util::rng::Pcg64;

const CASES: u64 = 16;

#[test]
fn randomized_container_round_trips_byte_identically() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 0xC0DE);
        let mut ck = Checkpoint::new(rng.next_u64());
        let n_sections = 1 + rng.below(5) as usize;
        for i in 0..n_sections {
            let name = format!("s{i}_{}", rng.below(1000));
            let len = rng.below(300) as usize;
            let payload: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
            ck.add(&name, payload);
        }
        let bytes = ck.encode();
        let back = Checkpoint::decode(&bytes).unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        assert_eq!(back.fingerprint, ck.fingerprint, "seed {seed}");
        assert_eq!(
            back.section_names().collect::<Vec<_>>(),
            ck.section_names().collect::<Vec<_>>(),
            "seed {seed}"
        );
        assert_eq!(back.encode(), bytes, "seed {seed}: re-encode differs");
    }
}

#[test]
fn every_single_byte_corruption_is_detected() {
    let mut ck = Checkpoint::new(0xFEED);
    ck.add("alpha", vec![7u8; 33]);
    ck.add("beta", vec![9u8; 21]);
    let bytes = ck.encode();
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0x01;
        assert!(
            Checkpoint::decode(&bad).is_err(),
            "flipping byte {i} of {} went undetected",
            bytes.len()
        );
    }
    // Truncation at any prefix length is detected too.
    for cut in 0..bytes.len() {
        assert!(Checkpoint::decode(&bytes[..cut]).is_err(), "truncation at {cut} undetected");
    }
}

#[test]
fn payload_corruption_names_the_section_it_hit() {
    let mut ck = Checkpoint::new(1);
    ck.add("alpha", vec![7u8; 33]);
    ck.add("beta", vec![9u8; 21]);
    let mut bytes = ck.encode();
    // Offset of beta's payload: 28-byte header, alpha record
    // (4 + "alpha" + 8 + 4 + payload), beta record prefix (4 + "beta" + 8 + 4).
    let beta_payload = 28 + (4 + 5 + 8 + 4 + 33) + (4 + 4 + 8 + 4);
    bytes[beta_payload + 10] ^= 0x40;
    let err = Checkpoint::decode(&bytes).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("beta") && msg.contains("crc"), "{msg}");

    // A header flip reads as header corruption, not a fingerprint mismatch.
    let mut hdr = ck.encode();
    hdr[14] ^= 0x01; // inside the fingerprint field
    let err = Checkpoint::decode(&hdr).unwrap_err();
    assert!(format!("{err:#}").contains("header corrupt"), "{err:#}");
}

#[test]
fn estimator_state_round_trips_byte_identically() {
    let tb = Testbed::new(3, 64, 10, 5, 3);
    let man = tb.manifest(8, 2);
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 0xE57A);
        // control-variate, fixed f
        {
            let f = (rng.below(999) + 1) as f64 / 1000.0;
            let mut a = ControlVariate::new(f);
            a.bind(&man).unwrap();
            let bytes = ckstate::encode_estimator(&a);
            let mut b = ControlVariate::new(0.5);
            b.bind(&man).unwrap();
            ckstate::decode_estimator(&mut b, &bytes).unwrap();
            assert_eq!(ckstate::encode_estimator(&b), bytes, "cv seed {seed}");
        }
        // control-variate with the Theorem-4 controller ticked by a random
        // alignment observation
        {
            let mut a = ControlVariate::new(0.25).with_adaptive(true);
            a.bind(&man).unwrap();
            let align = Alignment {
                rho: rng.next_f64(),
                kappa: 0.5 + rng.next_f64(),
                sigma_g: rng.next_f64(),
                sigma_h: rng.next_f64(),
                n: 8,
            };
            a.observe_alignment(Some(align));
            let bytes = ckstate::encode_estimator(&a);
            let mut b = ControlVariate::new(0.25).with_adaptive(true);
            b.bind(&man).unwrap();
            ckstate::decode_estimator(&mut b, &bytes).unwrap();
            assert_eq!(ckstate::encode_estimator(&b), bytes, "adaptive cv seed {seed}");
        }
        // predicted-lgp
        {
            let f = (rng.below(999) + 1) as f64 / 1000.0;
            let mut a = PredictedLgp::new(f);
            a.bind(&man).unwrap();
            let bytes = ckstate::encode_estimator(&a);
            let mut b = PredictedLgp::new(0.5);
            b.bind(&man).unwrap();
            ckstate::decode_estimator(&mut b, &bytes).unwrap();
            assert_eq!(ckstate::encode_estimator(&b), bytes, "plgp seed {seed}");
        }
        // multi-tangent: state is the (k, seed) identity
        {
            let k = 1 + rng.below(6) as usize;
            let s = rng.next_u64();
            let mut a = MultiTangentForward::new(k, s);
            a.bind(&man).unwrap();
            let bytes = ckstate::encode_estimator(&a);
            let mut b = MultiTangentForward::new(k, s);
            b.bind(&man).unwrap();
            ckstate::decode_estimator(&mut b, &bytes).unwrap();
            assert_eq!(ckstate::encode_estimator(&b), bytes, "mtf seed {seed}");
        }
        // neural-cv with a fitted MLP: full weight tensors round-trip
        {
            let mut a = NeuralControlVariate::new(0.25).with_seed(seed).with_mlp(4, 10, 0.05);
            a.bind(&man).unwrap();
            let mut buf = FitBuffer::new(man.n_fit);
            let idxs: Vec<usize> = (0..man.n_fit).map(|i| (i * 7) % tb.n).collect();
            tb.fill_fit_buffer(&mut buf, &idxs);
            a.fit_own(Backend::blocked(), &buf, 1e-4, &mut Workspace::new()).unwrap();
            let bytes = ckstate::encode_estimator(&a);
            let mut b = NeuralControlVariate::new(0.25).with_seed(seed).with_mlp(4, 10, 0.05);
            b.bind(&man).unwrap();
            ckstate::decode_estimator(&mut b, &bytes).unwrap();
            assert_eq!(ckstate::encode_estimator(&b), bytes, "ncv seed {seed}");
        }
    }
}

#[test]
fn multi_tangent_rejects_mismatched_tangent_config() {
    let tb = Testbed::new(3, 64, 10, 5, 3);
    let man = tb.manifest(8, 2);
    let mut a = MultiTangentForward::new(4, 9);
    a.bind(&man).unwrap();
    let bytes = ckstate::encode_estimator(&a);
    let mut b = MultiTangentForward::new(2, 9);
    b.bind(&man).unwrap();
    assert!(ckstate::decode_estimator(&mut b, &bytes).is_err(), "k mismatch must be rejected");
}

fn testbed_params(tb: &Testbed) -> ParamStore {
    ParamStore {
        trunk: tb.trunk.clone(),
        head_w: tb.head_w.clone(),
        head_b: tb.head_b.clone(),
        width: tb.width,
        classes: tb.classes,
    }
}

#[test]
fn optimizer_state_round_trips_byte_identically_after_real_steps() {
    let tb = Testbed::new(5, 32, 10, 5, 3);
    let man = tb.manifest(8, 2);
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed, 0x0071);
        for kind in [OptimKind::Sgd, OptimKind::Momentum, OptimKind::AdamW, OptimKind::Muon] {
            let mut params = testbed_params(&tb);
            let cfg = OptimConfig { lr: 0.02, backend: Backend::blocked(), ..OptimConfig::default() };
            let mut opt = Optimizer::new(kind, cfg.clone(), &params, &man);
            // Two real steps with random gradients populate every moment
            // buffer (Muon's matrix momentum and aux AdamW included).
            for _ in 0..2 {
                let mut g = tb.zero_grad();
                rng.fill_normal(&mut g.trunk, 1.0);
                rng.fill_normal(&mut g.head_w, 1.0);
                rng.fill_normal(&mut g.head_b, 1.0);
                opt.step(&mut params, &g, &man);
            }
            let bytes = ckstate::encode_optimizer(&opt);
            let mut fresh = Optimizer::new(kind, cfg.clone(), &params, &man);
            ckstate::decode_optimizer(&mut fresh, &bytes).unwrap();
            assert_eq!(
                ckstate::encode_optimizer(&fresh),
                bytes,
                "{kind:?} seed {seed}: re-encode differs"
            );
        }
    }
}

#[test]
fn optimizer_kind_mismatch_is_rejected() {
    let tb = Testbed::new(5, 32, 10, 5, 3);
    let man = tb.manifest(8, 2);
    let params = testbed_params(&tb);
    let cfg = OptimConfig { lr: 0.02, backend: Backend::blocked(), ..OptimConfig::default() };
    let sgd = Optimizer::new(OptimKind::Sgd, cfg.clone(), &params, &man);
    let mut muon = Optimizer::new(OptimKind::Muon, cfg, &params, &man);
    let err = ckstate::decode_optimizer(&mut muon, &ckstate::encode_optimizer(&sgd)).unwrap_err();
    assert!(format!("{err:#}").contains("optimizer kind"), "{err:#}");
}
