//! Synthetic CIFAR-10 substitute.
//!
//! Each class is defined by a small family of oriented sinusoid gratings
//! with a class-specific color palette; samples draw a random family
//! member, random phase, a smooth luminance gradient and pixel noise. The
//! task is linearly non-separable in pixel space but comfortably learnable
//! by a small ViT — validation accuracy climbs well above the 10% chance
//! floor, which is what Figure 1 needs to show relative progress.

use super::{Dataset, Image};
use crate::util::rng::Pcg64;

/// Class definition: orientation (radians), spatial frequency, color
/// weights per channel, and a secondary harmonic.
#[derive(Clone, Copy, Debug)]
struct ClassProto {
    angle: f32,
    freq: f32,
    color: [f32; 3],
    harmonic: f32,
}

fn prototypes(classes: usize) -> Vec<ClassProto> {
    // Deterministic, well-separated prototype grid.
    (0..classes)
        .map(|k| {
            let t = k as f32 / classes as f32;
            ClassProto {
                angle: std::f32::consts::PI * t,
                freq: 0.25 + 0.55 * ((k % 5) as f32 / 4.0),
                color: [
                    0.4 + 0.6 * ((k % 3) as f32 / 2.0),
                    0.4 + 0.6 * (((k + 1) % 3) as f32 / 2.0),
                    0.4 + 0.6 * (((k + 2) % 3) as f32 / 2.0),
                ],
                harmonic: if k % 2 == 0 { 2.0 } else { 3.0 },
            }
        })
        .collect()
}

/// Generate one sample of class `label`.
pub fn sample(label: usize, side: usize, classes: usize, rng: &mut Pcg64) -> Image {
    let protos = prototypes(classes);
    let p = protos[label % protos.len()];
    // Per-sample nuisance parameters.
    let phase = rng.range_f32(0.0, 2.0 * std::f32::consts::PI);
    let angle = p.angle + rng.range_f32(-0.12, 0.12);
    let freq = p.freq * rng.range_f32(0.9, 1.1);
    let grad_dir = rng.range_f32(0.0, 2.0 * std::f32::consts::PI);
    let grad_amp = rng.range_f32(0.0, 0.4);
    let noise = 0.35f32;
    let (ca, sa) = (angle.cos(), angle.sin());
    let mut im = Image::zeros(side);
    for y in 0..side {
        for x in 0..side {
            let xf = x as f32 - side as f32 / 2.0;
            let yf = y as f32 - side as f32 / 2.0;
            let u = ca * xf + sa * yf;
            let base = (freq * u + phase).sin() + 0.5 * (p.harmonic * freq * u + phase).cos();
            let lum = grad_amp
                * ((grad_dir.cos() * xf + grad_dir.sin() * yf) / side as f32);
            for c in 0..3 {
                let v = p.color[c] * base + lum + noise * rng.normal();
                im.set(c, y, x, v);
            }
        }
    }
    im
}

/// Generate a balanced dataset of `n` examples over `classes` classes.
pub fn generate(n: usize, side: usize, classes: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 17);
    let mut ds = Dataset::default();
    ds.images.reserve(n);
    for i in 0..n {
        let label = (i % classes) as u8;
        ds.images.push(sample(label as usize, side, classes, &mut rng));
        ds.labels.push(label);
    }
    // Shuffle jointly so mini-batches are class-mixed.
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let images = perm.iter().map(|&i| ds.images[i].clone()).collect();
    let labels = perm.iter().map(|&i| ds.labels[i]).collect();
    Dataset { images, labels }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::stats;

    #[test]
    fn generates_requested_size_and_balance() {
        let ds = generate(100, 16, 10, 0);
        assert_eq!(ds.len(), 100);
        let mut counts = [0usize; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(10, 8, 10, 42);
        let b = generate(10, 8, 10, 42);
        assert_eq!(a.images[3].data, b.images[3].data);
        let c = generate(10, 8, 10, 43);
        assert_ne!(a.images[3].data, c.images[3].data);
    }

    #[test]
    fn classes_are_statistically_distinct() {
        // Mean within-class correlation must exceed cross-class — the
        // signal a classifier will pick up.
        let mut rng = Pcg64::seeded(5);
        let side = 16;
        let a1 = sample(0, side, 10, &mut rng);
        let a2 = sample(0, side, 10, &mut rng);
        let b1 = sample(5, side, 10, &mut rng);
        let within = stats::cosine(&a1.data, &a2.data).abs();
        let cross = stats::cosine(&a1.data, &b1.data).abs();
        // Random phase means within-class cosine isn't huge; but across
        // many pixels the structure still correlates more than cross-class
        // on average. Use a soft check over several draws.
        let mut w_sum = 0.0;
        let mut c_sum = 0.0;
        for _ in 0..20 {
            let x = sample(2, side, 10, &mut rng);
            let y = sample(2, side, 10, &mut rng);
            let z = sample(7, side, 10, &mut rng);
            w_sum += stats::cosine(&x.data, &y.data).abs();
            c_sum += stats::cosine(&x.data, &z.data).abs();
        }
        assert!(
            w_sum > c_sum || within > cross,
            "within {w_sum} vs cross {c_sum}"
        );
    }

    #[test]
    fn values_are_bounded() {
        let ds = generate(20, 16, 10, 1);
        for im in &ds.images {
            for &v in &im.data {
                assert!(v.is_finite() && v.abs() < 10.0);
            }
        }
    }
}
