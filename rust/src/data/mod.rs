//! Data substrate: synthetic CIFAR-10 substitute, the paper's augmentation
//! pipeline, and the pre-augmented device-resident dataset served through
//! an infinite shuffled iterator (paper Sec. 7.1).
//!
//! Substitution note (DESIGN.md §3): no network access means no real
//! CIFAR-10; `synthetic.rs` generates class-conditional images whose
//! classification task is learnable but non-trivial, which is all the
//! algorithm's gradient statistics depend on.

pub mod augment;
pub mod cifar;
pub mod loader;
pub mod synthetic;

/// One image: CHW f32, values roughly in [-2, 2] (normalized space).
#[derive(Clone, Debug)]
pub struct Image {
    pub data: Vec<f32>,
    pub side: usize,
}

impl Image {
    pub fn zeros(side: usize) -> Image {
        Image { data: vec![0.0; 3 * side * side], side }
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.side + y) * self.side + x]
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: f32) {
        self.data[(c * self.side + y) * self.side + x] = v;
    }
}

/// A labeled dataset held fully in memory.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub images: Vec<Image>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Copy a batch of examples (by index) into a flat (m, 3, S, S) buffer
    /// plus an i32 label buffer — the exact layout the HLO artifacts take.
    pub fn gather(&self, idx: &[usize], x_out: &mut Vec<f32>, y_out: &mut Vec<i32>) {
        x_out.clear();
        y_out.clear();
        for &i in idx {
            x_out.extend_from_slice(&self.images[i].data);
            y_out.push(self.labels[i] as i32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_indexing() {
        let mut im = Image::zeros(4);
        im.set(2, 3, 1, 7.0);
        assert_eq!(im.at(2, 3, 1), 7.0);
        assert_eq!(im.data.len(), 48);
    }

    #[test]
    fn gather_layout() {
        let mut ds = Dataset::default();
        for lbl in 0..3u8 {
            let mut im = Image::zeros(2);
            im.data.fill(lbl as f32);
            ds.images.push(im);
            ds.labels.push(lbl);
        }
        let (mut x, mut y) = (Vec::new(), Vec::new());
        ds.gather(&[2, 0], &mut x, &mut y);
        assert_eq!(x.len(), 2 * 12);
        assert_eq!(&x[..12], &[2.0f32; 12][..]);
        assert_eq!(y, vec![2, 0]);
    }
}
