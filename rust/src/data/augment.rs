//! The paper's augmentation pipeline (Sec. 7.1), pre-applied at load time:
//!
//! - random crop with padding 4;
//! - horizontal flip, p = 0.5;
//! - color jitter, p = 0.2;
//! - random erasing, p = 0.25, area ∈ [0.02, 0.12], aspect ∈ [0.3, 3.3].

use super::Image;
use crate::util::rng::Pcg64;

/// Pad by `pad` (reflect-free zero padding, as torchvision's default
/// constant fill) then crop back to the original side at a random offset.
pub fn random_crop(im: &Image, pad: usize, rng: &mut Pcg64) -> Image {
    let s = im.side;
    let ox = rng.below((2 * pad + 1) as u64) as isize - pad as isize;
    let oy = rng.below((2 * pad + 1) as u64) as isize - pad as isize;
    let mut out = Image::zeros(s);
    for c in 0..3 {
        for y in 0..s {
            let sy = y as isize + oy;
            if sy < 0 || sy >= s as isize {
                continue;
            }
            for x in 0..s {
                let sx = x as isize + ox;
                if sx < 0 || sx >= s as isize {
                    continue;
                }
                out.set(c, y, x, im.at(c, sy as usize, sx as usize));
            }
        }
    }
    out
}

/// Horizontal mirror.
pub fn hflip(im: &Image) -> Image {
    let s = im.side;
    let mut out = Image::zeros(s);
    for c in 0..3 {
        for y in 0..s {
            for x in 0..s {
                out.set(c, y, x, im.at(c, y, s - 1 - x));
            }
        }
    }
    out
}

/// Brightness/contrast/per-channel jitter (a compact stand-in for
/// torchvision's ColorJitter in normalized space).
pub fn color_jitter(im: &Image, rng: &mut Pcg64) -> Image {
    let bright = rng.range_f32(-0.2, 0.2);
    let contrast = rng.range_f32(0.8, 1.2);
    let ch_scale = [
        rng.range_f32(0.9, 1.1),
        rng.range_f32(0.9, 1.1),
        rng.range_f32(0.9, 1.1),
    ];
    let s = im.side;
    let mut out = im.clone();
    for c in 0..3 {
        for y in 0..s {
            for x in 0..s {
                let v = im.at(c, y, x);
                out.set(c, y, x, (v * contrast + bright) * ch_scale[c]);
            }
        }
    }
    out
}

/// Random erasing (Zhong et al.): blank a random rectangle with noise.
/// Area fraction ∈ [lo, hi], aspect ratio ∈ [0.3, 3.3] — paper's settings.
pub fn random_erase(im: &Image, lo: f32, hi: f32, rng: &mut Pcg64) -> Image {
    let s = im.side;
    let total = (s * s) as f32;
    let mut out = im.clone();
    for _attempt in 0..10 {
        let area = total * rng.range_f32(lo, hi);
        let aspect = rng.range_f32(0.3, 3.3);
        let h = (area * aspect).sqrt().round() as usize;
        let w = (area / aspect).sqrt().round() as usize;
        if h == 0 || w == 0 || h >= s || w >= s {
            continue;
        }
        let y0 = rng.below((s - h) as u64 + 1) as usize;
        let x0 = rng.below((s - w) as u64 + 1) as usize;
        for c in 0..3 {
            for y in y0..y0 + h {
                for x in x0..x0 + w {
                    out.set(c, y, x, rng.normal());
                }
            }
        }
        return out;
    }
    out
}

/// Apply the full stochastic pipeline to one image.
pub fn augment(im: &Image, rng: &mut Pcg64) -> Image {
    let mut out = random_crop(im, 4, rng);
    if rng.coin(0.5) {
        out = hflip(&out);
    }
    if rng.coin(0.2) {
        out = color_jitter(&out, rng);
    }
    if rng.coin(0.25) {
        out = random_erase(&out, 0.02, 0.12, rng);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn striped(side: usize) -> Image {
        let mut im = Image::zeros(side);
        for c in 0..3 {
            for y in 0..side {
                for x in 0..side {
                    im.set(c, y, x, x as f32);
                }
            }
        }
        im
    }

    #[test]
    fn hflip_is_involution() {
        let im = striped(8);
        let f = hflip(&im);
        assert_eq!(f.at(0, 0, 0), 7.0);
        assert_eq!(hflip(&f).data, im.data);
    }

    #[test]
    fn crop_preserves_shape_and_zero_offset_possible() {
        let im = striped(16);
        let mut rng = Pcg64::seeded(0);
        for _ in 0..20 {
            let c = random_crop(&im, 4, &mut rng);
            assert_eq!(c.side, 16);
            assert_eq!(c.data.len(), im.data.len());
        }
    }

    #[test]
    fn erase_changes_bounded_region() {
        let im = striped(16);
        let mut rng = Pcg64::seeded(1);
        let e = random_erase(&im, 0.02, 0.12, &mut rng);
        let changed = im
            .data
            .iter()
            .zip(&e.data)
            .filter(|(a, b)| a != b)
            .count();
        // changed pixels (x3 channels) within [0.02, 0.15] of the image
        let frac = changed as f32 / im.data.len() as f32;
        assert!(frac > 0.0 && frac < 0.2, "frac={frac}");
    }

    #[test]
    fn jitter_keeps_values_finite() {
        let im = striped(8);
        let mut rng = Pcg64::seeded(2);
        let j = color_jitter(&im, &mut rng);
        assert!(j.data.iter().all(|v| v.is_finite()));
        assert_ne!(j.data, im.data);
    }

    #[test]
    fn augment_pipeline_deterministic_per_seed() {
        let im = striped(16);
        let a = augment(&im, &mut Pcg64::seeded(7));
        let b = augment(&im, &mut Pcg64::seeded(7));
        assert_eq!(a.data, b.data);
    }
}
