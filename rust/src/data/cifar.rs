//! Real CIFAR-10 loader (binary version, the `data_batch_*.bin` format).
//!
//! The evaluation in this repository runs on the synthetic substitute
//! (DESIGN.md §3 — no network access in this environment), but the data
//! pipeline is complete: drop the standard `cifar-10-batches-bin/` files
//! into a directory and pass `--cifar <dir>` (or call `load_dir`) to train
//! on the real dataset with the identical augmentation/serving path.
//!
//! Format per record: 1 label byte + 3072 pixel bytes (R, G, B planes,
//! row-major 32×32), 10 000 records per batch file.

use super::{Dataset, Image};

pub const SIDE: usize = 32;
pub const RECORD: usize = 1 + 3 * SIDE * SIDE;

/// Per-channel normalization constants (the standard CIFAR-10 values).
pub const MEAN: [f32; 3] = [0.4914, 0.4822, 0.4465];
pub const STD: [f32; 3] = [0.2470, 0.2435, 0.2616];

/// Decode one batch file's bytes into (images, labels).
pub fn decode(bytes: &[u8]) -> anyhow::Result<Dataset> {
    anyhow::ensure!(
        !bytes.is_empty() && bytes.len() % RECORD == 0,
        "CIFAR batch size {} is not a multiple of record size {RECORD}",
        bytes.len()
    );
    let n = bytes.len() / RECORD;
    let mut ds = Dataset::default();
    ds.images.reserve(n);
    for rec in bytes.chunks_exact(RECORD) {
        let label = rec[0];
        anyhow::ensure!(label < 10, "label {label} out of range");
        let mut im = Image::zeros(SIDE);
        for c in 0..3 {
            let plane = &rec[1 + c * SIDE * SIDE..1 + (c + 1) * SIDE * SIDE];
            for y in 0..SIDE {
                for x in 0..SIDE {
                    let v = plane[y * SIDE + x] as f32 / 255.0;
                    im.set(c, y, x, (v - MEAN[c]) / STD[c]);
                }
            }
        }
        ds.images.push(im);
        ds.labels.push(label);
    }
    Ok(ds)
}

/// Load train (data_batch_1..5.bin) and test (test_batch.bin) sets from a
/// `cifar-10-batches-bin` directory.
pub fn load_dir(dir: &std::path::Path) -> anyhow::Result<(Dataset, Dataset)> {
    let mut train = Dataset::default();
    for i in 1..=5 {
        let path = dir.join(format!("data_batch_{i}.bin"));
        let bytes = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let part = decode(&bytes)
            .map_err(|e| e.context(format!("decoding {}", path.display())))?;
        train.images.extend(part.images);
        train.labels.extend(part.labels);
    }
    let test_path = dir.join("test_batch.bin");
    let test_bytes = std::fs::read(&test_path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", test_path.display()))?;
    let test = decode(&test_bytes)
        .map_err(|e| e.context(format!("decoding {}", test_path.display())))?;
    Ok((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a fake batch file: record i has label i % 10 and constant
    /// pixel value i % 256.
    fn fake_batch(n: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n * RECORD);
        for i in 0..n {
            out.push((i % 10) as u8);
            out.extend(std::iter::repeat((i % 256) as u8).take(3 * SIDE * SIDE));
        }
        out
    }

    #[test]
    fn decodes_labels_and_normalized_pixels() {
        let ds = decode(&fake_batch(4)).unwrap();
        assert_eq!(ds.len(), 4);
        assert_eq!(ds.labels, vec![0, 1, 2, 3]);
        // pixel value 2/255, channel 0
        let want = (2.0 / 255.0 - MEAN[0]) / STD[0];
        assert!((ds.images[2].at(0, 5, 7) - want).abs() < 1e-6);
    }

    #[test]
    fn rejects_truncated_input() {
        assert!(decode(&fake_batch(2)[..RECORD + 5]).is_err());
        assert!(decode(&[]).is_err());
    }

    #[test]
    fn rejects_bad_label() {
        let mut b = fake_batch(1);
        b[0] = 42;
        assert!(decode(&b).is_err());
    }

    #[test]
    fn load_dir_round_trip() {
        let dir = std::env::temp_dir().join("lgp_cifar_test");
        std::fs::create_dir_all(&dir).unwrap();
        for i in 1..=5 {
            std::fs::write(dir.join(format!("data_batch_{i}.bin")), fake_batch(8)).unwrap();
        }
        std::fs::write(dir.join("test_batch.bin"), fake_batch(6)).unwrap();
        let (train, test) = load_dir(&dir).unwrap();
        assert_eq!(train.len(), 40);
        assert_eq!(test.len(), 6);
    }

    #[test]
    fn io_errors_name_the_offending_path() {
        let dir = std::env::temp_dir().join("lgp_cifar_test_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for i in 1..=5 {
            std::fs::write(dir.join(format!("data_batch_{i}.bin")), fake_batch(2)).unwrap();
        }
        // test_batch.bin absent: the error must carry the full path, not
        // just the file name.
        let err = load_dir(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("test_batch.bin") && msg.contains("lgp_cifar_test_missing"),
            "{msg}"
        );
        // A present-but-garbled batch names the file it came from.
        std::fs::write(dir.join("test_batch.bin"), [1u8; 7]).unwrap();
        let err = load_dir(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("test_batch.bin") && msg.contains("record size"), "{msg}");
    }
}
