//! Pre-augmented in-memory dataset + infinite shuffled stream — exactly
//! the paper's serving scheme (Sec. 7.1): "pre-apply the full augmentation
//! pipeline to generate an effective dataset of size 100,000 ... served via
//! an infinite iterator with per-epoch index shuffling."
//!
//! Sharding (DESIGN.md ADR-004): the stream is *positional*. Every example
//! the trainer will ever consume has a global stream position `p`; epoch
//! `p / n` is served through a permutation derived **statelessly** from
//! `(seed, epoch)`, so any shard can materialize any slice of the stream
//! without consuming shared mutable state. `DataPipeline` keeps a cursor
//! for the serial convenience API (`next_batch`); workers get independent
//! [`ShardDataView`]s over the same `Arc<Dataset>` and read disjoint
//! position ranges. Identical positions yield identical examples no matter
//! how many shards read the stream — the bit-determinism contract's data
//! half.

use super::{augment, synthetic, Dataset};
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// RNG stream namespace for the per-epoch permutations (kept away from the
/// generation streams 23/31 used below).
const PERM_STREAM_BASE: u64 = 0x51ed_0000;

/// Stateless per-epoch permutation cache: maps a global stream position to
/// a dataset index. Cheap to clone conceptually but owns its scratch, so
/// every worker can hold one without sharing mutable state.
#[derive(Clone, Debug)]
pub struct EpochPerm {
    seed: u64,
    n: usize,
    /// Epoch whose permutation is currently materialized (`usize::MAX`
    /// means none yet).
    cached: usize,
    perm: Vec<usize>,
}

impl EpochPerm {
    pub fn new(seed: u64, n: usize) -> EpochPerm {
        assert!(n > 0, "empty dataset has no stream");
        EpochPerm { seed, n, cached: usize::MAX, perm: Vec::new() }
    }

    /// The permutation of epoch `e`, derived from `(seed, e)` alone — the
    /// property the shard proptests pin: every view of the stream
    /// reshuffles identically per epoch regardless of shard count.
    fn ensure_epoch(&mut self, e: usize) {
        if self.cached == e {
            return;
        }
        self.perm.clear();
        self.perm.extend(0..self.n);
        let mut rng = Pcg64::new(self.seed, PERM_STREAM_BASE + e as u64);
        rng.shuffle(&mut self.perm);
        self.cached = e;
    }

    /// Dataset index served at global stream position `p`.
    pub fn index_at(&mut self, p: usize) -> usize {
        self.ensure_epoch(p / self.n);
        self.perm[p % self.n]
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

/// A worker-owned window onto the training stream: shared read-only data
/// (`Arc<Dataset>`) plus a private permutation cache. Reading position
/// ranges through a view never touches the pipeline's cursor.
#[derive(Clone)]
pub struct ShardDataView {
    ds: Arc<Dataset>,
    perm: EpochPerm,
}

impl ShardDataView {
    /// Fill flat buffers with the `m` examples at stream positions
    /// `[pos, pos + m)` (may span an epoch boundary). Buffers are cleared
    /// and refilled. This inlines [`Dataset::gather`]'s layout rather
    /// than delegating so the hot path never materializes an index
    /// vector — with retained buffer capacity it is allocation-free once
    /// warm (the per-worker property the `alloc-counter` suite pins).
    pub fn batch_at(&mut self, pos: usize, m: usize, x: &mut Vec<f32>, y: &mut Vec<i32>) {
        x.clear();
        y.clear();
        for p in pos..pos + m {
            let i = self.perm.index_at(p);
            x.extend_from_slice(&self.ds.images[i].data);
            y.push(self.ds.labels[i] as i32);
        }
    }

    /// Dataset index at a stream position (proptest hook).
    pub fn index_at(&mut self, pos: usize) -> usize {
        self.perm.index_at(pos)
    }
}

/// Training + validation stores for one run.
pub struct DataPipeline {
    pub train: Arc<Dataset>,
    pub val: Arc<Dataset>,
    seed: u64,
    /// Next unconsumed global stream position (the serial cursor; sharded
    /// updates advance it in one jump via [`advance`](Self::advance)).
    cursor: usize,
    serial: EpochPerm,
}

impl DataPipeline {
    /// Build: generate `base_n` synthetic examples, pre-apply `mult`
    /// augmented copies each (paper: 2x), plus a clean validation split.
    pub fn build(base_n: usize, val_n: usize, side: usize, classes: usize,
                 mult: usize, seed: u64) -> DataPipeline {
        let base = synthetic::generate(base_n, side, classes, seed);
        // Validation from an independent stream (never augmented).
        let val = synthetic::generate(val_n, side, classes, seed ^ 0x5eed_0001);
        let mut aug_rng = Pcg64::new(seed, 23);
        let mut train = Dataset::default();
        train.images.reserve(base_n * mult.max(1));
        for (im, &lbl) in base.images.iter().zip(&base.labels) {
            for copy in 0..mult.max(1) {
                let sample = if copy == 0 {
                    im.clone() // keep one un-augmented copy per example
                } else {
                    augment::augment(im, &mut aug_rng)
                };
                train.images.push(sample);
                train.labels.push(lbl);
            }
        }
        let n = train.len();
        DataPipeline {
            train: Arc::new(train),
            val: Arc::new(val),
            seed,
            cursor: 0,
            serial: EpochPerm::new(seed, n),
        }
    }

    /// Epochs started so far (an epoch starts with its reshuffle, exactly
    /// like the pre-ADR-004 stateful iterator).
    pub fn epoch(&self) -> usize {
        self.cursor.div_ceil(self.serial.len())
    }

    /// Next unconsumed global stream position.
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    /// Consume `count` stream positions without materializing them — the
    /// coordinator calls this after a sharded scatter whose workers read
    /// the positions directly through their views.
    pub fn advance(&mut self, count: usize) {
        self.cursor += count;
    }

    /// An independent worker view over the training stream (shared data,
    /// private permutation cache).
    pub fn make_view(&self) -> ShardDataView {
        ShardDataView {
            ds: self.train.clone(),
            perm: EpochPerm::new(self.seed, self.train.len()),
        }
    }

    /// Next `m` indices of the infinite stream (reshuffles at epoch
    /// boundaries), advancing the cursor.
    pub fn next_indices(&mut self, m: usize) -> Vec<usize> {
        let out = (self.cursor..self.cursor + m)
            .map(|p| self.serial.index_at(p))
            .collect();
        self.cursor += m;
        out
    }

    /// Fill flat buffers for the next training micro-batch (serial path).
    pub fn next_batch(&mut self, m: usize, x: &mut Vec<f32>, y: &mut Vec<i32>) {
        let idx = self.next_indices(m);
        self.train.gather(&idx, x, y);
    }

    /// Deterministic validation batches (chunked, in order).
    pub fn val_batches(&self, m: usize) -> Vec<(Vec<f32>, Vec<i32>)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i + m <= self.val.len() {
            let idx: Vec<usize> = (i..i + m).collect();
            let (mut x, mut y) = (Vec::new(), Vec::new());
            self.val.gather(&idx, &mut x, &mut y);
            out.push((x, y));
            i += m;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_sizes() {
        let p = DataPipeline::build(50, 20, 8, 10, 2, 0);
        assert_eq!(p.train.len(), 100);
        assert_eq!(p.val.len(), 20);
    }

    #[test]
    fn infinite_iterator_covers_all_indices_each_epoch() {
        let mut p = DataPipeline::build(25, 5, 8, 5, 1, 0);
        let mut seen = vec![0usize; 25];
        for _ in 0..5 {
            for &i in &p.next_indices(5) {
                seen[i] += 1;
            }
        }
        // one full epoch: every index exactly once
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
        assert_eq!(p.epoch(), 1);
    }

    #[test]
    fn epoch_reshuffles() {
        let mut p = DataPipeline::build(32, 5, 8, 4, 1, 3);
        let e1: Vec<usize> = (0..4).flat_map(|_| p.next_indices(8)).collect();
        let e2: Vec<usize> = (0..4).flat_map(|_| p.next_indices(8)).collect();
        assert_ne!(e1, e2);
        let mut s1 = e1.clone();
        let mut s2 = e2.clone();
        s1.sort_unstable();
        s2.sort_unstable();
        assert_eq!(s1, s2); // same index set
    }

    #[test]
    fn val_batches_chunk_correctly() {
        let p = DataPipeline::build(20, 17, 8, 10, 1, 0);
        let vb = p.val_batches(5);
        assert_eq!(vb.len(), 3); // 17 / 5 = 3 full batches
        assert_eq!(vb[0].0.len(), 5 * 3 * 8 * 8);
        assert_eq!(vb[0].1.len(), 5);
    }

    #[test]
    fn batch_buffer_layout() {
        let mut p = DataPipeline::build(10, 5, 8, 10, 1, 0);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        p.next_batch(4, &mut x, &mut y);
        assert_eq!(x.len(), 4 * 3 * 8 * 8);
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn views_agree_with_serial_stream_across_epochs() {
        let mut p = DataPipeline::build(13, 5, 8, 4, 1, 9);
        let serial: Vec<usize> = p.next_indices(40); // spans 4 epochs of 13
        let mut v1 = p.make_view();
        let mut v2 = p.make_view();
        // Read the same positions interleaved and out of order: views are
        // position-addressed, so access order cannot matter.
        for pos in (0..40).rev() {
            assert_eq!(v1.index_at(pos), serial[pos], "pos {pos}");
        }
        for pos in 0..40 {
            assert_eq!(v2.index_at(pos), serial[pos], "pos {pos}");
        }
    }

    #[test]
    fn view_batch_matches_serial_batch() {
        let mut p = DataPipeline::build(10, 5, 8, 4, 1, 2);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        p.next_batch(6, &mut xs, &mut ys); // positions 0..6
        let mut v = p.make_view();
        let (mut xv, mut yv) = (Vec::new(), Vec::new());
        v.batch_at(0, 6, &mut xv, &mut yv);
        assert_eq!(xs, xv);
        assert_eq!(ys, yv);
        // advance() consumes positions without materializing them
        let c = p.cursor();
        p.advance(4);
        assert_eq!(p.cursor(), c + 4);
    }
}
