//! Pre-augmented in-memory dataset + infinite shuffled iterator — exactly
//! the paper's serving scheme (Sec. 7.1): "pre-apply the full augmentation
//! pipeline to generate an effective dataset of size 100,000 ... served via
//! an infinite iterator with per-epoch index shuffling."

use super::{augment, synthetic, Dataset};
use crate::util::rng::Pcg64;

/// Training + validation stores for one run.
pub struct DataPipeline {
    pub train: Dataset,
    pub val: Dataset,
    order: Vec<usize>,
    cursor: usize,
    epoch: usize,
    rng: Pcg64,
}

impl DataPipeline {
    /// Build: generate `base_n` synthetic examples, pre-apply `mult`
    /// augmented copies each (paper: 2x), plus a clean validation split.
    pub fn build(base_n: usize, val_n: usize, side: usize, classes: usize,
                 mult: usize, seed: u64) -> DataPipeline {
        let base = synthetic::generate(base_n, side, classes, seed);
        // Validation from an independent stream (never augmented).
        let val = synthetic::generate(val_n, side, classes, seed ^ 0x5eed_0001);
        let mut aug_rng = Pcg64::new(seed, 23);
        let mut train = Dataset::default();
        train.images.reserve(base_n * mult.max(1));
        for (im, &lbl) in base.images.iter().zip(&base.labels) {
            for copy in 0..mult.max(1) {
                let sample = if copy == 0 {
                    im.clone() // keep one un-augmented copy per example
                } else {
                    augment::augment(im, &mut aug_rng)
                };
                train.images.push(sample);
                train.labels.push(lbl);
            }
        }
        let n = train.len();
        DataPipeline {
            train,
            val,
            order: (0..n).collect(),
            cursor: 0,
            epoch: 0,
            rng: Pcg64::new(seed, 31),
        }
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Next `m` indices, reshuffling at epoch boundaries (infinite stream).
    pub fn next_indices(&mut self, m: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(m);
        while out.len() < m {
            if self.cursor == 0 {
                self.rng.shuffle(&mut self.order);
                self.epoch += 1;
            }
            let take = (m - out.len()).min(self.order.len() - self.cursor);
            out.extend_from_slice(&self.order[self.cursor..self.cursor + take]);
            self.cursor = (self.cursor + take) % self.order.len();
        }
        out
    }

    /// Fill flat buffers for the next training micro-batch.
    pub fn next_batch(&mut self, m: usize, x: &mut Vec<f32>, y: &mut Vec<i32>) {
        let idx = self.next_indices(m);
        self.train.gather(&idx, x, y);
    }

    /// Deterministic validation batches (chunked, in order).
    pub fn val_batches(&self, m: usize) -> Vec<(Vec<f32>, Vec<i32>)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i + m <= self.val.len() {
            let idx: Vec<usize> = (i..i + m).collect();
            let (mut x, mut y) = (Vec::new(), Vec::new());
            self.val.gather(&idx, &mut x, &mut y);
            out.push((x, y));
            i += m;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_sizes() {
        let p = DataPipeline::build(50, 20, 8, 10, 2, 0);
        assert_eq!(p.train.len(), 100);
        assert_eq!(p.val.len(), 20);
    }

    #[test]
    fn infinite_iterator_covers_all_indices_each_epoch() {
        let mut p = DataPipeline::build(25, 5, 8, 5, 1, 0);
        let mut seen = vec![0usize; 25];
        for _ in 0..5 {
            for &i in &p.next_indices(5) {
                seen[i] += 1;
            }
        }
        // one full epoch: every index exactly once
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
        assert_eq!(p.epoch(), 1);
    }

    #[test]
    fn epoch_reshuffles() {
        let mut p = DataPipeline::build(32, 5, 8, 4, 1, 3);
        let e1: Vec<usize> = (0..4).flat_map(|_| p.next_indices(8)).collect();
        let e2: Vec<usize> = (0..4).flat_map(|_| p.next_indices(8)).collect();
        assert_ne!(e1, e2);
        let mut s1 = e1.clone();
        let mut s2 = e2.clone();
        s1.sort_unstable();
        s2.sort_unstable();
        assert_eq!(s1, s2); // same index set
    }

    #[test]
    fn val_batches_chunk_correctly() {
        let p = DataPipeline::build(20, 17, 8, 10, 1, 0);
        let vb = p.val_batches(5);
        assert_eq!(vb.len(), 3); // 17 / 5 = 3 full batches
        assert_eq!(vb[0].0.len(), 5 * 3 * 8 * 8);
        assert_eq!(vb[0].1.len(), 5);
    }

    #[test]
    fn batch_buffer_layout() {
        let mut p = DataPipeline::build(10, 5, 8, 10, 1, 0);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        p.next_batch(4, &mut x, &mut y);
        assert_eq!(x.len(), 4 * 3 * 8 * 8);
        assert_eq!(y.len(), 4);
        assert!(y.iter().all(|&l| (0..10).contains(&l)));
    }
}
