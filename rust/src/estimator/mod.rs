//! Pluggable gradient estimators (DESIGN.md ADR-005).
//!
//! The paper's central object — an unbiased per-micro-batch gradient
//! estimate built from a cheap predicted gradient and an occasional true
//! gradient (eq. 1) — is a *policy*, not a training loop. This module
//! makes that policy a first-class seam: [`GradientEstimator`] decides,
//! per optimizer update, how a micro-batch slot splits into control and
//! prediction parts ([`UpdatePlan`]), whether the predictor participates,
//! and how the slot's gradients combine. The session
//! (`crate::session::TrainSession`) stays estimator-agnostic: it
//! scatters slots over the shard workers, reduces them in fixed order
//! (ADR-004), and steps the optimizer.
//!
//! Three estimators ship:
//!
//! - [`TrueBackprop`] — Algorithm 2: full Forward+Backward on every
//!   example; the vanilla baseline.
//! - [`ControlVariate`] — Algorithm 1 (GPR): eq. (1),
//!   `g = f·g_ct + (1−f)(g_p − (g_cp − g_ct))`, unbiased for any
//!   predictor quality (Lemma 1). Optionally retunes f online via the
//!   Theorem-4 controller ([`adaptive::AdaptiveF`]) and can route the
//!   combine through the `cv_combine` device artifact.
//! - [`PredictedLgp`] — the naive blend `f·g_ct + (1−f)·g_p` *without*
//!   the control-variate correction: biased whenever the predictor is,
//!   shipped as the ablation the paper argues against (Sec. 3).
//!
//! Two related-work estimators complete the zoo (ADR-006):
//!
//! - [`MultiTangentForward`] — forward-gradient estimation (PAPERS.md,
//!   arXiv 2410.17764): project the true gradient onto K seeded tangent
//!   directions and average, `ĝ = (1/K) Σ_k (v_k·g) v_k` with
//!   `v_k ~ N(0, I)`. Unbiased because `E[v vᵀ] = I`; backward-free.
//! - [`NeuralControlVariate`] — a small learned predictor (PAPERS.md,
//!   arXiv 1806.00159) fit on the same `FitBuffer` stream as the linear
//!   one, combined through the *same* eq.-(1) correction — Lemma 1 makes
//!   the estimate unbiased regardless of the network's quality.
//!
//! Further estimator families implement the same trait without touching
//! the training loop.

pub mod adaptive;
pub mod combine;
pub mod forward;
pub mod neural;
pub mod testbed;

use crate::metrics::Alignment;
use crate::model::manifest::Manifest;
use crate::model::params::FlatGrad;
use crate::predictor::fit::{FitBuffer, FitReport};
use crate::runtime::Runtime;
use crate::tensor::{Backend, Workspace};

pub use adaptive::AdaptiveF;
pub use forward::MultiTangentForward;
pub use neural::NeuralControlVariate;

/// Per-update execution plan an estimator hands the executor: how each
/// micro-batch slot splits and whether the predictor runs. Snapshotted
/// once per optimizer update, so every shard agrees (ADR-004).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UpdatePlan {
    /// Examples per slot taking the true Forward+Backward (control part).
    pub mc: usize,
    /// Examples per slot taking CheapForward + predictor (prediction part).
    pub mp: usize,
    /// Whether the predictor participates this update (requires a fitted
    /// predictor and `mp > 0`); when false the slot degenerates to the
    /// control gradient — still unbiased.
    pub use_pred: bool,
    /// Effective control fraction `mc / (mc + mp)` used by the combine
    /// (quantization-corrected).
    pub f_eff: f32,
}

impl UpdatePlan {
    /// Stream positions one micro-batch slot consumes. The prediction
    /// batch is only drawn when the predictor runs — the same consumption
    /// rule on every shard count, so slot offsets are deterministic.
    pub fn consumed_per_slot(&self) -> usize {
        self.mc + if self.use_pred { self.mp } else { 0 }
    }

    /// Full micro-batch size `m = mc + mp`.
    pub fn micro_batch(&self) -> usize {
        self.mc + self.mp
    }
}

/// Context a combine may use: host combines ignore it, device combines
/// route through the runtime's `cv_combine` artifact. Host-only harnesses
/// (the estimator testbed, unbiasedness tests) pass `rt: None`; a device
/// combine invoked without a runtime fails loudly instead of silently
/// degrading.
pub struct CombineCx<'a> {
    pub rt: Option<&'a Runtime>,
}

/// One micro-batch of host-side activations a host predictor consumes:
/// trunk outputs `a` (m, width), softmax probabilities (m, classes),
/// labels, and the current head weights (width, classes row-major) needed
/// to backpropagate residuals into the NTK feature `h`.
pub struct PredictInput<'a> {
    pub a: &'a [f32],
    pub probs: &'a [f32],
    pub y: &'a [i32],
    pub head_w: &'a [f32],
    pub m: usize,
    pub width: usize,
    pub classes: usize,
    pub smoothing: f32,
}

/// A pluggable gradient-estimation policy (ADR-005).
///
/// Implementations must be `Send + Sync`: [`combine`](Self::combine) is
/// called concurrently from shard worker threads through a shared `&dyn`
/// reference. All mutation (adaptive retuning) happens through
/// [`observe_alignment`](Self::observe_alignment), which the session
/// calls serially between updates.
pub trait GradientEstimator: Send + Sync {
    /// Short stable identifier, e.g. for logs and bench labels.
    fn name(&self) -> &'static str;

    /// Control fraction f ∈ (0, 1] currently in effect (1.0 when every
    /// example takes the true backward pass). Drives artifact selection
    /// and the φ(f) column of the log.
    fn f(&self) -> f64;

    /// Whether this estimator ever consults the linear gradient
    /// predictor. Gates refit scheduling and predictor uploads.
    fn uses_predictor(&self) -> bool;

    /// One-time hook after the runtime manifest is loaded: validate
    /// parameters and capture manifest facts (e.g. the admissible control
    /// fractions for the adaptive controller).
    fn bind(&mut self, man: &Manifest) -> anyhow::Result<()> {
        let _ = man;
        Ok(())
    }

    /// Build this update's plan. `predictor_fitted` reports whether at
    /// least one refit has installed predictor state.
    fn plan(&self, man: &Manifest, predictor_fitted: bool) -> UpdatePlan;

    /// Combine one slot's gradients. `g` holds the control gradient
    /// `g_ct` on entry and the estimate on return; `g_cp`/`g_p` are the
    /// predictor's outputs on the control and prediction parts. Called
    /// once per slot when `plan.use_pred`; must be deterministic and —
    /// on the host path — allocation-free (ADR-003).
    fn combine(
        &self,
        cx: &CombineCx,
        g: &mut FlatGrad,
        g_cp: &FlatGrad,
        g_p: &FlatGrad,
        f_eff: f32,
    ) -> anyhow::Result<()>;

    /// Alignment feedback after each predictor refit. Returns
    /// `Some(new_f)` when the estimator retuned its control fraction.
    fn observe_alignment(&mut self, align: Option<Alignment>) -> Option<f64> {
        let _ = align;
        None
    }

    /// Control fractions whose artifacts should be pre-compiled by
    /// warm-up (an adaptive estimator may visit every lowered fraction).
    fn warmup_fractions(&self, man: &Manifest) -> Vec<f64> {
        let _ = man;
        vec![self.f()]
    }

    /// Post-process a slot's *control-only* gradient (called when
    /// `plan.use_pred` is false, before reduction). `slot_seed` is the
    /// slot's stream position — a pure function of the data cursor, so the
    /// transform is bit-identical at every shard count (ADR-004). The
    /// default is the identity; [`MultiTangentForward`] replaces the exact
    /// gradient with its tangent-projected estimate here.
    fn transform_control(&self, g: &mut FlatGrad, slot_seed: u64) {
        let _ = (g, slot_seed);
    }

    /// Fraction of examples that take a true backward pass — the cost
    /// axis of the paper's variance/cost trade-off. Defaults to `f()`;
    /// backward-free estimators report 0.
    fn backward_fraction(&self) -> f64 {
        self.f()
    }

    /// Whether predictions come from [`host_predict`](Self::host_predict)
    /// instead of the device predictor artifact. Host predictors skip the
    /// predictor upload and the device `predict_grad` calls.
    fn host_predictor(&self) -> bool {
        false
    }

    /// Predict one micro-batch's mean gradient on the host, writing into
    /// `out`. Only called when [`host_predictor`](Self::host_predictor)
    /// is true; must be deterministic.
    fn host_predict(&self, input: &PredictInput, out: &mut FlatGrad) -> anyhow::Result<()> {
        let _ = (input, out);
        anyhow::bail!("estimator '{}' has no host predictor", self.name())
    }

    /// Whether this estimator fits its *own* predictor state from the
    /// FitBuffer instead of sharing the session's linear predictor.
    fn owns_predictor_fit(&self) -> bool {
        false
    }

    /// Fit the estimator's own predictor from the collected (gradient,
    /// activation) stream. Only called when
    /// [`owns_predictor_fit`](Self::owns_predictor_fit) is true.
    fn fit_own(
        &mut self,
        be: Backend,
        buf: &FitBuffer,
        lambda: f32,
        ws: &mut Workspace,
    ) -> anyhow::Result<FitReport> {
        let _ = (be, buf, lambda, ws);
        anyhow::bail!("estimator '{}' does not fit its own predictor", self.name())
    }

    /// Whether the predictor this estimator consults is ready.
    /// `linear_fits` is the session's shared linear-predictor fit count;
    /// estimators owning their fit override this with their own state.
    fn predictor_ready(&self, linear_fits: usize) -> bool {
        linear_fits > 0
    }

    /// Serialize checkpointable estimator state (ADR-008): everything a
    /// resumed run needs for the estimator to be *the same estimator* —
    /// the adaptive-f controller position, the NCV network and fit count,
    /// the current control fraction. Stateless estimators return empty.
    fn save_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore state written by [`save_state`](Self::save_state). Called
    /// after [`bind`](Self::bind), so manifest-derived structures exist.
    /// The default accepts only an empty payload — a stateless estimator
    /// handed bytes is a checkpoint/config mismatch, not a no-op.
    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        anyhow::ensure!(
            bytes.is_empty(),
            "estimator '{}' carries no checkpoint state but the checkpoint has {} bytes",
            self.name(),
            bytes.len()
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// TrueBackprop — Algorithm 2
// ---------------------------------------------------------------------------

/// The vanilla baseline: every example takes the full Forward+Backward;
/// the predictor never runs. Equivalent to [`ControlVariate`] at f = 1
/// (eq. 1 collapses to the true gradient), but skips the predictor
/// machinery entirely.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrueBackprop;

impl GradientEstimator for TrueBackprop {
    fn name(&self) -> &'static str {
        "true-backprop"
    }

    fn f(&self) -> f64 {
        1.0
    }

    fn uses_predictor(&self) -> bool {
        false
    }

    fn plan(&self, man: &Manifest, _predictor_fitted: bool) -> UpdatePlan {
        UpdatePlan { mc: man.micro_batch, mp: 0, use_pred: false, f_eff: 1.0 }
    }

    fn combine(
        &self,
        _cx: &CombineCx,
        _g: &mut FlatGrad,
        _g_cp: &FlatGrad,
        _g_p: &FlatGrad,
        _f_eff: f32,
    ) -> anyhow::Result<()> {
        // Never reached: plan().use_pred is always false.
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// ControlVariate — Algorithm 1 (GPR)
// ---------------------------------------------------------------------------

/// The paper's estimator: eq. (1) over a (control, prediction) split of
/// each micro-batch. Unbiased for any predictor (Lemma 1); the variance
/// inflation φ(f, ρ, κ) and the compute ratio γ(f) govern when it beats
/// vanilla (Theorem 3).
#[derive(Clone, Debug)]
pub struct ControlVariate {
    f: f64,
    device_combine: bool,
    adaptive_requested: bool,
    adaptive: Option<AdaptiveF>,
}

impl ControlVariate {
    /// Estimator with control fraction `f` (paper headline: 1/4),
    /// host-side combine, no adaptive retuning.
    pub fn new(f: f64) -> ControlVariate {
        ControlVariate { f, device_combine: false, adaptive_requested: false, adaptive: None }
    }

    /// Enable the Theorem-4 online controller: after each refit, steer f
    /// toward the quantized f*(ρ̂, κ̂) among the manifest's lowered
    /// fractions.
    pub fn with_adaptive(mut self, on: bool) -> ControlVariate {
        self.adaptive_requested = on;
        self
    }

    /// Route eq. (1) through the `cv_combine` pallas artifact instead of
    /// the fused host loop (4 extra device round-trips; exercises the
    /// full L1 path).
    pub fn with_device_combine(mut self, on: bool) -> ControlVariate {
        self.device_combine = on;
        self
    }

    /// Whether the adaptive controller is active.
    pub fn is_adaptive(&self) -> bool {
        self.adaptive.is_some() || self.adaptive_requested
    }
}

impl GradientEstimator for ControlVariate {
    fn name(&self) -> &'static str {
        "control-variate"
    }

    fn f(&self) -> f64 {
        self.f
    }

    fn uses_predictor(&self) -> bool {
        true
    }

    fn bind(&mut self, man: &Manifest) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.f > 0.0 && self.f <= 1.0,
            "control fraction f must be in (0,1], got {}",
            self.f
        );
        if self.adaptive_requested && self.adaptive.is_none() {
            self.adaptive = Some(AdaptiveF::new(man.fs.clone(), self.f));
        }
        Ok(())
    }

    fn plan(&self, man: &Manifest, predictor_fitted: bool) -> UpdatePlan {
        let (mc, mp) = man.split_sizes(self.f);
        UpdatePlan {
            mc,
            mp,
            use_pred: predictor_fitted && mp > 0,
            f_eff: mc as f32 / man.micro_batch as f32,
        }
    }

    fn combine(
        &self,
        cx: &CombineCx,
        g: &mut FlatGrad,
        g_cp: &FlatGrad,
        g_p: &FlatGrad,
        f_eff: f32,
    ) -> anyhow::Result<()> {
        if self.device_combine {
            let rt = cx
                .rt
                .ok_or_else(|| anyhow::anyhow!("device combine requires a runtime in CombineCx"))?;
            let v = rt.cv_combine(&g.concat(), &g_cp.concat(), &g_p.concat(), f_eff)?;
            *g = FlatGrad::from_concat(&v, g.trunk.len(), g.head_w.len());
        } else {
            // eq. (1) fused in place over the control-gradient buffers:
            // one pass, no fresh allocation (ADR-003).
            combine::cv_combine_into(g, g_cp, g_p, f_eff);
        }
        Ok(())
    }

    fn observe_alignment(&mut self, align: Option<Alignment>) -> Option<f64> {
        let ctl = self.adaptive.as_mut()?;
        let new_f = ctl.update(align);
        if (new_f - self.f).abs() > 1e-12 {
            self.f = new_f;
            Some(new_f)
        } else {
            None
        }
    }

    fn warmup_fractions(&self, man: &Manifest) -> Vec<f64> {
        if self.is_adaptive() {
            // The controller may visit every lowered fraction.
            man.fs.clone()
        } else {
            vec![self.f]
        }
    }

    fn save_state(&self) -> Vec<u8> {
        let mut e = crate::checkpoint::Enc::new();
        e.put_f64(self.f);
        match &self.adaptive {
            Some(ctl) => {
                e.put_bool(true);
                e.put_f64(ctl.current);
                e.put_u64(ctl.switches as u64);
            }
            None => e.put_bool(false),
        }
        e.into_bytes()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut d = crate::checkpoint::Dec::new(bytes, "control-variate state");
        let f = d.take_f64()?;
        anyhow::ensure!(
            f > 0.0 && f <= 1.0,
            "checkpointed control fraction {f} out of range (0,1]"
        );
        self.f = f;
        if d.take_bool()? {
            let current = d.take_f64()?;
            let switches = d.take_u64()? as usize;
            let ctl = self.adaptive.as_mut().ok_or_else(|| {
                anyhow::anyhow!(
                    "checkpoint carries adaptive-f state but this session was built \
                     without adaptive_f"
                )
            })?;
            ctl.current = current;
            ctl.switches = switches;
        } else {
            anyhow::ensure!(
                self.adaptive.is_none(),
                "this session enables adaptive_f but the checkpoint has no controller state"
            );
        }
        d.finish()
    }
}

// ---------------------------------------------------------------------------
// PredictedLgp — the no-control-variate ablation
// ---------------------------------------------------------------------------

/// Linear gradient prediction *without* the control-variate correction:
/// `g = f·g_ct + (1−f)·g_p`. Biased whenever `E[g_p] ≠ ∇F` — this is the
/// estimator the paper's Section 3 argues against, shipped so the bias
/// is measurable on this testbed rather than asserted.
#[derive(Clone, Copy, Debug)]
pub struct PredictedLgp {
    f: f64,
}

impl PredictedLgp {
    pub fn new(f: f64) -> PredictedLgp {
        PredictedLgp { f }
    }
}

impl GradientEstimator for PredictedLgp {
    fn name(&self) -> &'static str {
        "predicted-lgp"
    }

    fn f(&self) -> f64 {
        self.f
    }

    fn uses_predictor(&self) -> bool {
        true
    }

    fn bind(&mut self, _man: &Manifest) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.f > 0.0 && self.f <= 1.0,
            "control fraction f must be in (0,1], got {}",
            self.f
        );
        Ok(())
    }

    fn plan(&self, man: &Manifest, predictor_fitted: bool) -> UpdatePlan {
        let (mc, mp) = man.split_sizes(self.f);
        UpdatePlan {
            mc,
            mp,
            use_pred: predictor_fitted && mp > 0,
            f_eff: mc as f32 / man.micro_batch as f32,
        }
    }

    fn combine(
        &self,
        _cx: &CombineCx,
        g: &mut FlatGrad,
        _g_cp: &FlatGrad,
        g_p: &FlatGrad,
        f_eff: f32,
    ) -> anyhow::Result<()> {
        combine::blend_into(g, g_p, f_eff);
        Ok(())
    }

    fn save_state(&self) -> Vec<u8> {
        let mut e = crate::checkpoint::Enc::new();
        e.put_f64(self.f);
        e.into_bytes()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut d = crate::checkpoint::Dec::new(bytes, "predicted-lgp state");
        let f = d.take_f64()?;
        anyhow::ensure!(
            f > 0.0 && f <= 1.0,
            "checkpointed control fraction {f} out of range (0,1]"
        );
        self.f = f;
        d.finish()
    }
}

/// Test-only manifest literal shared by the estimator submodule tests.
#[cfg(test)]
pub(crate) fn tests_manifest(micro_batch: usize, fs: Vec<f64>) -> Manifest {
    use crate::model::manifest::TrunkParam;
    use std::collections::BTreeMap;
    let trunk_params = 24;
    Manifest {
        dir: ".".into(),
        preset: "estimator-test".into(),
        image: 4,
        classes: 3,
        width: 4,
        label_smoothing: 0.0,
        rank: 2,
        n_chunk: 4,
        n_fit: 8,
        feat_dim: 4,
        trunk_params,
        total_params: trunk_params + 4 * 3 + 3,
        micro_batch,
        fs,
        val_batch: 8,
        trunk_layout: vec![TrunkParam {
            name: "w".into(),
            shape: vec![6, 4],
            offset: 0,
            len: trunk_params,
            muon: true,
        }],
        artifacts: BTreeMap::new(),
        init_trunk: ".".into(),
        init_head_w: ".".into(),
        init_head_b: ".".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest(micro_batch: usize, fs: Vec<f64>) -> Manifest {
        tests_manifest(micro_batch, fs)
    }

    #[test]
    fn true_backprop_plans_full_control() {
        let man = manifest(8, vec![0.25]);
        let plan = TrueBackprop.plan(&man, true);
        assert_eq!(plan, UpdatePlan { mc: 8, mp: 0, use_pred: false, f_eff: 1.0 });
        assert_eq!(plan.consumed_per_slot(), 8);
        assert_eq!(plan.micro_batch(), 8);
    }

    #[test]
    fn control_variate_plan_gates_on_fit_and_split() {
        let man = manifest(8, vec![0.25]);
        let est = ControlVariate::new(0.25);
        let unfitted = est.plan(&man, false);
        assert_eq!((unfitted.mc, unfitted.mp), (2, 6));
        assert!(!unfitted.use_pred);
        // prediction draw only happens when the predictor runs
        assert_eq!(unfitted.consumed_per_slot(), 2);
        let fitted = est.plan(&man, true);
        assert!(fitted.use_pred);
        assert_eq!(fitted.consumed_per_slot(), 8);
        assert!((fitted.f_eff - 0.25).abs() < 1e-6);
        // f = 1 never uses the predictor even when fitted
        let full = ControlVariate::new(1.0).plan(&man, true);
        assert!(!full.use_pred);
        assert_eq!(full.mc, 8);
    }

    #[test]
    fn bind_rejects_out_of_range_f() {
        let man = manifest(8, vec![0.25]);
        assert!(ControlVariate::new(0.0).bind(&man).is_err());
        assert!(ControlVariate::new(1.5).bind(&man).is_err());
        assert!(PredictedLgp::new(-0.1).bind(&man).is_err());
        assert!(ControlVariate::new(0.25).bind(&man).is_ok());
    }

    #[test]
    fn adaptive_bind_captures_manifest_fractions() {
        let man = manifest(8, vec![0.125, 0.25, 0.5]);
        let mut est = ControlVariate::new(0.25).with_adaptive(true);
        est.bind(&man).unwrap();
        assert_eq!(est.warmup_fractions(&man), vec![0.125, 0.25, 0.5]);
        // Strong alignment: the controller must not raise f, and a change
        // is reported back so the session can log it.
        let good = Alignment { rho: 0.97, kappa: 1.0, sigma_g: 1.0, sigma_h: 1.0, n: 64 };
        if let Some(new_f) = est.observe_alignment(Some(good)) {
            assert!(new_f <= 0.25);
            assert_eq!(est.f(), new_f);
        } else {
            assert_eq!(est.f(), 0.25);
        }
    }

    #[test]
    fn non_adaptive_never_retunes() {
        let man = manifest(8, vec![0.125, 0.25]);
        let mut est = ControlVariate::new(0.25);
        est.bind(&man).unwrap();
        let a = Alignment { rho: 0.99, kappa: 1.0, sigma_g: 1.0, sigma_h: 1.0, n: 64 };
        assert_eq!(est.observe_alignment(Some(a)), None);
        assert_eq!(est.f(), 0.25);
        assert_eq!(est.warmup_fractions(&man), vec![0.25]);
    }

    #[test]
    fn predicted_lgp_blends_without_correction() {
        let g_ct = FlatGrad { trunk: vec![2.0, 4.0], head_w: vec![2.0], head_b: vec![2.0] };
        let g_cp = FlatGrad { trunk: vec![9.0, 9.0], head_w: vec![9.0], head_b: vec![9.0] };
        let g_p = FlatGrad { trunk: vec![6.0, 8.0], head_w: vec![6.0], head_b: vec![6.0] };
        let mut g = g_ct.clone();
        // CombineCx is only consulted by device combines; PredictedLgp is
        // host-only, so a runtime is not needed here — call blend directly
        // through the trait-free path.
        combine::blend_into(&mut g, &g_p, 0.25);
        assert_eq!(g.trunk, vec![0.25 * 2.0 + 0.75 * 6.0, 0.25 * 4.0 + 0.75 * 8.0]);
        // Unlike eq. (1), g_cp plays no role — the estimator is biased by
        // exactly the predictor's bias.
        let mut g2 = g_ct.clone();
        combine::cv_combine_into(&mut g2, &g_cp, &g_p, 0.25);
        assert_ne!(g.trunk, g2.trunk);
    }
}
