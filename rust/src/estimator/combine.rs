//! The control-variate combine (paper eq. 1), the naive blend it is
//! compared against, and the micro-batch split — the pure functions at
//! the heart of the estimators, kept separate so property tests can
//! hammer them without a runtime.

use crate::model::params::FlatGrad;

/// eq. (1):  g = f·g_ct + (1−f)·(g_p − (g_cp − g_ct)).
///
/// Unbiased (Lemma 1): E[g_cp] = E[g_p] ⇒ E[g] = E[g_ct] = ∇F.
/// Allocating convenience over [`cv_combine_into`].
pub fn cv_combine(g_ct: &FlatGrad, g_cp: &FlatGrad, g_p: &FlatGrad, f: f32) -> FlatGrad {
    let mut out = g_ct.clone();
    cv_combine_into(&mut out, g_cp, g_p, f);
    out
}

/// eq. (1) fused in place over the control-gradient buffers: since
/// f·g_ct + (1−f)·(g_p − (g_cp − g_ct)) = g_ct + (1−f)·(g_p − g_cp),
/// the combine is a single axpy-style pass over each preallocated
/// gradient slab — no temporaries, no allocation (ADR-003). `g` holds
/// g_ct on entry and the combined gradient on return.
pub fn cv_combine_into(g: &mut FlatGrad, g_cp: &FlatGrad, g_p: &FlatGrad, f: f32) {
    let w = 1.0 - f;
    let apply = |o: &mut [f32], cp: &[f32], p: &[f32]| {
        debug_assert_eq!(o.len(), cp.len());
        debug_assert_eq!(o.len(), p.len());
        for ((ov, cv), pv) in o.iter_mut().zip(cp).zip(p) {
            *ov += w * (pv - cv);
        }
    };
    apply(&mut g.trunk, &g_cp.trunk, &g_p.trunk);
    apply(&mut g.head_w, &g_cp.head_w, &g_p.head_w);
    apply(&mut g.head_b, &g_cp.head_b, &g_p.head_b);
}

/// The naive blend WITHOUT the control-variate correction:
/// g = f·g_ct + (1−f)·g_p, in place over the control-gradient buffers.
/// Biased by exactly the predictor's bias — this is
/// [`PredictedLgp`](super::PredictedLgp)'s combine, shipped as the
/// ablation eq. (1) improves on (paper Sec. 3).
pub fn blend_into(g: &mut FlatGrad, g_p: &FlatGrad, f: f32) {
    let w = 1.0 - f;
    let apply = |o: &mut [f32], p: &[f32]| {
        debug_assert_eq!(o.len(), p.len());
        for (ov, pv) in o.iter_mut().zip(p) {
            *ov = f * *ov + w * pv;
        }
    };
    apply(&mut g.trunk, &g_p.trunk);
    apply(&mut g.head_w, &g_p.head_w);
    apply(&mut g.head_b, &g_p.head_b);
}

/// Split a micro-batch index list into (control, prediction) parts with
/// |control| = max(1, round(f·m)). The two parts partition the input —
/// checked by the proptests.
pub fn split_indices(idx: &[usize], f: f64) -> (Vec<usize>, Vec<usize>) {
    let m = idx.len();
    let mc = ((f * m as f64).round() as usize).clamp(1, m);
    (idx[..mc].to_vec(), idx[mc..].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fg(v: &[f32]) -> FlatGrad {
        FlatGrad { trunk: v.to_vec(), head_w: vec![v[0]; 2], head_b: vec![v[0]] }
    }

    #[test]
    fn f_one_recovers_true_gradient() {
        let g = cv_combine(&fg(&[1.0, 2.0]), &fg(&[9.0, 9.0]), &fg(&[5.0, 5.0]), 1.0);
        assert_eq!(g.trunk, vec![1.0, 2.0]);
    }

    #[test]
    fn perfect_predictor_blends_plainly() {
        // g_cp == g_ct ⇒ g = f g_ct + (1-f) g_p.
        let ct = fg(&[2.0, 4.0]);
        let p = fg(&[6.0, 8.0]);
        let g = cv_combine(&ct, &ct, &p, 0.25);
        assert_eq!(g.trunk, vec![0.25 * 2.0 + 0.75 * 6.0, 0.25 * 4.0 + 0.75 * 8.0]);
    }

    #[test]
    fn zero_predictor_reduces_to_control_gradient() {
        let ct = fg(&[3.0, -1.0]);
        let z = fg(&[0.0, 0.0]);
        let g = cv_combine(&ct, &z, &z, 0.25);
        // f·ct + (1-f)·(0 − (0 − ct)) = ct
        assert_eq!(g.trunk, ct.trunk);
    }

    #[test]
    fn in_place_combine_matches_formula() {
        let ct = fg(&[2.0, -3.0]);
        let cp = fg(&[1.0, 1.0]);
        let p = fg(&[5.0, 0.0]);
        let f = 0.25f32;
        let mut g = ct.clone();
        cv_combine_into(&mut g, &cp, &p, f);
        for i in 0..2 {
            let want = f * ct.trunk[i] + (1.0 - f) * (p.trunk[i] - (cp.trunk[i] - ct.trunk[i]));
            assert!((g.trunk[i] - want).abs() < 1e-6, "{} vs {want}", g.trunk[i]);
        }
        // and the allocating wrapper agrees with the in-place pass
        let g2 = cv_combine(&ct, &cp, &p, f);
        assert_eq!(g.trunk, g2.trunk);
        assert_eq!(g.head_w, g2.head_w);
        assert_eq!(g.head_b, g2.head_b);
    }

    #[test]
    fn blend_matches_formula_and_drops_correction() {
        let ct = fg(&[2.0, -3.0]);
        let p = fg(&[5.0, 0.0]);
        let f = 0.25f32;
        let mut g = ct.clone();
        blend_into(&mut g, &p, f);
        for i in 0..2 {
            let want = f * ct.trunk[i] + (1.0 - f) * p.trunk[i];
            assert!((g.trunk[i] - want).abs() < 1e-6, "{} vs {want}", g.trunk[i]);
        }
        // When the predictor is exact on the control batch (g_cp == g_ct)
        // the two estimators coincide — eq. (1)'s correction vanishes.
        let g_cv = cv_combine(&ct, &ct, &p, f);
        assert_eq!(g.trunk, g_cv.trunk);
    }

    #[test]
    fn blend_at_f_one_is_the_control_gradient() {
        let ct = fg(&[4.0, 7.0]);
        let p = fg(&[-1.0, 2.0]);
        let mut g = ct.clone();
        blend_into(&mut g, &p, 1.0);
        assert_eq!(g.trunk, ct.trunk);
    }

    #[test]
    fn split_partitions() {
        let idx: Vec<usize> = (0..16).collect();
        let (c, p) = split_indices(&idx, 0.25);
        assert_eq!(c.len(), 4);
        assert_eq!(p.len(), 12);
        let mut all = c.clone();
        all.extend(&p);
        assert_eq!(all, idx);
    }

    #[test]
    fn split_never_empty_control() {
        let idx: Vec<usize> = (0..8).collect();
        let (c, _) = split_indices(&idx, 0.001);
        assert_eq!(c.len(), 1);
        let (c, p) = split_indices(&idx, 1.0);
        assert_eq!(c.len(), 8);
        assert!(p.is_empty());
    }
}
