//! Host estimator testbed: a seeded two-layer linear-softmax workload
//! every [`GradientEstimator`] can run end-to-end *without* the PJRT
//! runtime or AOT artifacts.
//!
//! The model mirrors the real session's structure exactly where the
//! estimator seam cares:
//!
//! - trunk `a = W_t x` with `W_t` (width, feat) row-major — the trunk
//!   gradient `h xᵀ` lands in the same layout a `TrunkParam` describes;
//! - linear head `logits = W_aᵀ a + b` with `head_w` (width, classes)
//!   row-major — residual backprop `h = W_a r` is bit-for-bit the
//!   [`Predictor::backprop_features`] feature, so the NTK predictor fits
//!   this model natively;
//! - softmax cross-entropy with the same fixed accumulation order as the
//!   `shard_determinism` host model, so every quantity is a pure bitwise
//!   function of (parameters, example index).
//!
//! [`Testbed::slot_estimate`] mirrors the shard worker's `run_micro`
//! (control grad → `transform_control` / predictor split → eq.-(1)
//! combine) against this host model, which is what lets the
//! `estimator_sweep` example, the statistical unbiasedness suite and the
//! zoo-wide shard-determinism test drive all five estimators on stub-only
//! hosts.

use super::{CombineCx, GradientEstimator, PredictInput, UpdatePlan};
use crate::model::manifest::{Manifest, TrunkParam};
use crate::model::params::FlatGrad;
use crate::predictor::fit::FitBuffer;
use crate::predictor::{residuals, Predictor};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;

/// Seeded population + model parameters for the host workload.
pub struct Testbed {
    pub feat: usize,
    pub width: usize,
    pub classes: usize,
    /// Population size; batches sample indices in `[0, n)`.
    pub n: usize,
    /// Inputs, (n, feat) row-major.
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    /// Trunk weights W_t, (width, feat) row-major.
    pub trunk: Vec<f32>,
    /// Head weights W_a, (width, classes) row-major.
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

impl Testbed {
    /// Build a seeded population and initialize the model.
    pub fn new(seed: u64, n: usize, feat: usize, width: usize, classes: usize) -> Testbed {
        let mut rng = Pcg64::seeded(seed);
        let mut x = vec![0.0f32; n * feat];
        rng.fill_normal(&mut x, 1.0);
        let y: Vec<i32> = (0..n).map(|_| rng.below(classes as u64) as i32).collect();
        let mut trunk = vec![0.0f32; width * feat];
        rng.fill_normal(&mut trunk, (1.0 / feat as f32).sqrt());
        let mut head_w = vec![0.0f32; width * classes];
        rng.fill_normal(&mut head_w, (1.0 / width as f32).sqrt());
        let mut head_b = vec![0.0f32; classes];
        rng.fill_normal(&mut head_b, 0.01);
        Testbed { feat, width, classes, n, x, y, trunk, head_w, head_b }
    }

    /// Trunk parameter count P_T = width × feat.
    pub fn trunk_params(&self) -> usize {
        self.width * self.feat
    }

    /// A manifest describing this model, shaped like the estimator-test
    /// literal: enough for `bind`/`plan` and the predictor dimensions.
    pub fn manifest(&self, micro_batch: usize, rank: usize) -> Manifest {
        let trunk_params = self.trunk_params();
        Manifest {
            dir: ".".into(),
            preset: "estimator-testbed".into(),
            image: 4,
            classes: self.classes,
            width: self.width,
            label_smoothing: 0.0,
            rank,
            n_chunk: 4,
            n_fit: 64,
            feat_dim: self.feat,
            trunk_params,
            total_params: trunk_params + self.width * self.classes + self.classes,
            micro_batch,
            fs: vec![0.25],
            val_batch: 8,
            trunk_layout: vec![TrunkParam {
                name: "w".into(),
                shape: vec![self.width, self.feat],
                offset: 0,
                len: trunk_params,
                muon: true,
            }],
            artifacts: BTreeMap::new(),
            init_trunk: ".".into(),
            init_head_w: ".".into(),
            init_head_b: ".".into(),
        }
    }

    /// Zero gradient with this model's segment sizes.
    pub fn zero_grad(&self) -> FlatGrad {
        FlatGrad {
            trunk: vec![0.0; self.trunk_params()],
            head_w: vec![0.0; self.width * self.classes],
            head_b: vec![0.0; self.classes],
        }
    }

    /// Forward one example: trunk activations (width) and softmax
    /// probabilities (classes). Fixed accumulation order.
    pub fn forward(&self, idx: usize, a: &mut [f32], probs: &mut [f32]) -> f32 {
        let xj = &self.x[idx * self.feat..(idx + 1) * self.feat];
        for i in 0..self.width {
            let row = &self.trunk[i * self.feat..(i + 1) * self.feat];
            let mut s = 0.0f32;
            for (w, xv) in row.iter().zip(xj) {
                s += w * xv;
            }
            a[i] = s;
        }
        let c = self.classes;
        for k in 0..c {
            let mut s = self.head_b[k];
            for i in 0..self.width {
                s += self.head_w[i * c + k] * a[i];
            }
            probs[k] = s;
        }
        let mx = probs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut z = 0.0f32;
        for v in probs.iter_mut() {
            *v = (*v - mx).exp();
            z += *v;
        }
        for v in probs.iter_mut() {
            *v /= z;
        }
        let yj = self.y[idx] as usize;
        -(probs[yj].max(1e-30)).ln()
    }

    /// Exact per-example gradient and loss. `grad` is fully overwritten.
    pub fn example_grad(&self, idx: usize, grad: &mut FlatGrad) -> f32 {
        let (w, c) = (self.width, self.classes);
        let mut a = vec![0.0f32; w];
        let mut probs = vec![0.0f32; c];
        let loss = self.forward(idx, &mut a, &mut probs);
        let yj = self.y[idx] as usize;
        // residual r = p − onehot(y)
        let mut r = probs;
        r[yj] -= 1.0;
        // h = W_a r — the same feature the NTK predictor backprops.
        let xj = &self.x[idx * self.feat..(idx + 1) * self.feat];
        for i in 0..w {
            let mut h = 0.0f32;
            for (wv, rv) in self.head_w[i * c..(i + 1) * c].iter().zip(&r) {
                h += wv * rv;
            }
            let gr = &mut grad.trunk[i * self.feat..(i + 1) * self.feat];
            for (g, xv) in gr.iter_mut().zip(xj) {
                *g = h * xv;
            }
            let gw = &mut grad.head_w[i * c..(i + 1) * c];
            for (g, rv) in gw.iter_mut().zip(&r) {
                *g = a[i] * rv;
            }
        }
        grad.head_b.copy_from_slice(&r);
        loss
    }

    /// Mean gradient + mean loss over a batch of example indices, plus
    /// the batch activations/probabilities the predictors consume.
    pub fn batch_grad(&self, idxs: &[usize]) -> BatchOut {
        let m = idxs.len();
        let (w, c) = (self.width, self.classes);
        let mut out = BatchOut {
            grad: self.zero_grad(),
            loss: 0.0,
            a: vec![0.0; m * w],
            probs: vec![0.0; m * c],
            y: Vec::with_capacity(m),
        };
        let mut g = self.zero_grad();
        for (j, &idx) in idxs.iter().enumerate() {
            out.loss += self.example_grad(idx, &mut g);
            self.forward(idx, &mut out.a[j * w..(j + 1) * w], &mut out.probs[j * c..(j + 1) * c]);
            out.y.push(self.y[idx]);
            for (o, v) in out.grad.trunk.iter_mut().zip(&g.trunk) {
                *o += v;
            }
            for (o, v) in out.grad.head_w.iter_mut().zip(&g.head_w) {
                *o += v;
            }
            for (o, v) in out.grad.head_b.iter_mut().zip(&g.head_b) {
                *o += v;
            }
        }
        let inv = 1.0 / m as f32;
        out.grad.scale(inv);
        out.loss *= inv;
        out
    }

    /// Cheap forward of a batch (no gradients): activations, probs,
    /// labels — what the prediction split of a slot sees.
    pub fn batch_inputs(&self, idxs: &[usize]) -> BatchOut {
        let m = idxs.len();
        let (w, c) = (self.width, self.classes);
        let mut out = BatchOut {
            grad: FlatGrad { trunk: Vec::new(), head_w: Vec::new(), head_b: Vec::new() },
            loss: 0.0,
            a: vec![0.0; m * w],
            probs: vec![0.0; m * c],
            y: Vec::with_capacity(m),
        };
        for (j, &idx) in idxs.iter().enumerate() {
            out.loss +=
                self.forward(idx, &mut out.a[j * w..(j + 1) * w], &mut out.probs[j * c..(j + 1) * c]);
            out.y.push(self.y[idx]);
        }
        out.loss /= m as f32;
        out
    }

    /// Push each example's (trunk grad, a, h) onto the fit buffer — the
    /// same triple the session's refit collectors gather.
    pub fn fill_fit_buffer(&self, buf: &mut FitBuffer, idxs: &[usize]) {
        let (w, c) = (self.width, self.classes);
        let mut g = self.zero_grad();
        let mut a = vec![0.0f32; w];
        let mut probs = vec![0.0f32; c];
        let mut h = vec![0.0f32; w];
        for &idx in idxs {
            self.example_grad(idx, &mut g);
            self.forward(idx, &mut a, &mut probs);
            let yj = self.y[idx] as usize;
            let mut r = probs.clone();
            r[yj] -= 1.0;
            for i in 0..w {
                let mut s = 0.0f32;
                for (wv, rv) in self.head_w[i * c..(i + 1) * c].iter().zip(&r) {
                    s += wv * rv;
                }
                h[i] = s;
            }
            buf.push(&g.trunk, &a, &h);
        }
    }

    /// Host mirror of the device linear predictor on one batch: trunk
    /// from `predict_mean_trunk`, head from the exact closed form.
    pub fn linear_predict(&self, pred: &Predictor, batch: &BatchOut, out: &mut FlatGrad) {
        let m = batch.y.len();
        let (w, c) = (self.width, self.classes);
        let resid = residuals(&batch.probs, &batch.y, c, 0.0);
        let h = Predictor::backprop_features(&resid, &self.head_w, w);
        let a_t = Tensor::from_vec(batch.a.clone(), &[m, w]);
        out.trunk.copy_from_slice(&pred.predict_mean_trunk(&a_t, &h));
        let (gw, gb) = Predictor::head_grads(&a_t, &resid);
        out.head_w.copy_from_slice(&gw);
        out.head_b.copy_from_slice(&gb);
    }

    /// One slot's gradient estimate — the host mirror of the shard
    /// worker's `run_micro`: control gradient, then either the
    /// control-only transform or the (g_cp, g_p) predictor split and the
    /// estimator's combine. Pure function of (model, stream, pos), so it
    /// is bit-identical on every shard count.
    pub fn slot_estimate(
        &self,
        est: &dyn GradientEstimator,
        plan: &UpdatePlan,
        pred: &Predictor,
        stream: &[usize],
        pos: usize,
    ) -> anyhow::Result<(FlatGrad, f32)> {
        let ctrl_idx = &stream[pos..pos + plan.mc];
        let ctrl = self.batch_grad(ctrl_idx);
        let mut g = ctrl.grad;
        if !plan.use_pred {
            est.transform_control(&mut g, pos as u64);
            return Ok((g, ctrl.loss));
        }
        let pred_idx = &stream[pos + plan.mc..pos + plan.mc + plan.mp];
        let pbatch = self.batch_inputs(pred_idx);
        let mut g_cp = self.zero_grad();
        let mut g_p = self.zero_grad();
        if est.host_predictor() {
            est.host_predict(
                &PredictInput {
                    a: &ctrl.a,
                    probs: &ctrl.probs,
                    y: &ctrl.y,
                    head_w: &self.head_w,
                    m: plan.mc,
                    width: self.width,
                    classes: self.classes,
                    smoothing: 0.0,
                },
                &mut g_cp,
            )?;
            est.host_predict(
                &PredictInput {
                    a: &pbatch.a,
                    probs: &pbatch.probs,
                    y: &pbatch.y,
                    head_w: &self.head_w,
                    m: plan.mp,
                    width: self.width,
                    classes: self.classes,
                    smoothing: 0.0,
                },
                &mut g_p,
            )?;
        } else {
            self.linear_predict(pred, &ctrl, &mut g_cp);
            self.linear_predict(pred, &pbatch, &mut g_p);
        }
        est.combine(&CombineCx { rt: None }, &mut g, &g_cp, &g_p, plan.f_eff)?;
        Ok((g, ctrl.loss))
    }

    /// Plain SGD step over all three segments.
    pub fn sgd_step(&mut self, grad: &FlatGrad, lr: f32) {
        for (w, g) in self.trunk.iter_mut().zip(&grad.trunk) {
            *w -= lr * g;
        }
        for (w, g) in self.head_w.iter_mut().zip(&grad.head_w) {
            *w -= lr * g;
        }
        for (w, g) in self.head_b.iter_mut().zip(&grad.head_b) {
            *w -= lr * g;
        }
    }

    /// Exact population mean gradient — the ground truth μ = ∇F the
    /// unbiasedness suite tests against.
    pub fn population_grad(&self) -> FlatGrad {
        let idxs: Vec<usize> = (0..self.n).collect();
        self.batch_grad(&idxs).grad
    }

    /// Mean loss over the whole population.
    pub fn population_loss(&self) -> f32 {
        let mut a = vec![0.0f32; self.width];
        let mut p = vec![0.0f32; self.classes];
        let mut s = 0.0f32;
        for idx in 0..self.n {
            s += self.forward(idx, &mut a, &mut p);
        }
        s / self.n as f32
    }
}

/// One batch's outputs: mean gradient (empty for cheap forwards), mean
/// loss, and the flattened activations/probabilities/labels.
pub struct BatchOut {
    pub grad: FlatGrad,
    pub loss: f32,
    pub a: Vec<f32>,
    pub probs: Vec<f32>,
    pub y: Vec<i32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::{ControlVariate, TrueBackprop};
    use crate::predictor::fit::fit;

    #[test]
    fn example_grad_matches_finite_differences() {
        let tb = Testbed::new(3, 8, 6, 4, 3);
        let mut g = tb.zero_grad();
        tb.example_grad(2, &mut g);
        let eps = 1e-3f32;
        let mut a = vec![0.0f32; tb.width];
        let mut p = vec![0.0f32; tb.classes];
        // trunk coordinate
        for &k in &[0usize, 7, 13] {
            let mut tb2 = Testbed { trunk: tb.trunk.clone(), ..clone_light(&tb) };
            tb2.trunk[k] += eps;
            let up = tb2.forward(2, &mut a, &mut p);
            tb2.trunk[k] -= 2.0 * eps;
            let dn = tb2.forward(2, &mut a, &mut p);
            let fd = (up - dn) / (2.0 * eps);
            assert!((fd - g.trunk[k]).abs() < 2e-2, "trunk[{k}]: fd={fd} an={}", g.trunk[k]);
        }
        // head coordinate
        for &k in &[0usize, 5] {
            let mut tb2 = clone_light(&tb);
            tb2.head_w[k] += eps;
            let up = tb2.forward(2, &mut a, &mut p);
            tb2.head_w[k] -= 2.0 * eps;
            let dn = tb2.forward(2, &mut a, &mut p);
            let fd = (up - dn) / (2.0 * eps);
            assert!((fd - g.head_w[k]).abs() < 2e-2, "head_w[{k}]: fd={fd} an={}", g.head_w[k]);
        }
    }

    fn clone_light(tb: &Testbed) -> Testbed {
        Testbed {
            feat: tb.feat,
            width: tb.width,
            classes: tb.classes,
            n: tb.n,
            x: tb.x.clone(),
            y: tb.y.clone(),
            trunk: tb.trunk.clone(),
            head_w: tb.head_w.clone(),
            head_b: tb.head_b.clone(),
        }
    }

    #[test]
    fn slot_estimate_true_backprop_equals_batch_grad() {
        let tb = Testbed::new(5, 32, 8, 4, 3);
        let man = tb.manifest(8, 2);
        let est = TrueBackprop;
        let plan = est.plan(&man, true);
        let stream: Vec<usize> = (0..16).map(|i| (i * 3) % tb.n).collect();
        let (g, loss) = tb.slot_estimate(&est, &plan, &Predictor::new(tb.trunk_params(), 4, 2), &stream, 0).unwrap();
        let want = tb.batch_grad(&stream[0..8]);
        assert_eq!(g.trunk, want.grad.trunk);
        assert_eq!(loss, want.loss);
    }

    #[test]
    fn cv_slot_estimate_runs_through_the_fitted_linear_predictor() {
        let tb = Testbed::new(6, 64, 8, 4, 3);
        let man = tb.manifest(8, 2);
        let mut est = ControlVariate::new(0.25);
        est.bind(&man).unwrap();
        let mut buf = FitBuffer::new(24);
        tb.fill_fit_buffer(&mut buf, &(0..24).collect::<Vec<_>>());
        let mut pred = Predictor::new(tb.trunk_params(), tb.width, 2);
        fit(&mut pred, &buf, 1e-4).unwrap();
        let plan = est.plan(&man, true);
        assert!(plan.use_pred);
        let stream: Vec<usize> = (0..32).map(|i| (i * 5) % tb.n).collect();
        let (g, _) = tb.slot_estimate(&est, &plan, &pred, &stream, 0).unwrap();
        assert!(g.trunk.iter().all(|v| v.is_finite()));
        // The combine moved the estimate off the pure control gradient.
        let ctrl = tb.batch_grad(&stream[0..plan.mc]);
        assert_ne!(g.trunk, ctrl.grad.trunk);
    }

    #[test]
    fn fit_buffer_features_match_predictor_contract() {
        // h pushed by fill_fit_buffer must equal backprop_features of the
        // residuals — that equality is what makes the NTK fit native here.
        let tb = Testbed::new(7, 16, 6, 4, 3);
        let mut buf = FitBuffer::new(4);
        tb.fill_fit_buffer(&mut buf, &[1, 2, 3, 4]);
        let b = tb.batch_inputs(&[1, 2, 3, 4]);
        let resid = residuals(&b.probs, &b.y, tb.classes, 0.0);
        let h = Predictor::backprop_features(&resid, &tb.head_w, tb.width);
        for j in 0..4 {
            let hrow = h.row(j);
            for (x, y) in buf.h(j).iter().zip(hrow) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }
}
