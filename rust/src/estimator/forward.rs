//! Multi-tangent forward-gradient estimator (PAPERS.md, arXiv 2410.17764).
//!
//! Forward-mode AD yields directional derivatives `v·g` without a
//! backward pass; projecting onto K random tangents and averaging,
//!
//! ```text
//! ĝ = (1/K) Σ_k (v_k · g) v_k ,   v_k ~ N(0, I) iid,
//! ```
//!
//! gives an unbiased estimate of `g` because `E[v vᵀ] = I`. The testbed
//! computes the exact per-slot gradient first (this repo has no
//! forward-mode runtime artifact), then *projects it* through
//! [`GradientEstimator::transform_control`] — statistically identical to
//! the JVP formulation, since `v·g` is exactly the JVP the forward pass
//! would have produced.
//!
//! Determinism contract (ADR-004): tangent seeds are a pure function of
//! `(estimator seed, slot stream position, tangent index)`, so the
//! projected estimate is bit-identical at every shard count, and sorting
//! the seeds before accumulation makes the result bitwise invariant to
//! tangent order.

use super::{CombineCx, GradientEstimator, UpdatePlan};
use crate::model::manifest::Manifest;
use crate::model::params::FlatGrad;
use crate::util::rng::Pcg64;

/// Dedicated PCG stream for tangent draws so they can never collide with
/// data-pipeline or init streams that share a seed.
const TANGENT_STREAM: u64 = 0x7467; // "tg"

/// Derive the per-tangent seed for tangent `i` of the slot at stream
/// position `slot_seed`. SplitMix64-style finalizer over the packed
/// inputs: adjacent slots/tangents land far apart in seed space.
fn tangent_seed(seed: u64, slot_seed: u64, i: u64) -> u64 {
    let mut z = seed
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(slot_seed.wrapping_mul(0xbf58_476d_1ce4_e5b9))
        .wrapping_add(i.wrapping_add(1).wrapping_mul(0x94d0_49bb_1331_11eb));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Replace `g` with its K-tangent projection `(1/K) Σ (v_k·g) v_k`, one
/// N(0,1) tangent per seed. Seeds are sorted first, so the result is a
/// bitwise-pure function of the seed *set* — permutation-invariant in
/// tangent order (a property the proptests pin).
pub fn multi_tangent_project(g: &mut FlatGrad, seeds: &[u64]) {
    assert!(!seeds.is_empty(), "need at least one tangent");
    let mut order: Vec<u64> = seeds.to_vec();
    order.sort_unstable();
    let n = g.trunk.len() + g.head_w.len() + g.head_b.len();
    let inv_k = 1.0f32 / order.len() as f32;
    let mut v = vec![0.0f32; n];
    let mut acc = vec![0.0f32; n];
    for &s in &order {
        Pcg64::new(s, TANGENT_STREAM).fill_normal(&mut v, 1.0);
        // v·g in fixed segment order (trunk, head_w, head_b).
        let mut dot = 0.0f32;
        let mut off = 0;
        for seg in [&g.trunk[..], &g.head_w[..], &g.head_b[..]] {
            for (gv, vv) in seg.iter().zip(&v[off..off + seg.len()]) {
                dot += gv * vv;
            }
            off += seg.len();
        }
        let w = dot * inv_k;
        for (a, vv) in acc.iter_mut().zip(&v) {
            *a += w * vv;
        }
    }
    let mut off = 0;
    for seg in [&mut g.trunk[..], &mut g.head_w[..], &mut g.head_b[..]] {
        seg.copy_from_slice(&acc[off..off + seg.len()]);
        off += seg.len();
    }
}

/// Forward-gradient estimator: every slot takes the (cheapest available)
/// control pass, and the gradient is replaced by its projection onto K
/// seeded random tangents. Backward-free and unbiased; variance scales
/// like `O(P/K)` in the parameter count, which is exactly the trade-off
/// the sweep harness measures.
#[derive(Clone, Copy, Debug)]
pub struct MultiTangentForward {
    k: usize,
    seed: u64,
}

impl MultiTangentForward {
    /// Estimator with `k` tangent directions drawn from streams derived
    /// from `seed`.
    pub fn new(k: usize, seed: u64) -> MultiTangentForward {
        MultiTangentForward { k, seed }
    }

    /// Number of tangent directions.
    pub fn tangents(&self) -> usize {
        self.k
    }
}

impl GradientEstimator for MultiTangentForward {
    fn name(&self) -> &'static str {
        "multi-tangent"
    }

    fn f(&self) -> f64 {
        1.0
    }

    fn uses_predictor(&self) -> bool {
        false
    }

    fn bind(&mut self, _man: &Manifest) -> anyhow::Result<()> {
        anyhow::ensure!(self.k >= 1, "multi-tangent needs at least 1 tangent, got {}", self.k);
        Ok(())
    }

    fn plan(&self, man: &Manifest, _predictor_fitted: bool) -> UpdatePlan {
        UpdatePlan { mc: man.micro_batch, mp: 0, use_pred: false, f_eff: 1.0 }
    }

    fn combine(
        &self,
        _cx: &CombineCx,
        _g: &mut FlatGrad,
        _g_cp: &FlatGrad,
        _g_p: &FlatGrad,
        _f_eff: f32,
    ) -> anyhow::Result<()> {
        // Never reached: plan().use_pred is always false.
        Ok(())
    }

    fn transform_control(&self, g: &mut FlatGrad, slot_seed: u64) {
        let seeds: Vec<u64> =
            (0..self.k as u64).map(|i| tangent_seed(self.seed, slot_seed, i)).collect();
        multi_tangent_project(g, &seeds);
    }

    fn backward_fraction(&self) -> f64 {
        // Forward gradients never run a backward pass.
        0.0
    }

    fn save_state(&self) -> Vec<u8> {
        // Tangent draws are positional (seed, slot, i) — there is no
        // mutable state. Record the construction config for validation:
        // resuming with different tangents would silently change the
        // estimator's variance.
        let mut e = crate::checkpoint::Enc::new();
        e.put_u64(self.k as u64);
        e.put_u64(self.seed);
        e.into_bytes()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut d = crate::checkpoint::Dec::new(bytes, "multi-tangent state");
        let k = d.take_u64()? as usize;
        let seed = d.take_u64()?;
        anyhow::ensure!(
            k == self.k && seed == self.seed,
            "multi-tangent checkpoint mismatch: checkpoint has k={k} seed={seed}, \
             session has k={} seed={}",
            self.k,
            self.seed
        );
        d.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad() -> FlatGrad {
        let mut g = FlatGrad {
            trunk: vec![0.0; 24],
            head_w: vec![0.0; 8],
            head_b: vec![0.0; 2],
        };
        let mut rng = Pcg64::seeded(11);
        rng.fill_normal(&mut g.trunk, 1.0);
        rng.fill_normal(&mut g.head_w, 1.0);
        rng.fill_normal(&mut g.head_b, 1.0);
        g
    }

    #[test]
    fn projection_is_deterministic_and_moves_the_gradient() {
        let base = grad();
        let seeds = [3u64, 9, 27];
        let mut a = base.clone();
        multi_tangent_project(&mut a, &seeds);
        let mut b = base.clone();
        multi_tangent_project(&mut b, &seeds);
        assert_eq!(a.trunk, b.trunk);
        assert_eq!(a.head_w, b.head_w);
        assert_eq!(a.head_b, b.head_b);
        assert_ne!(a.trunk, base.trunk, "K=3 projection must differ from the exact gradient");
    }

    #[test]
    fn projection_is_permutation_invariant() {
        let base = grad();
        let mut a = base.clone();
        multi_tangent_project(&mut a, &[1, 2, 3, 4]);
        let mut b = base.clone();
        multi_tangent_project(&mut b, &[4, 2, 1, 3]);
        assert_eq!(a.trunk.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   b.trunk.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
        assert_eq!(a.head_w, b.head_w);
        assert_eq!(a.head_b, b.head_b);
    }

    #[test]
    fn many_tangents_concentrate_toward_the_true_gradient() {
        // ĝ is unbiased with variance O(P/K): at K ≫ P the projection
        // should land close to g in cosine similarity.
        let base = grad();
        let n = base.trunk.len() + base.head_w.len() + base.head_b.len();
        let mut proj = base.clone();
        let seeds: Vec<u64> = (0..64 * n as u64).map(|i| tangent_seed(5, 0, i)).collect();
        multi_tangent_project(&mut proj, &seeds);
        let flat = |g: &FlatGrad| {
            let mut v = g.trunk.clone();
            v.extend_from_slice(&g.head_w);
            v.extend_from_slice(&g.head_b);
            v
        };
        let (a, b) = (flat(&base), flat(&proj));
        let dot: f64 = a.iter().zip(&b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
        let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let cos = dot / (na * nb);
        assert!(cos > 0.9, "cos={cos}");
    }

    #[test]
    fn estimator_surface() {
        let mut est = MultiTangentForward::new(8, 42);
        assert_eq!(est.name(), "multi-tangent");
        assert_eq!(est.f(), 1.0);
        assert_eq!(est.backward_fraction(), 0.0);
        assert!(!est.uses_predictor());
        assert!(est.bind(&crate::estimator::tests_manifest(8, vec![0.25])).is_ok());
        assert!(MultiTangentForward::new(0, 1)
            .bind(&crate::estimator::tests_manifest(8, vec![0.25]))
            .is_err());
        let plan = est.plan(&crate::estimator::tests_manifest(8, vec![0.25]), true);
        assert!(!plan.use_pred);
        assert_eq!(plan.mc, 8);
    }
}
