//! Learned (neural) control-variate predictor (PAPERS.md, arXiv
//! 1806.00159).
//!
//! The paper's predictor is *linear* in the bilinear feature
//! `vec([a;1] hᵀ)`; the neural-control-variates literature argues the
//! same variance-reduction identity works for *any* learned predictor —
//! eq. (1) is unbiased regardless of predictor quality (Lemma 1), so the
//! predictor family is a pure variance knob. [`NeuralControlVariate`]
//! swaps the linear coefficient map for a small tanh MLP:
//!
//! ```text
//! ĝ_trunk(x) = U · mlp([a(x); h(x)]),    h = W_aᵀ r_cls,
//! ```
//!
//! keeping the same rank-r Gram-trick basis U as the linear fit
//! ([`crate::predictor::fit::gram_basis`]) and the same [`FitBuffer`]
//! sample stream, so the two predictors are head-to-head comparable on
//! identical data. Head gradients are exact (closed form from the
//! residuals), exactly as in the device predictor.
//!
//! The MLP trains by deterministic full-batch gradient descent with a
//! fixed seed, step count and learning rate — refits are a pure function
//! of the buffer contents, preserving the ADR-004 bitwise-determinism
//! contract.

use super::{combine, CombineCx, GradientEstimator, PredictInput, UpdatePlan};
use crate::model::manifest::Manifest;
use crate::model::params::FlatGrad;
use crate::predictor::fit::{gram_basis, FitBuffer, FitReport};
use crate::predictor::{residuals, Predictor};
use crate::tensor::{Backend, Tensor, Workspace};
use crate::util::rng::Pcg64;

/// Dedicated PCG stream for MLP weight init.
const NCV_STREAM: u64 = 0x6e63; // "nc"

/// Fitted state: the shared rank-r basis plus the MLP coefficient map.
struct NcvState {
    /// Basis in transposed layout: r contiguous rows of length p_t
    /// (row c = column c of U), so ĝ = Σ_c c[c]·u_row_c is r axpys.
    u_rows: Vec<f32>,
    p_t: usize,
    r: usize,
    /// Activation/feature width D; MLP input is [a; h] of length 2D.
    d: usize,
    hidden: usize,
    w1: Vec<f32>, // (hidden, 2d) row-major
    b1: Vec<f32>, // (hidden)
    w2: Vec<f32>, // (r, hidden) row-major
    b2: Vec<f32>, // (r)
}

impl NcvState {
    /// MLP forward: coefficients c = W2 tanh(W1 φ + b1) + b2.
    fn coeffs(&self, phi: &[f32], hid: &mut [f32], out: &mut [f32]) {
        debug_assert_eq!(phi.len(), 2 * self.d);
        debug_assert_eq!(hid.len(), self.hidden);
        debug_assert_eq!(out.len(), self.r);
        for (i, hv) in hid.iter_mut().enumerate() {
            let row = &self.w1[i * 2 * self.d..(i + 1) * 2 * self.d];
            let mut s = self.b1[i];
            for (wv, pv) in row.iter().zip(phi) {
                s += wv * pv;
            }
            *hv = s.tanh();
        }
        for (j, ov) in out.iter_mut().enumerate() {
            let row = &self.w2[j * self.hidden..(j + 1) * self.hidden];
            let mut s = self.b2[j];
            for (wv, hv) in row.iter().zip(hid.iter()) {
                s += wv * hv;
            }
            *ov = s;
        }
    }
}

/// Control-variate estimator with a learned MLP predictor. Same update
/// plan and eq.-(1) combine as [`super::ControlVariate`]; the predictor
/// runs on the host ([`GradientEstimator::host_predict`]) and fits its
/// own state from the session's FitBuffer
/// ([`GradientEstimator::fit_own`]).
pub struct NeuralControlVariate {
    f: f64,
    rank: usize,
    hidden: usize,
    train_steps: usize,
    lr: f32,
    seed: u64,
    fits: usize,
    state: Option<NcvState>,
}

impl NeuralControlVariate {
    /// Estimator with control fraction `f` and default MLP
    /// hyper-parameters (16 hidden units, 200 GD steps, lr 0.05, seed 0).
    pub fn new(f: f64) -> NeuralControlVariate {
        NeuralControlVariate {
            f,
            rank: 0,
            hidden: 16,
            train_steps: 200,
            lr: 0.05,
            seed: 0,
            fits: 0,
            state: None,
        }
    }

    /// Override the MLP hyper-parameters (hidden width, GD steps, lr).
    pub fn with_mlp(mut self, hidden: usize, train_steps: usize, lr: f32) -> NeuralControlVariate {
        self.hidden = hidden;
        self.train_steps = train_steps;
        self.lr = lr;
        self
    }

    /// Override the weight-init seed.
    pub fn with_seed(mut self, seed: u64) -> NeuralControlVariate {
        self.seed = seed;
        self
    }

    /// Number of completed own fits.
    pub fn fits(&self) -> usize {
        self.fits
    }
}

impl GradientEstimator for NeuralControlVariate {
    fn name(&self) -> &'static str {
        "neural-cv"
    }

    fn f(&self) -> f64 {
        self.f
    }

    fn uses_predictor(&self) -> bool {
        true
    }

    fn bind(&mut self, man: &Manifest) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.f > 0.0 && self.f <= 1.0,
            "control fraction f must be in (0,1], got {}",
            self.f
        );
        anyhow::ensure!(self.hidden >= 1, "neural-cv needs at least 1 hidden unit");
        anyhow::ensure!(man.rank >= 1, "neural-cv needs manifest rank >= 1");
        self.rank = man.rank;
        Ok(())
    }

    fn plan(&self, man: &Manifest, predictor_fitted: bool) -> UpdatePlan {
        let (mc, mp) = man.split_sizes(self.f);
        UpdatePlan {
            mc,
            mp,
            use_pred: predictor_fitted && mp > 0,
            f_eff: mc as f32 / man.micro_batch as f32,
        }
    }

    fn combine(
        &self,
        _cx: &CombineCx,
        g: &mut FlatGrad,
        g_cp: &FlatGrad,
        g_p: &FlatGrad,
        f_eff: f32,
    ) -> anyhow::Result<()> {
        // The same eq.-(1) correction as ControlVariate: Lemma 1 keeps
        // the estimate unbiased no matter what the MLP predicts.
        combine::cv_combine_into(g, g_cp, g_p, f_eff);
        Ok(())
    }

    fn host_predictor(&self) -> bool {
        true
    }

    fn owns_predictor_fit(&self) -> bool {
        true
    }

    fn predictor_ready(&self, _linear_fits: usize) -> bool {
        self.fits > 0
    }

    fn fit_own(
        &mut self,
        be: Backend,
        buf: &FitBuffer,
        _lambda: f32,
        ws: &mut Workspace,
    ) -> anyhow::Result<FitReport> {
        let r = self.rank.max(1);
        let (u_cols, energy_captured) = gram_basis(be, buf, r, ws)?;
        let n = buf.len();
        let p_t = buf.grad(0).len();
        let d = buf.h(0).len();
        let in_dim = 2 * d;

        // Training set: inputs φ_j = [a_j; h_j], targets c_j = U^T g_j
        // (contiguous dots against the transposed basis rows).
        let mut phis = vec![0.0f32; n * in_dim];
        let mut targets = vec![0.0f32; n * r];
        for j in 0..n {
            let phi = &mut phis[j * in_dim..(j + 1) * in_dim];
            phi[..d].copy_from_slice(&buf.a1(j)[..d]);
            phi[d..].copy_from_slice(buf.h(j));
            let g = buf.grad(j);
            for c in 0..r {
                targets[j * r + c] = be.dot(g, &u_cols.data[c * p_t..(c + 1) * p_t]);
            }
        }

        // Seeded init; scale 1/sqrt(fan_in) keeps tanh pre-activations
        // in-range regardless of D.
        let hidden = self.hidden;
        let mut rng = Pcg64::new(self.seed, NCV_STREAM);
        let mut st = NcvState {
            u_rows: u_cols.data.clone(),
            p_t,
            r,
            d,
            hidden,
            w1: vec![0.0; hidden * in_dim],
            b1: vec![0.0; hidden],
            w2: vec![0.0; r * hidden],
            b2: vec![0.0; r],
        };
        ws.give_tensor(u_cols);
        rng.fill_normal(&mut st.w1, 1.0 / (in_dim as f32).sqrt());
        rng.fill_normal(&mut st.w2, 1.0 / (hidden as f32).sqrt());

        // Deterministic full-batch GD on the mean-squared coefficient
        // error — fixed loop order, fixed step count, no early exit.
        let mut hid = vec![0.0f32; hidden];
        let mut out = vec![0.0f32; r];
        let mut gw1 = vec![0.0f32; hidden * in_dim];
        let mut gb1 = vec![0.0f32; hidden];
        let mut gw2 = vec![0.0f32; r * hidden];
        let mut gb2 = vec![0.0f32; r];
        let inv_n = 1.0 / n as f32;
        for _ in 0..self.train_steps {
            for v in gw1.iter_mut().chain(gb1.iter_mut()) {
                *v = 0.0;
            }
            for v in gw2.iter_mut().chain(gb2.iter_mut()) {
                *v = 0.0;
            }
            for j in 0..n {
                let phi = &phis[j * in_dim..(j + 1) * in_dim];
                st.coeffs(phi, &mut hid, &mut out);
                let tgt = &targets[j * r..(j + 1) * r];
                // dL/dc = 2/n (c − t); backprop through the two layers.
                for c in 0..r {
                    let dc = 2.0 * inv_n * (out[c] - tgt[c]);
                    gb2[c] += dc;
                    let grow = &mut gw2[c * hidden..(c + 1) * hidden];
                    for (gv, hv) in grow.iter_mut().zip(&hid) {
                        *gv += dc * hv;
                    }
                }
                for i in 0..hidden {
                    let mut dh = 0.0f32;
                    for c in 0..r {
                        dh += 2.0 * inv_n * (out[c] - tgt[c]) * st.w2[c * hidden + i];
                    }
                    let dpre = dh * (1.0 - hid[i] * hid[i]);
                    gb1[i] += dpre;
                    let grow = &mut gw1[i * in_dim..(i + 1) * in_dim];
                    for (gv, pv) in grow.iter_mut().zip(phi) {
                        *gv += dpre * pv;
                    }
                }
            }
            let lr = self.lr;
            for (w, g) in st.w1.iter_mut().zip(&gw1) {
                *w -= lr * g;
            }
            for (w, g) in st.b1.iter_mut().zip(&gb1) {
                *w -= lr * g;
            }
            for (w, g) in st.w2.iter_mut().zip(&gw2) {
                *w -= lr * g;
            }
            for (w, g) in st.b2.iter_mut().zip(&gb2) {
                *w -= lr * g;
            }
        }

        // Training-set relative error in trunk-gradient space.
        let mut err_num = 0.0f64;
        let mut err_den = 0.0f64;
        let mut ghat = vec![0.0f32; p_t];
        for j in 0..n {
            st.coeffs(&phis[j * in_dim..(j + 1) * in_dim], &mut hid, &mut out);
            for v in ghat.iter_mut() {
                *v = 0.0;
            }
            for c in 0..r {
                let w = out[c];
                let urow = &st.u_rows[c * p_t..(c + 1) * p_t];
                for (o, uv) in ghat.iter_mut().zip(urow) {
                    *o += w * uv;
                }
            }
            let g = buf.grad(j);
            for p in 0..p_t {
                let dlt = (ghat[p] - g[p]) as f64;
                err_num += dlt * dlt;
                err_den += (g[p] as f64) * (g[p] as f64);
            }
        }

        self.state = Some(st);
        self.fits += 1;
        Ok(FitReport {
            n,
            rank: r,
            energy_captured,
            rel_error: (err_num / err_den.max(1e-30)).sqrt(),
        })
    }

    fn host_predict(&self, input: &PredictInput, out: &mut FlatGrad) -> anyhow::Result<()> {
        let st = self
            .state
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("neural-cv consulted before its first fit"))?;
        let (m, d, classes) = (input.m, input.width, input.classes);
        anyhow::ensure!(d == st.d, "feature width changed since fit: {d} vs {}", st.d);
        anyhow::ensure!(out.trunk.len() == st.p_t, "trunk length mismatch");
        let resid = residuals(input.probs, input.y, classes, input.smoothing);
        let h = Predictor::backprop_features(&resid, input.head_w, d);

        // Mean MLP coefficient over the batch, then one basis expansion.
        let mut hid = vec![0.0f32; st.hidden];
        let mut c_one = vec![0.0f32; st.r];
        let mut c_mean = vec![0.0f32; st.r];
        let mut phi = vec![0.0f32; 2 * d];
        for j in 0..m {
            phi[..d].copy_from_slice(&input.a[j * d..(j + 1) * d]);
            phi[d..].copy_from_slice(h.row(j));
            st.coeffs(&phi, &mut hid, &mut c_one);
            for (acc, v) in c_mean.iter_mut().zip(&c_one) {
                *acc += v;
            }
        }
        let inv_m = 1.0 / m as f32;
        for v in c_mean.iter_mut() {
            *v *= inv_m;
        }
        for v in out.trunk.iter_mut() {
            *v = 0.0;
        }
        for c in 0..st.r {
            let w = c_mean[c];
            let urow = &st.u_rows[c * st.p_t..(c + 1) * st.p_t];
            for (o, uv) in out.trunk.iter_mut().zip(urow) {
                *o += w * uv;
            }
        }

        // Head gradients are exact (closed form), as in the device
        // predictor — the MLP only models the trunk part.
        let a_t = Tensor::from_vec(input.a.to_vec(), &[m, d]);
        let (gw, gb) = Predictor::head_grads(&a_t, &resid);
        out.head_w.copy_from_slice(&gw);
        out.head_b.copy_from_slice(&gb);
        Ok(())
    }

    fn save_state(&self) -> Vec<u8> {
        let mut e = crate::checkpoint::Enc::new();
        e.put_f64(self.f);
        e.put_u64(self.fits as u64);
        match &self.state {
            None => e.put_bool(false),
            Some(st) => {
                e.put_bool(true);
                e.put_u64(st.p_t as u64);
                e.put_u64(st.r as u64);
                e.put_u64(st.d as u64);
                e.put_u64(st.hidden as u64);
                e.put_f32s(&st.u_rows);
                e.put_f32s(&st.w1);
                e.put_f32s(&st.b1);
                e.put_f32s(&st.w2);
                e.put_f32s(&st.b2);
            }
        }
        e.into_bytes()
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut dec = crate::checkpoint::Dec::new(bytes, "neural-cv state");
        let f = dec.take_f64()?;
        anyhow::ensure!(
            f > 0.0 && f <= 1.0,
            "checkpointed control fraction {f} out of range (0,1]"
        );
        self.f = f;
        self.fits = dec.take_u64()? as usize;
        self.state = if dec.take_bool()? {
            let p_t = dec.take_u64()? as usize;
            let r = dec.take_u64()? as usize;
            let d = dec.take_u64()? as usize;
            let hidden = dec.take_u64()? as usize;
            let st = NcvState {
                u_rows: dec.take_f32s()?,
                p_t,
                r,
                d,
                hidden,
                w1: dec.take_f32s()?,
                b1: dec.take_f32s()?,
                w2: dec.take_f32s()?,
                b2: dec.take_f32s()?,
            };
            anyhow::ensure!(
                st.u_rows.len() == r * p_t
                    && st.w1.len() == hidden * 2 * d
                    && st.b1.len() == hidden
                    && st.w2.len() == r * hidden
                    && st.b2.len() == r,
                "neural-cv checkpoint has inconsistent layer shapes"
            );
            Some(st)
        } else {
            None
        };
        dec.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::tests_manifest;

    /// Low-rank synthetic stream: g_j = U* c(a_j, h_j) with a nonlinear
    /// coefficient map, so the MLP has signal the linear fit lacks.
    fn filled_buffer(rng: &mut Pcg64, p_t: usize, d: usize, n: usize) -> FitBuffer {
        let mut u = vec![0.0f32; 2 * p_t];
        rng.fill_normal(&mut u, (1.0 / p_t as f32).sqrt());
        let mut buf = FitBuffer::new(n);
        for _ in 0..n {
            let mut a = vec![0.0f32; d];
            let mut h = vec![0.0f32; d];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut h, 1.0);
            let c0 = (a[0] * h[0]).tanh() + 0.5 * a[1];
            let c1 = (a[1] * h[1]).tanh() - 0.5 * h[0];
            let g: Vec<f32> =
                (0..p_t).map(|p| c0 * u[p] + c1 * u[p_t + p]).collect();
            buf.push(&g, &a, &h);
        }
        buf
    }

    #[test]
    fn fit_is_deterministic_and_reports_sane_numbers() {
        let mut rng = Pcg64::seeded(9);
        let buf = filled_buffer(&mut rng, 60, 4, 24);
        let man = tests_manifest(8, vec![0.25]);
        let mut ws = Workspace::new();
        let mut est = NeuralControlVariate::new(0.25).with_mlp(8, 120, 0.05);
        est.bind(&man).unwrap();
        let rep = est.fit_own(Backend::blocked(), &buf, 1e-4, &mut ws).unwrap();
        assert_eq!(rep.n, 24);
        assert_eq!(rep.rank, man.rank);
        assert!(rep.energy_captured > 0.99, "{rep:?}"); // exactly rank-2 data
        assert!(rep.rel_error.is_finite() && rep.rel_error < 1.0, "{rep:?}");
        assert!(est.predictor_ready(0));

        let mut est2 = NeuralControlVariate::new(0.25).with_mlp(8, 120, 0.05);
        est2.bind(&man).unwrap();
        let rep2 = est2.fit_own(Backend::blocked(), &buf, 1e-4, &mut ws).unwrap();
        assert_eq!(rep.rel_error.to_bits(), rep2.rel_error.to_bits(), "fit must be deterministic");
    }

    #[test]
    fn host_predict_fills_all_segments_deterministically() {
        let mut rng = Pcg64::seeded(10);
        let (p_t, d, classes, m) = (60usize, 4usize, 3usize, 5usize);
        let buf = filled_buffer(&mut rng, p_t, d, 24);
        let man = tests_manifest(8, vec![0.25]);
        let mut ws = Workspace::new();
        let mut est = NeuralControlVariate::new(0.25).with_mlp(8, 80, 0.05);
        est.bind(&man).unwrap();
        est.fit_own(Backend::blocked(), &buf, 1e-4, &mut ws).unwrap();

        let mut a = vec![0.0f32; m * d];
        rng.fill_normal(&mut a, 1.0);
        let mut probs = vec![0.0f32; m * classes];
        for j in 0..m {
            let row = &mut probs[j * classes..(j + 1) * classes];
            let mut z = 0.0f32;
            for v in row.iter_mut() {
                *v = rng.next_f32() + 0.1;
                z += *v;
            }
            for v in row.iter_mut() {
                *v /= z;
            }
        }
        let y: Vec<i32> = (0..m).map(|j| (j % classes) as i32).collect();
        let mut head_w = vec![0.0f32; d * classes];
        rng.fill_normal(&mut head_w, 0.5);
        let input = PredictInput {
            a: &a,
            probs: &probs,
            y: &y,
            head_w: &head_w,
            m,
            width: d,
            classes,
            smoothing: 0.0,
        };
        let zero = || FlatGrad {
            trunk: vec![0.0; p_t],
            head_w: vec![0.0; d * classes],
            head_b: vec![0.0; classes],
        };
        let mut g1 = zero();
        est.host_predict(&input, &mut g1).unwrap();
        let mut g2 = zero();
        est.host_predict(&input, &mut g2).unwrap();
        assert_eq!(g1.trunk, g2.trunk);
        assert_eq!(g1.head_w, g2.head_w);
        assert_eq!(g1.head_b, g2.head_b);
        assert!(g1.trunk.iter().any(|v| *v != 0.0), "fitted predictor must predict");
        assert!(g1.head_b.iter().all(|v| v.is_finite()));
        // Head part is the exact closed form.
        let resid = residuals(&probs, &y, classes, 0.0);
        let a_t = Tensor::from_vec(a.clone(), &[m, d]);
        let (gw, gb) = Predictor::head_grads(&a_t, &resid);
        assert_eq!(g1.head_w, gw);
        assert_eq!(g1.head_b, gb);
    }

    #[test]
    fn unfitted_predict_and_bad_bind_fail_loudly() {
        let man = tests_manifest(8, vec![0.25]);
        let est = NeuralControlVariate::new(0.25);
        let mut g = FlatGrad { trunk: vec![0.0; 4], head_w: vec![0.0; 2], head_b: vec![0.0; 1] };
        let input = PredictInput {
            a: &[],
            probs: &[],
            y: &[],
            head_w: &[],
            m: 0,
            width: 0,
            classes: 1,
            smoothing: 0.0,
        };
        assert!(est.host_predict(&input, &mut g).is_err());
        assert!(NeuralControlVariate::new(0.0).bind(&man).is_err());
        assert!(NeuralControlVariate::new(1.5).bind(&man).is_err());
        assert!(NeuralControlVariate::new(0.25).bind(&man).is_ok());
    }
}
