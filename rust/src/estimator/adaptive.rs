//! Adaptive control fraction — Theorem 4 applied online.
//!
//! The paper notes ("Optimal f and regime switch") that the control ratio
//! f can be tuned: given the measured alignment (ρ̂, κ̂), the compute-
//! normalized objective Q(f) = φ(f, ρ̂, κ̂)·γ(f) has the closed-form
//! minimizer f*(ρ̂, κ̂). This controller tracks the alignment and steers f
//! toward f*, quantized to the control fractions whose artifacts exist
//! (HLO shapes are static, so only pre-lowered (m_c, m_p) splits are
//! admissible).
//!
//! Safety rails:
//! - hysteresis: only switch when the predicted compute saving exceeds
//!   `min_gain` (avoids flapping between adjacent fractions);
//! - falls back to f = 1 territory (the largest available fraction) when
//!   ρ̂ drops below the Theorem 4 regime switch — the paper's "vanilla is
//!   optimal" region.

use crate::metrics::Alignment;
use crate::theory::{self, CostModel};

#[derive(Clone, Debug)]
pub struct AdaptiveF {
    /// Admissible fractions (must have artifacts), sorted ascending.
    pub choices: Vec<f64>,
    pub cost: CostModel,
    /// Minimum relative Q improvement required to switch (hysteresis).
    pub min_gain: f64,
    pub current: f64,
    /// Switches performed (diagnostics).
    pub switches: usize,
}

impl AdaptiveF {
    pub fn new(mut choices: Vec<f64>, initial: f64) -> AdaptiveF {
        choices.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(!choices.is_empty(), "need at least one admissible f");
        let current = *choices
            .iter()
            .min_by(|a, b| {
                (*a - initial)
                    .abs()
                    .partial_cmp(&(*b - initial).abs())
                    .unwrap()
            })
            .unwrap();
        AdaptiveF {
            choices,
            cost: CostModel::default(),
            min_gain: 0.02,
            current,
            switches: 0,
        }
    }

    /// The admissible fraction closest to the unconstrained optimum f*.
    pub fn quantized_f_star(&self, a: &Alignment) -> f64 {
        let target = theory::f_star(a.rho, a.kappa, &self.cost);
        // Evaluate Q at each admissible choice and pick the best — the
        // quantized argmin, not merely the nearest neighbour of f*.
        let _ = target;
        *self
            .choices
            .iter()
            .min_by(|&&x, &&y| {
                theory::q_objective(x, a.rho, a.kappa, &self.cost)
                    .partial_cmp(&theory::q_objective(y, a.rho, a.kappa, &self.cost))
                    .unwrap()
            })
            .unwrap()
    }

    /// Update with the latest alignment snapshot; returns the (possibly
    /// new) control fraction to use for subsequent updates.
    pub fn update(&mut self, align: Option<Alignment>) -> f64 {
        let Some(a) = align else {
            return self.current; // no information yet — hold
        };
        // Below the regime switch, vanilla-like (largest f) is optimal.
        if a.rho <= theory::rho_switch(a.kappa, &self.cost) {
            let top = *self.choices.last().unwrap();
            if (top - self.current).abs() > 1e-12 {
                self.current = top;
                self.switches += 1;
            }
            return self.current;
        }
        let cand = self.quantized_f_star(&a);
        if (cand - self.current).abs() < 1e-12 {
            return self.current;
        }
        let q_now = theory::q_objective(self.current, a.rho, a.kappa, &self.cost);
        let q_new = theory::q_objective(cand, a.rho, a.kappa, &self.cost);
        if q_new < q_now * (1.0 - self.min_gain) {
            self.current = cand;
            self.switches += 1;
        }
        self.current
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn align(rho: f64, kappa: f64) -> Alignment {
        Alignment { rho, kappa, sigma_g: 1.0, sigma_h: kappa, n: 64 }
    }

    #[test]
    fn picks_smaller_f_for_good_alignment() {
        let mut c = AdaptiveF::new(vec![0.125, 0.25, 0.5], 0.25);
        let f = c.update(Some(align(0.97, 1.0)));
        assert!(f <= 0.25, "high alignment should not raise f, got {f}");
        assert!((0.125..=0.25).contains(&f));
    }

    #[test]
    fn falls_back_to_largest_f_below_regime_switch() {
        let mut c = AdaptiveF::new(vec![0.125, 0.25, 0.5], 0.125);
        // rho = 0.4 < rho_switch(1) = 0.6167
        let f = c.update(Some(align(0.4, 1.0)));
        assert_eq!(f, 0.5);
        assert_eq!(c.switches, 1);
    }

    #[test]
    fn hysteresis_prevents_flapping() {
        let mut c = AdaptiveF::new(vec![0.125, 0.25], 0.25);
        c.min_gain = 0.5; // demand a huge gain
        let f = c.update(Some(align(0.9, 1.0)));
        assert_eq!(f, 0.25, "should hold with strong hysteresis");
        assert_eq!(c.switches, 0);
    }

    #[test]
    fn no_information_holds_current() {
        let mut c = AdaptiveF::new(vec![0.125, 0.25, 0.5], 0.25);
        assert_eq!(c.update(None), 0.25);
        assert_eq!(c.switches, 0);
    }

    #[test]
    fn quantized_choice_minimizes_q_among_choices() {
        let c = AdaptiveF::new(vec![0.125, 0.25, 0.5], 0.25);
        let a = align(0.85, 1.0);
        let best = c.quantized_f_star(&a);
        let cost = CostModel::default();
        for &f in &c.choices {
            assert!(
                theory::q_objective(best, a.rho, a.kappa, &cost)
                    <= theory::q_objective(f, a.rho, a.kappa, &cost) + 1e-12
            );
        }
    }

    #[test]
    fn initial_snaps_to_admissible() {
        let c = AdaptiveF::new(vec![0.125, 0.5], 0.3);
        assert!((c.current - 0.125).abs() < 1e-12 || (c.current - 0.5).abs() < 1e-12);
    }
}
