//! bench-report — validate the emitted `BENCH_*.json` trajectory files.
//!
//! Scans a directory (default: the repo root, where the bench binaries
//! write) for `BENCH_*.json`, validates each against the `lgp.bench.v1`
//! schema (EXPERIMENTS.md §Schema), prints a summary table, and exits
//! nonzero if any document is malformed or an expected document is
//! missing. The same validator runs under `cargo test` via
//! `tests/backend_equivalence.rs`, so emitters cannot drift silently.
//!
//!   cargo run --release --bin bench_report
//!   cargo run --release --bin bench_report -- --dir . --expect kernels,cost_model

use lgp::bench_support::json_out::bench_out_dir;
use lgp::bench_support::{schema, Table};
use lgp::util::cli::Args;
use std::path::PathBuf;

fn main() {
    std::process::exit(run());
}

fn run() -> i32 {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            return 2;
        }
    };
    let dir = args
        .str_opt("dir")
        .map(PathBuf::from)
        .unwrap_or_else(bench_out_dir);
    let expect: Vec<String> = args
        .str_opt("expect")
        .map(|v| v.split(',').filter(|s| !s.is_empty()).map(String::from).collect())
        .unwrap_or_default();
    let unknown = args.unknown_keys();
    if !unknown.is_empty() {
        eprintln!("unknown flags: {unknown:?}");
        return 2;
    }

    let mut files: Vec<PathBuf> = match std::fs::read_dir(&dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map_or(false, |n| n.starts_with("BENCH_") && n.ends_with(".json"))
            })
            .collect(),
        Err(e) => {
            eprintln!("cannot read {}: {e}", dir.display());
            return 1;
        }
    };
    files.sort();

    let mut table = Table::new(&["file", "bench", "records", "backends", "status"]);
    let mut failures = 0usize;
    let mut seen_benches: Vec<String> = Vec::new();
    for path in &files {
        let fname = path.file_name().unwrap().to_string_lossy().into_owned();
        match schema::validate_file(path) {
            Ok(rep) => {
                seen_benches.push(rep.bench.clone());
                table.row(vec![
                    fname,
                    rep.bench,
                    rep.records.to_string(),
                    rep.backends.join(","),
                    "ok".into(),
                ]);
            }
            Err(msg) => {
                failures += 1;
                table.row(vec![fname, "-".into(), "-".into(), "-".into(), "MALFORMED".into()]);
                eprintln!("error: {}: {msg}", path.display());
            }
        }
    }

    println!("[BENCH-REPORT] {} ({} file(s))\n", dir.display(), files.len());
    table.print();

    for want in &expect {
        if !seen_benches.iter().any(|b| b == want) {
            eprintln!("error: expected bench document '{want}' not found in {}", dir.display());
            failures += 1;
        }
    }
    if files.is_empty() && expect.is_empty() {
        println!("\nno BENCH_*.json files found — run `cargo bench` first (EXPERIMENTS.md)");
    }
    if failures > 0 {
        eprintln!("\n{failures} validation failure(s)");
        1
    } else {
        0
    }
}
