//! bench-report — validate the emitted `BENCH_*.json` trajectory files
//! and gate perf regressions between two of them.
//!
//! **Validate** (default): scans a directory (default: the repo root,
//! where the bench binaries write) for `BENCH_*.json`, validates each
//! against the `lgp.bench.v1` schema (EXPERIMENTS.md §Schema), prints a
//! summary table, and exits nonzero if any document is malformed or an
//! expected document is missing. The same validator runs under
//! `cargo test` via `tests/backend_equivalence.rs`, so emitters cannot
//! drift silently.
//!
//! **Compare**: `--compare <baseline.json> <new.json>` diffs the two
//! documents cell by cell ((kernel, backend, shape) → mean ns/op) and
//! exits nonzero if any cell regressed by more than the threshold
//! (default 10%, override with `--threshold 0.15`) or disappeared from
//! the new document. This is the enforced perf-regression gate
//! (EXPERIMENTS.md §Compare gate).
//!
//! **CPU features**: `--cpu-features` prints the feature set the simd
//! backend detected ("avx2+fma" or "scalar") and exits 0 — the hook
//! scripts/verify.sh uses to decide whether to smoke the simd backend.
//!
//!   cargo run --release --bin bench_report
//!   cargo run --release --bin bench_report -- --dir . --expect kernels,cost_model
//!   cargo run --release --bin bench_report -- --compare BENCH_kernels.baseline.json BENCH_kernels.json
//!   cargo run --release --bin bench_report -- --cpu-features

use lgp::bench_support::json_out::bench_out_dir;
use lgp::bench_support::{compare, schema, Table};
use lgp::util::cli::Args;
use std::path::{Path, PathBuf};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.first().map(String::as_str) == Some("--compare") {
        std::process::exit(run_compare(&argv[1..]));
    }
    if argv.first().map(String::as_str) == Some("--cpu-features") {
        // Print the detected feature set ("avx2+fma" or "scalar") so
        // shell drivers (scripts/verify.sh) can gate the simd-backend
        // smoke run without re-implementing CPU detection.
        println!("{}", lgp::tensor::simd::cpu_features());
        std::process::exit(0);
    }
    std::process::exit(run());
}

/// `--compare <baseline.json> <new.json> [--threshold 0.10]`: positional
/// paths (two files is the natural grammar here), parsed by hand since the
/// shared flag parser is strictly `--key value`.
fn run_compare(rest: &[String]) -> i32 {
    let mut paths: Vec<&String> = Vec::new();
    let mut threshold = compare::DEFAULT_THRESHOLD;
    let mut i = 0;
    while i < rest.len() {
        if rest[i] == "--threshold" {
            match rest.get(i + 1).and_then(|v| v.parse::<f64>().ok()) {
                Some(t) if t > 0.0 => threshold = t,
                _ => {
                    eprintln!("--threshold needs a positive number");
                    return 2;
                }
            }
            i += 2;
        } else if rest[i].starts_with("--") {
            eprintln!("unknown compare flag '{}'", rest[i]);
            return 2;
        } else {
            paths.push(&rest[i]);
            i += 1;
        }
    }
    if paths.len() != 2 {
        eprintln!(
            "usage: bench_report --compare <baseline.json> <new.json> [--threshold 0.10]"
        );
        return 2;
    }
    let (base, new) = (Path::new(paths[0]), Path::new(paths[1]));
    let report = match compare::compare_files(base, new, threshold) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "[BENCH-COMPARE] {} vs {} (threshold {:.0}%)\n",
        base.display(),
        new.display(),
        threshold * 100.0
    );
    report.table().print();
    let (regs, imps) = (report.regressions().len(), report.improvements().len());
    println!(
        "\n{} cell(s): {} regressed, {} improved, {} missing",
        report.cells.len() + report.missing.len(),
        regs,
        imps,
        report.missing.len()
    );
    match report.failure_message() {
        None => {
            println!("gate: PASS");
            0
        }
        Some(msg) => {
            // Name every offending (kernel, backend, shape, threads) cell
            // so the failure is actionable straight from CI logs.
            eprintln!("gate: FAIL — {msg}");
            1
        }
    }
}

fn run() -> i32 {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            return 2;
        }
    };
    let dir = args
        .str_opt("dir")
        .map(PathBuf::from)
        .unwrap_or_else(bench_out_dir);
    let expect: Vec<String> = args
        .str_opt("expect")
        .map(|v| v.split(',').filter(|s| !s.is_empty()).map(String::from).collect())
        .unwrap_or_default();
    let unknown = args.unknown_keys();
    if !unknown.is_empty() {
        eprintln!("unknown flags: {unknown:?}");
        return 2;
    }

    let mut files: Vec<PathBuf> = match std::fs::read_dir(&dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map_or(false, |n| n.starts_with("BENCH_") && n.ends_with(".json"))
            })
            .collect(),
        Err(e) => {
            eprintln!("cannot read {}: {e}", dir.display());
            return 1;
        }
    };
    files.sort();

    let mut table = Table::new(&["file", "bench", "records", "backends", "status"]);
    let mut failures = 0usize;
    let mut seen_benches: Vec<String> = Vec::new();
    for path in &files {
        let fname = path.file_name().unwrap().to_string_lossy().into_owned();
        match schema::validate_file(path) {
            Ok(rep) => {
                seen_benches.push(rep.bench.clone());
                table.row(vec![
                    fname,
                    rep.bench,
                    rep.records.to_string(),
                    rep.backends.join(","),
                    "ok".into(),
                ]);
            }
            Err(msg) => {
                failures += 1;
                table.row(vec![fname, "-".into(), "-".into(), "-".into(), "MALFORMED".into()]);
                eprintln!("error: {}: {msg}", path.display());
            }
        }
    }

    println!("[BENCH-REPORT] {} ({} file(s))\n", dir.display(), files.len());
    table.print();

    for want in &expect {
        if !seen_benches.iter().any(|b| b == want) {
            eprintln!("error: expected bench document '{want}' not found in {}", dir.display());
            failures += 1;
        }
    }
    if files.is_empty() && expect.is_empty() {
        println!("\nno BENCH_*.json files found — run `cargo bench` first (EXPERIMENTS.md)");
    }
    if failures > 0 {
        eprintln!("\n{failures} validation failure(s)");
        1
    } else {
        0
    }
}
