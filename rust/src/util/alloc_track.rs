//! Debug-only allocation counter (feature `alloc-counter`).
//!
//! [`CountingAllocator`] wraps the system allocator and bumps a global
//! atomic on every `alloc`/`alloc_zeroed`/`realloc`. `lib.rs` installs it
//! as the `#[global_allocator]` when the feature is on, so *every* heap
//! allocation in the process — including ones hidden inside std — is
//! visible to [`alloc_count`].
//!
//! The point is the zero-allocation contract of ADR-003: the
//! `alloc_free_hotpath` integration test brackets a warmed steady-state
//! micro-batch + combine + optimizer step with two `alloc_count()` reads
//! and asserts the difference is exactly zero. Run it with
//!
//! ```sh
//! cargo test --features alloc-counter --test alloc_free_hotpath
//! ```
//!
//! The feature is off by default (the atomic bump taxes every allocation
//! in the process), so regular `cargo test` neither pays for nor runs it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static DEALLOCS: AtomicU64 = AtomicU64::new(0);

/// System-allocator wrapper that counts allocation events.
pub struct CountingAllocator;

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A realloc may move and always *may* touch the heap; count it as
        // an allocation event for the zero-alloc contract.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        DEALLOCS.fetch_add(1, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
}

/// Total allocation events (alloc + alloc_zeroed + realloc) since process
/// start. Only meaningful when the counting allocator is installed.
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Total deallocation events since process start.
pub fn dealloc_count() -> u64 {
    DEALLOCS.load(Ordering::Relaxed)
}
