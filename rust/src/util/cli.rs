//! Tiny command-line argument parser (no clap in the offline crate set).
//!
//! Grammar: `lgp <subcommand> [--flag] [--key value]...`. Typed accessors
//! with defaults; unknown keys are reported so typos fail loudly.
//!
//! Also home to the shared enum-flag machinery: each string-valued enum
//! flag (`--algo`, `--optimizer`, `--backend`) declares one
//! [`EnumSpec`] table that drives its `FromStr` parser, its error
//! message, *and* the `--help` option list ([`options`]) — a single
//! source of truth, so help text cannot drift from what the parsers
//! accept.

use std::collections::BTreeMap;

/// One selectable value of an enum-valued CLI flag: the canonical name
/// (shown in help), accepted aliases, and the value itself.
pub struct EnumSpec<T: 'static> {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub value: T,
}

/// Parse `s` against a spec table; the error lists the canonical options
/// exactly as [`options`] renders them for `--help`.
pub fn parse_enum<T: Copy>(specs: &[EnumSpec<T>], what: &str, s: &str) -> anyhow::Result<T> {
    for spec in specs {
        if spec.name == s || spec.aliases.contains(&s) {
            return Ok(spec.value);
        }
    }
    anyhow::bail!("unknown {what} '{s}' (want {})", options(specs))
}

/// The canonical `a|b|c` option list of a spec table (help text).
pub fn options<T>(specs: &[EnumSpec<T>]) -> String {
    specs.iter().map(|s| s.name).collect::<Vec<_>>().join("|")
}

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut it = argv.into_iter().peekable();
        let mut args = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --key, got '{tok}'"))?
                .to_string();
            if key.is_empty() {
                return Err("empty flag name".into());
            }
            // --key=value or --key value or bare flag
            if let Some((k, v)) = key.split_once('=') {
                args.flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                args.flags.insert(key, it.next().unwrap());
            } else {
                args.flags.insert(key, "true".to_string());
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    /// Strictly-parsed typed accessor: `None` when the flag is absent, a
    /// hard error naming the flag and the offending text when it is
    /// present but malformed. The `*_or` accessors below silently fall
    /// back to the default on a parse failure — acceptable for ad-hoc
    /// bench/example knobs, wrong for explicit user input (a typo like
    /// `--steps 3O` must not quietly train with the default step count).
    pub fn parsed<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.str_opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("invalid --{key} '{v}': {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.str_opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.str_opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.str_opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.str_opt(key).map_or(false, |v| v != "false")
    }

    /// Comma-separated f64 list, e.g. `--fs 0.1,0.25`.
    pub fn f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.str_opt(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.parse().ok())
                .collect(),
        }
    }

    /// Every parsed `--key value` pair, in sorted key order — for
    /// commands that re-spawn the binary with a filtered copy of their
    /// own flags (`lgp launch`, DESIGN.md ADR-010). Does not mark keys
    /// as consumed.
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str)> + '_ {
        self.flags.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Keys that were provided but never read by the command — typo guard.
    pub fn unknown_keys(&self) -> Vec<String> {
        let seen = self.consumed.borrow();
        self.flags
            .keys()
            .filter(|k| !seen.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_kv() {
        let a = parse("train --preset small --steps 100 --f 0.25");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_or("preset", "x"), "small");
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!((a.f64_or("f", 0.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn equals_syntax_and_bare_flags() {
        let a = parse("bench --quiet --budget=12.5");
        assert!(a.flag("quiet"));
        assert!(!a.flag("loud"));
        assert!((a.f64_or("budget", 0.0) - 12.5).abs() < 1e-12);
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("sweep --fs 0.1,0.25,0.5");
        assert_eq!(a.f64_list("fs", &[1.0]), vec![0.1, 0.25, 0.5]);
        assert_eq!(a.f64_list("other", &[1.0]), vec![1.0]);
    }

    #[test]
    fn unknown_key_detection() {
        let a = parse("train --presett tiny");
        let _ = a.str_opt("preset");
        assert_eq!(a.unknown_keys(), vec!["presett".to_string()]);
    }

    #[test]
    fn entries_expose_every_flag_for_respawn() {
        let a = parse("launch --preset tiny --steps 4 --procs 2 --resume");
        let got: Vec<(&str, &str)> = a.entries().collect();
        assert_eq!(
            got,
            vec![("preset", "tiny"), ("procs", "2"), ("resume", "true"), ("steps", "4")]
        );
        assert!(!a.unknown_keys().is_empty(), "entries must not mark keys consumed");
    }

    #[test]
    fn rejects_positional_after_flags() {
        assert!(Args::parse(vec!["train".into(), "oops".into()]).is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn parsed_is_strict_where_or_accessors_default() {
        let a = parse("train --steps 3O --f 0.25");
        // The lenient accessor silently falls back...
        assert_eq!(a.usize_or("steps", 7), 7);
        // ...the strict one reports the malformed value.
        let err = a.parsed::<usize>("steps").unwrap_err();
        assert!(format!("{err}").contains("'3O'"), "{err}");
        assert_eq!(a.parsed::<f64>("f").unwrap(), Some(0.25));
        assert_eq!(a.parsed::<f64>("missing").unwrap(), None);
    }

    #[test]
    fn enum_specs_parse_names_aliases_and_report_options() {
        #[derive(Clone, Copy, Debug, PartialEq)]
        enum Fruit {
            Apple,
            Pear,
        }
        const SPECS: &[EnumSpec<Fruit>] = &[
            EnumSpec { name: "apple", aliases: &["pomme"], value: Fruit::Apple },
            EnumSpec { name: "pear", aliases: &[], value: Fruit::Pear },
        ];
        assert_eq!(parse_enum(SPECS, "fruit", "apple").unwrap(), Fruit::Apple);
        assert_eq!(parse_enum(SPECS, "fruit", "pomme").unwrap(), Fruit::Apple);
        assert_eq!(parse_enum(SPECS, "fruit", "pear").unwrap(), Fruit::Pear);
        let err = parse_enum(SPECS, "fruit", "mango").unwrap_err();
        assert_eq!(format!("{err}"), "unknown fruit 'mango' (want apple|pear)");
        assert_eq!(options(SPECS), "apple|pear");
    }
}
