//! PCG64 (XSL-RR) pseudo-random number generator.
//!
//! Hand-rolled because the offline crate set has no `rand`. Deterministic,
//! splittable-by-stream, and statistically strong enough for data
//! generation, augmentation and Monte-Carlo validation of Proposition 2.

/// Permuted congruential generator, 128-bit state, XSL-RR output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams
    /// yield independent sequences even for equal seeds.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 1) | 1) ^ 0xda3e_39cb_94b9_5bdb;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits of uniformity.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n). Lemire rejection-free-ish (modulo bias is
    /// negligible for n << 2^64 but we debias anyway).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Standard normal via Box–Muller (cached pair not kept — simplicity
    /// over speed; the hot paths batch through `fill_normal`).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Fill a slice with iid N(0, sigma^2).
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * sigma;
        }
    }

    /// Raw generator state `(state, inc)` for checkpointing (ADR-008).
    /// Most RNG use in this repo is *positional* — fresh generators seeded
    /// from `(seed, position)` — so sessions rarely hold a live generator;
    /// these accessors exist for the components (and tests) that do.
    pub fn state_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`state_parts`](Self::state_parts) output.
    pub fn from_parts(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::seeded(1);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = rng.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(2);
        let n = 40_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = rng.normal() as f64;
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(4);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn state_parts_round_trip_resumes_the_stream() {
        let mut a = Pcg64::new(9, 3);
        for _ in 0..17 {
            a.next_u64();
        }
        let (s, i) = a.state_parts();
        let mut b = Pcg64::from_parts(s, i);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn coin_bias() {
        let mut rng = Pcg64::seeded(5);
        let hits = (0..10_000).filter(|_| rng.coin(0.25)).count();
        assert!((hits as f64 / 10_000.0 - 0.25).abs() < 0.02);
    }
}
