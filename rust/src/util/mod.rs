//! Shared substrates: RNG, JSON, CLI parsing, logging, timing, binary I/O.
//!
//! These exist because the offline crate set is exactly the `xla` crate's
//! dependency closure — no rand/serde/clap — so the library carries its
//! own small, tested implementations (DESIGN.md §10).

#[cfg(feature = "alloc-counter")]
pub mod alloc_track;
pub mod cli;
pub mod json;
pub mod rng;
pub mod shutdown;

use std::io::Write;
use std::time::Instant;

/// Wall-clock stopwatch used by the coordinator's budget loop and benches.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Read a little-endian f32 binary file (the init_*.bin artifacts).
pub fn read_f32_file(path: &std::path::Path) -> anyhow::Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "{} length {} not a multiple of 4",
        path.display(),
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a little-endian f32 binary file (checkpoints).
pub fn write_f32_file(path: &std::path::Path, data: &[f32]) -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, buf).map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

/// Append-only CSV writer with a fixed header, used by training loops and
/// benches to emit the series behind each paper figure.
pub struct CsvWriter {
    file: std::fs::File,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: &std::path::Path, header: &[&str]) -> anyhow::Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(values.len() == self.cols, "csv row arity mismatch");
        let line: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.file, "{}", line.join(","))?;
        Ok(())
    }
}

/// Parse an optional environment-variable override. `Ok(None)` when the
/// variable is unset; a malformed value is a hard error naming the
/// variable and the offending text — env overrides must never silently
/// fall back to a default the caller didn't ask for (they exist
/// precisely because someone set them on purpose).
pub fn env_parse<T: std::str::FromStr>(name: &str) -> anyhow::Result<Option<T>>
where
    T::Err: std::fmt::Display,
{
    match std::env::var(name) {
        Err(std::env::VarError::NotPresent) => Ok(None),
        Err(std::env::VarError::NotUnicode(_)) => {
            Err(anyhow::anyhow!("env var {name} is not valid unicode"))
        }
        Ok(raw) => raw.trim().parse::<T>().map(Some).map_err(|e| {
            anyhow::anyhow!("invalid {name}='{raw}': {e} (unset it or pass a valid value)")
        }),
    }
}

/// Leveled stderr logger; verbosity from LGP_LOG (error|warn|info|debug).
#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

pub fn log_level() -> Level {
    match std::env::var("LGP_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok(other) => {
            // Not a hard error (logging must not abort a run), but never
            // silent either: say it once, then use the default. The
            // format work stays inside the Once so the steady state pays
            // nothing (log_level runs on every log-macro evaluation).
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                eprintln!(
                    "[warn] LGP_LOG='{other}' is not a level (want error|warn|info|debug); using info"
                )
            });
            Level::Info
        }
        Err(_) => Level::Info,
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= $crate::util::Level::Info {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= $crate::util::Level::Debug {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= $crate::util::Level::Warn {
            eprintln!("[warn] {}", format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_file_round_trip() {
        let dir = std::env::temp_dir().join("lgp_util_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.bin");
        let data = vec![1.5f32, -2.25, 0.0, f32::MAX];
        write_f32_file(&path, &data).unwrap();
        assert_eq!(read_f32_file(&path).unwrap(), data);
    }

    #[test]
    fn f32_file_rejects_bad_length() {
        let dir = std::env::temp_dir().join("lgp_util_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 7]).unwrap();
        assert!(read_f32_file(&path).is_err());
    }

    #[test]
    fn env_parse_reports_malformed_values() {
        // A test-private name: nothing else in the process reads it, so
        // set/remove cannot race the LGP_SHARDS consumers.
        const VAR: &str = "LGP_UTIL_ENV_PARSE_TEST";
        std::env::remove_var(VAR);
        assert!(env_parse::<usize>(VAR).unwrap().is_none());
        std::env::set_var(VAR, "4");
        assert_eq!(env_parse::<usize>(VAR).unwrap(), Some(4));
        std::env::set_var(VAR, " 8 ");
        assert_eq!(env_parse::<usize>(VAR).unwrap(), Some(8), "whitespace is trimmed");
        std::env::set_var(VAR, "abc");
        let err = env_parse::<usize>(VAR).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains(VAR) && msg.contains("abc"), "{msg}");
        std::env::remove_var(VAR);
    }

    #[test]
    fn csv_writer_arity_check() {
        let dir = std::env::temp_dir().join("lgp_util_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        assert!(w.row(&[1.0, 2.0]).is_ok());
        assert!(w.row(&[1.0]).is_err());
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n1,2\n"));
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.seconds();
        let b = sw.seconds();
        assert!(b >= a && a >= 0.0);
    }
}
