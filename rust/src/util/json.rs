//! Minimal JSON parser + writer.
//!
//! Hand-rolled because the offline crate set has no serde. Supports the
//! full JSON grammar we exchange with the python AOT side (manifest.json)
//! and what the metrics logger emits (JSONL records). Numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset of the offending input. (Display and
/// Error are hand-implemented — the offline crate set has no thiserror.)
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<usize> (shapes in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for constructing records ergonomically.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8 passes through unchanged
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"preset":"tiny","dims":{"trunk_params":31840},
            "fs":[0.25,0.5],"layout":[{"name":"w","shape":[4,8],"muon":true}]}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.at(&["preset"]).as_str(), Some("tiny"));
        assert_eq!(j.at(&["dims", "trunk_params"]).as_usize(), Some(31840));
        assert_eq!(j.at(&["fs"]).as_arr().unwrap().len(), 2);
        let layout = j.at(&["layout"]).as_arr().unwrap();
        assert_eq!(layout[0].at(&["shape"]).as_usize_vec(), Some(vec![4, 8]));
        assert_eq!(layout[0].at(&["muon"]).as_bool(), Some(true));
    }

    #[test]
    fn round_trip() {
        let doc = r#"{"a":[1,2.5,-3e2,true,false,null,"x\ny"],"b":{"c":""}}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""aA\t\"\\b""#).unwrap();
        assert_eq!(j.as_str(), Some("aA\t\"\\b"));
        let out = Json::Str("x\"\n\\".into()).to_string();
        assert_eq!(Json::parse(&out).unwrap().as_str(), Some("x\"\n\\"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-12.5e-1").unwrap().as_f64(), Some(-1.25));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn unicode_pass_through() {
        let j = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → ∞"));
    }

    #[test]
    fn builder_and_writer() {
        let j = obj(vec![("x", num(1.0)), ("y", s("z"))]);
        assert_eq!(j.to_string(), r#"{"x":1,"y":"z"}"#);
    }
}
