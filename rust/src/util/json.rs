//! Minimal JSON parser + writer.
//!
//! Hand-rolled because the offline crate set has no serde. Supports the
//! full JSON grammar we exchange with the python AOT side (manifest.json)
//! and what the metrics logger emits (JSONL records). Numbers are f64.
//!
//! Since the serve control plane (DESIGN.md ADR-009) this parser also
//! sits on a network-facing wire, so it is hardened for untrusted input:
//! container nesting is depth-limited ([`MAX_DEPTH`]) so a `[[[[…` bomb
//! returns a [`JsonError`] naming the offset instead of overflowing the
//! stack, numbers that overflow f64 are rejected, `\u` escapes decode
//! UTF-16 surrogate pairs exactly (lone/truncated surrogates are errors,
//! never U+FFFD), and the integer accessors are checked-exact — `-1` or
//! `1.9` never silently becomes a `usize`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Maximum container nesting the parser accepts. Deep enough for any
/// document this system exchanges (manifests nest ~4 levels), shallow
/// enough that recursive descent cannot exhaust the stack on adversarial
/// input (`rust/tests/json_adversarial.rs`).
pub const MAX_DEPTH: usize = 128;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset of the offending input. (Display and
/// Error are hand-implemented — the offline crate set has no thiserror.)
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Largest f64 that still represents every smaller non-negative
    /// integer exactly (2^53). Beyond it `n as u64` would quietly invent
    /// digits, so the checked accessors refuse.
    const MAX_SAFE_INT: f64 = 9_007_199_254_740_992.0;

    /// Checked exact-integer accessor: `Some` only for a non-negative
    /// whole number within 2^53. `-1`, `1.9`, strings, and huge numbers
    /// all return `None` — config surfaces turn that into a field-naming
    /// error instead of a silently truncated value.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n <= Self::MAX_SAFE_INT => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Checked exact-integer accessor over the signed range (|n| ≤ 2^53).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() <= Self::MAX_SAFE_INT => Some(*n as i64),
            _ => None,
        }
    }

    /// Checked conversion to `usize` (via [`as_u64`](Self::as_u64)).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<usize> (shapes in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for constructing records ergonomically.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// Current container nesting; bounded by [`MAX_DEPTH`] so adversarial
    /// input cannot drive the recursive descent into a stack overflow.
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    /// Enter one container level; errors (naming the offending offset)
    /// past [`MAX_DEPTH`]. The matching decrement happens on the success
    /// path of `array`/`object` — error paths abandon the parser anyway.
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        Ok(())
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let n: f64 = text.parse().map_err(|_| self.err("bad number"))?;
        // `1e999` parses to infinity; on a network-facing config surface
        // that must be a structured error, not a value that NaN-poisons
        // downstream arithmetic.
        if !n.is_finite() {
            return Err(self.err("number overflows f64"));
        }
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            // Handles its own cursor movement (a surrogate
                            // pair spans two escapes).
                            out.push(self.unicode_escape()?);
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8 passes through unchanged
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    /// Decode one `\uXXXX` escape with the cursor on the `u`. A valid
    /// UTF-16 high surrogate must be immediately followed by a `\uYYYY`
    /// low surrogate; the pair combines into the real scalar (the pair
    /// d83d/de00 decodes to U+1F600, not two U+FFFD). Lone, reversed, or
    /// truncated surrogates are structured errors.
    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        let hi = self.hex4()?;
        match hi {
            0xD800..=0xDBFF => {
                if self.peek() != Some(b'\\') || self.b.get(self.i + 1) != Some(&b'u') {
                    return Err(self.err("unpaired high surrogate in \\u escape"));
                }
                self.i += 1; // consume the '\'; hex4 consumes the 'u'
                let lo = self.hex4()?;
                if !(0xDC00..=0xDFFF).contains(&lo) {
                    return Err(self.err("invalid low surrogate in \\u escape"));
                }
                let scalar = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                Ok(char::from_u32(scalar).expect("surrogate pair combines to a valid scalar"))
            }
            0xDC00..=0xDFFF => Err(self.err("unpaired low surrogate in \\u escape")),
            c => Ok(char::from_u32(c).expect("non-surrogate BMP code point is a valid scalar")),
        }
    }

    /// Parse the `uXXXX` of a `\u` escape (cursor on the `u`), advancing
    /// past it. Strict: exactly four ASCII hex digits — `from_str_radix`
    /// leniencies like a leading `+` are rejected.
    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 5 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let mut code = 0u32;
        for k in 1..=4 {
            let d = (self.b[self.i + k] as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad \\u escape (non-hex digit)"))?;
            code = code * 16 + d;
        }
        self.i += 5;
        Ok(code)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"preset":"tiny","dims":{"trunk_params":31840},
            "fs":[0.25,0.5],"layout":[{"name":"w","shape":[4,8],"muon":true}]}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.at(&["preset"]).as_str(), Some("tiny"));
        assert_eq!(j.at(&["dims", "trunk_params"]).as_usize(), Some(31840));
        assert_eq!(j.at(&["fs"]).as_arr().unwrap().len(), 2);
        let layout = j.at(&["layout"]).as_arr().unwrap();
        assert_eq!(layout[0].at(&["shape"]).as_usize_vec(), Some(vec![4, 8]));
        assert_eq!(layout[0].at(&["muon"]).as_bool(), Some(true));
    }

    #[test]
    fn round_trip() {
        let doc = r#"{"a":[1,2.5,-3e2,true,false,null,"x\ny"],"b":{"c":""}}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""aA\t\"\\b""#).unwrap();
        assert_eq!(j.as_str(), Some("aA\t\"\\b"));
        let out = Json::Str("x\"\n\\".into()).to_string();
        assert_eq!(Json::parse(&out).unwrap().as_str(), Some("x\"\n\\"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-12.5e-1").unwrap().as_f64(), Some(-1.25));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn unicode_pass_through() {
        let j = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → ∞"));
    }

    #[test]
    fn builder_and_writer() {
        let j = obj(vec![("x", num(1.0)), ("y", s("z"))]);
        assert_eq!(j.to_string(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn depth_bomb_errors_instead_of_aborting() {
        // Regression for the unbounded-recursion stack overflow: a few KB
        // of '[' used to abort the whole process.
        for bomb in ["[".repeat(100_000), "{\"k\":".repeat(100_000)] {
            let err = Json::parse(&bomb).unwrap_err();
            assert!(err.msg.contains("nesting"), "{err}");
            assert!(err.pos <= bomb.len(), "error must name an in-bounds offset");
        }
        // At or under the limit still parses.
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        let over = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Json::parse(&over).is_err());
    }

    #[test]
    fn integer_accessors_are_checked_exact() {
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None, "-1 must not saturate to 0");
        assert_eq!(Json::parse("1.9").unwrap().as_usize(), None, "1.9 must not truncate to 1");
        assert_eq!(Json::parse("-1").unwrap().as_i64(), Some(-1));
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-0.5").unwrap().as_i64(), None);
        // 2^53 is the exactness boundary; past it, refuse.
        assert_eq!(Json::parse("9007199254740992").unwrap().as_u64(), Some(1u64 << 53));
        assert_eq!(Json::parse("1e17").unwrap().as_u64(), None);
        assert_eq!(Json::parse("\"7\"").unwrap().as_u64(), None, "strings are not integers");
    }

    #[test]
    fn overflowing_numbers_are_structured_errors() {
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("-1e999").is_err());
        // Large-but-finite still parses.
        assert_eq!(Json::parse("1e308").unwrap().as_f64(), Some(1e308));
    }

    #[test]
    fn surrogate_pairs_combine_into_real_scalars() {
        // U+1F600 is the UTF-16 pair D83D+DE00; the old decoder mangled
        // it into two U+FFFD.
        let j = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(j.as_str(), Some("\u{1F600}"), "surrogate pair must decode to one scalar");
        // U+1D11E (musical G clef) = D834+DD1E, embedded mid-string.
        let j = Json::parse("\"x\\ud834\\udd1ey\"").unwrap();
        assert_eq!(j.as_str(), Some("x\u{1D11E}y"));
        // BMP escapes unchanged.
        assert_eq!(Json::parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    }

    #[test]
    fn lone_or_malformed_surrogates_are_errors() {
        for bad in [
            "\"\\ud83d\"",       // lone high surrogate
            "\"\\ud83d!\"",      // high surrogate then plain char
            "\"\\ud83d\\n\"",    // high surrogate then a non-\u escape
            "\"\\ud83d\\u0041\"", // high surrogate then a non-surrogate \u
            "\"\\ude00\"",       // lone low surrogate
            "\"\\ud8",           // truncated escape at end of input
            "\"\\u00\"",         // short hex run
            "\"\\u+041\"",       // from_str_radix leniency must not leak in
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn astral_strings_round_trip_through_the_writer() {
        for text in ["😀", "x𝄞y", "héllo 😀🎵 → ∞", "\u{10FFFF}"] {
            let out = Json::Str(text.to_string()).to_string();
            assert_eq!(Json::parse(&out).unwrap().as_str(), Some(text), "{text:?}");
        }
    }
}
