//! Graceful-shutdown flag for the training loop (DESIGN.md ADR-008).
//!
//! The session polls [`requested`] at update boundaries: on SIGINT the
//! handler only flips an `AtomicBool` (the whole async-signal-safe
//! budget), the loop notices at the next boundary, writes a final
//! checkpoint, and exits cleanly. A second Ctrl-C still kills the
//! process the hard way because the handler is installed with
//! `SA_RESETHAND`-like semantics via re-registration — see [`install`].
//!
//! No `libc` dependency is available offline, so the handler goes
//! through the C `signal(2)` entry point directly; on non-Unix targets
//! the module compiles to a no-op flag that only [`request`] can set.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

static REQUESTED: AtomicBool = AtomicBool::new(false);
static INSTALL: Once = Once::new();

#[cfg(unix)]
mod sys {
    use super::{Ordering, REQUESTED};

    pub const SIGINT: i32 = 2;
    pub const SIG_DFL: usize = 0;

    extern "C" {
        // POSIX `signal(2)`; returns the previous handler (SIG_ERR = !0).
        pub fn signal(signum: i32, handler: usize) -> usize;
    }

    pub extern "C" fn on_sigint(_signum: i32) {
        // Async-signal-safe: a relaxed store and nothing else. Re-arm to
        // the default disposition so a second Ctrl-C terminates even if
        // the loop is wedged between poll points.
        REQUESTED.store(true, Ordering::Relaxed);
        unsafe {
            signal(SIGINT, SIG_DFL);
        }
    }
}

/// Install the SIGINT handler once per process. Idempotent; later calls
/// are no-ops (the flag is process-global, matching the one-session-per-
/// process CLI). On non-Unix targets this does nothing.
pub fn install() {
    INSTALL.call_once(|| {
        #[cfg(unix)]
        unsafe {
            let handler: extern "C" fn(i32) = sys::on_sigint;
            sys::signal(sys::SIGINT, handler as usize);
        }
    });
}

/// Has a graceful shutdown been requested (SIGINT or [`request`])?
pub fn requested() -> bool {
    REQUESTED.load(Ordering::Relaxed)
}

/// Programmatic shutdown request — what the signal handler does, callable
/// from tests and embedding code.
pub fn request() {
    REQUESTED.store(true, Ordering::Relaxed);
}

/// Clear the flag (tests; a fresh `TrainSession::run` also clears it so a
/// stale request from a previous run in the same process cannot abort the
/// next one at step 1).
pub fn reset() {
    REQUESTED.store(false, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_sets_and_reset_clears_the_flag() {
        reset();
        assert!(!requested());
        request();
        assert!(requested());
        reset();
        assert!(!requested());
    }

    #[test]
    fn install_is_idempotent() {
        install();
        install();
    }
}
