//! Graceful-shutdown signalling for training loops (DESIGN.md ADR-008,
//! ADR-009).
//!
//! Two mechanisms share one polling contract:
//!
//! - **Process-global SIGINT flag** (the CLI path): the session polls
//!   [`requested`] at update boundaries; on SIGINT the handler only flips
//!   an `AtomicBool` (the whole async-signal-safe budget), the loop
//!   notices at the next boundary, writes a final checkpoint, and exits
//!   cleanly. The handler re-arms to the default disposition so a second
//!   Ctrl-C *within one cycle* still kills a wedged process the hard way
//!   — and [`install`] re-registers it, so the next `run` in the same
//!   process gets a fresh graceful cycle (a long-lived multi-session
//!   process used to hard-die on its second Ctrl-C because the handler
//!   was `Once`-installed).
//! - **Per-session [`CancelToken`]** (the serve control plane): a hosted
//!   session built with an explicit token polls *only* that token. It
//!   neither installs the signal handler nor touches the process-global
//!   flag, so concurrent hosted sessions cannot clobber each other or
//!   the server's own Ctrl-C handling.
//!
//! No `libc` dependency is available offline, so the handler goes
//! through the C `signal(2)` entry point directly; on non-Unix targets
//! the module compiles to a no-op flag that only [`request`] can set.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

static REQUESTED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sys {
    use super::{Ordering, REQUESTED};

    pub const SIGINT: i32 = 2;
    pub const SIG_DFL: usize = 0;

    extern "C" {
        // POSIX `signal(2)`; returns the previous handler (SIG_ERR = !0).
        pub fn signal(signum: i32, handler: usize) -> usize;
    }

    pub extern "C" fn on_sigint(_signum: i32) {
        // Async-signal-safe: a relaxed store and nothing else. Re-arm to
        // the default disposition so a second Ctrl-C terminates even if
        // the loop is wedged between poll points.
        REQUESTED.store(true, Ordering::Relaxed);
        unsafe {
            signal(SIGINT, SIG_DFL);
        }
    }
}

/// (Re-)install the SIGINT handler. Called at the top of every
/// `TrainSession::run` without a per-session token, so each graceful
/// cycle re-arms the handler the previous cycle reset to `SIG_DFL` —
/// two sequential Ctrl-C-interrupted runs in one process both shut down
/// gracefully (`rust/tests/graceful_shutdown.rs`). Idempotent and cheap;
/// on non-Unix targets this does nothing.
pub fn install() {
    #[cfg(unix)]
    unsafe {
        let handler: extern "C" fn(i32) = sys::on_sigint;
        sys::signal(sys::SIGINT, handler as usize);
    }
}

/// Has a graceful shutdown been requested (SIGINT or [`request`])?
pub fn requested() -> bool {
    REQUESTED.load(Ordering::Relaxed)
}

/// Programmatic shutdown request — what the signal handler does, callable
/// from tests and embedding code.
pub fn request() {
    REQUESTED.store(true, Ordering::Relaxed);
}

/// Clear the flag (tests; a fresh `TrainSession::run` also clears it so a
/// stale request from a previous run in the same process cannot abort the
/// next one at step 1).
pub fn reset() {
    REQUESTED.store(false, Ordering::Relaxed);
}

/// Per-session cancellation handle (serve control plane, ADR-009).
///
/// Cloning shares the underlying flag: the server keeps one clone to
/// [`cancel`](CancelToken::cancel) from a `POST /sessions/:id/cancel`
/// handler while the session thread polls its own clone at update
/// boundaries. A session built with `SessionBuilder::cancel_token`
/// ignores the process-global SIGINT flag entirely.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request a graceful stop: the owning session writes its final
    /// checkpoint at the next update boundary and exits cleanly.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_sets_and_reset_clears_the_flag() {
        reset();
        assert!(!requested());
        request();
        assert!(requested());
        reset();
        assert!(!requested());
    }

    #[test]
    fn install_survives_repeated_calls() {
        // Re-registration is the whole point (the handler resets itself
        // to SIG_DFL after firing); repeated installs must be harmless.
        install();
        install();
        install();
    }

    #[test]
    fn cancel_tokens_are_independent_of_the_global_flag() {
        reset();
        let a = CancelToken::new();
        let b = CancelToken::new();
        let a2 = a.clone();
        assert!(!a.is_cancelled());
        a.cancel();
        assert!(a.is_cancelled(), "cancel must be visible to the owner");
        assert!(a2.is_cancelled(), "clones share the flag");
        assert!(!b.is_cancelled(), "tokens are per-session");
        assert!(!requested(), "a session token never touches the process flag");
    }
}
