//! Minimal HTTP/1.1 plumbing for the serve control plane (DESIGN.md
//! ADR-009).
//!
//! Deliberately tiny: exactly what the JSONL control plane needs and
//! nothing more. One request per connection (`Connection: close`, no
//! keep-alive state machine), bounded header and body reads so a hostile
//! client cannot balloon per-connection memory, and chunked transfer
//! encoding for the event stream. Zero dependencies — std sockets only,
//! same offline-crate constraint as the rest of the tree.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Hard cap on the request line + headers. Past this the connection is
/// answered `431` and closed — the read buffer never grows beyond
/// roughly this bound regardless of what the client streams.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Hard cap on declared request bodies: far above any real config
/// document, far below anything that could hurt. Checked against
/// `Content-Length` *before* the body is read, so an attacker declaring
/// a huge body costs one header parse, not a gigabyte of buffering.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// Per-connection socket timeout: a stalled or byte-dripping client is
/// disconnected instead of pinning its handler thread forever.
pub const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// One parsed request — just the parts the control plane routes on.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Request path with any query string stripped.
    pub path: String,
    pub body: Vec<u8>,
}

/// Why a request could not be read; maps onto the status the handler
/// answers before closing the connection.
#[derive(Debug)]
pub enum BadRequest {
    /// Head or declared body exceeds its bound (`status` is 431 or 413).
    TooLarge { status: u16, what: &'static str },
    /// Syntactically broken request → 400.
    Malformed(String),
    /// The socket died mid-read; nothing can be answered.
    Io(std::io::Error),
}

/// Reads one bounded request: head until `\r\n\r\n` (≤
/// [`MAX_HEAD_BYTES`]), then exactly `Content-Length` body bytes (≤
/// [`MAX_BODY_BYTES`]). Transfer-encoded request bodies are not
/// supported — the control plane's only body is a small JSON document.
pub fn read_request(stream: &mut TcpStream) -> Result<Request, BadRequest> {
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(pos) = find(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(BadRequest::TooLarge {
                status: 431,
                what: "request head exceeds 8 KiB",
            });
        }
        let n = stream.read(&mut chunk).map_err(BadRequest::Io)?;
        if n == 0 {
            return Err(BadRequest::Malformed(
                "connection closed before the request head completed".to_string(),
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| BadRequest::Malformed("request head is not UTF-8".to_string()))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("");
    if method.is_empty() || target.is_empty() {
        return Err(BadRequest::Malformed(format!("bad request line {request_line:?}")));
    }
    let path = target.split('?').next().unwrap_or("").to_string();

    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse::<usize>().map_err(|_| {
                    BadRequest::Malformed(format!("bad content-length {:?}", v.trim()))
                })?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(BadRequest::TooLarge { status: 413, what: "request body exceeds 1 MiB" });
    }

    let mut body = buf.split_off(head_end + 4);
    while body.len() < content_length {
        let n = stream.read(&mut chunk).map_err(BadRequest::Io)?;
        if n == 0 {
            return Err(BadRequest::Malformed(
                "connection closed before the declared body arrived".to_string(),
            ));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(Request { method, path, body })
}

fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Reason phrase for the handful of statuses the control plane emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        _ => "",
    }
}

/// One complete JSON response; close-delimited (`Connection: close`).
pub fn respond_json(stream: &mut TcpStream, status: u16, body: &str) -> std::io::Result<()> {
    write!(
        stream,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status,
        reason(status),
        body.len()
    )?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// `{"error": <msg>}` with the message JSON-escaped through the writer.
pub fn respond_error(stream: &mut TcpStream, status: u16, msg: &str) -> std::io::Result<()> {
    let body = crate::util::json::obj(vec![("error", crate::util::json::s(msg))]).to_string();
    respond_json(stream, status, &body)
}

/// Starts a chunked JSONL stream (`Transfer-Encoding: chunked`,
/// `application/x-ndjson`). Follow with [`write_chunk_line`] per event
/// and [`end_chunked`] to terminate.
pub fn start_chunked(stream: &mut TcpStream, status: u16) -> std::io::Result<()> {
    write!(
        stream,
        "HTTP/1.1 {} {}\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
        status,
        reason(status)
    )
}

/// One JSONL line as one chunk; the trailing `\n` is part of the chunk
/// so line-oriented clients can split on it directly.
pub fn write_chunk_line(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n{}\n\r\n", line.len() + 1, line)
}

/// Zero-length chunk: end of stream.
pub fn end_chunked(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Runs `read_request` against raw client bytes over a real loopback
    /// socket. The client half closes after writing, so truncation cases
    /// see EOF rather than a read timeout.
    fn roundtrip(raw: &[u8]) -> Result<Request, BadRequest> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let client = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(&raw).unwrap();
            c.flush().unwrap();
            // dropping the stream sends FIN; sent bytes stay readable
        });
        let (mut server, _) = listener.accept().unwrap();
        server.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let out = read_request(&mut server);
        client.join().unwrap();
        out
    }

    #[test]
    fn parses_request_line_headers_and_body() {
        let req = roundtrip(b"POST /sessions?watch=1 HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd")
            .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/sessions", "query string must be stripped");
        assert_eq!(req.body, b"abcd");

        let req = roundtrip(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.body.is_empty());
    }

    #[test]
    fn oversized_head_is_bounded_and_431() {
        let mut raw = b"GET /".to_vec();
        raw.extend(std::iter::repeat(b'a').take(4 * MAX_HEAD_BYTES));
        match roundtrip(&raw) {
            Err(BadRequest::TooLarge { status: 431, .. }) => {}
            other => panic!("want 431 TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn oversized_declared_body_is_rejected_before_buffering() {
        // The body never arrives — the declaration alone must be enough
        // to refuse, otherwise the cap would not bound memory.
        let raw = format!(
            "POST /sessions HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        match roundtrip(raw.as_bytes()) {
            Err(BadRequest::TooLarge { status: 413, .. }) => {}
            other => panic!("want 413 TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn truncated_body_and_garbage_are_malformed_not_panics() {
        match roundtrip(b"POST /sessions HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc") {
            Err(BadRequest::Malformed(_)) => {}
            other => panic!("want Malformed, got {other:?}"),
        }
        match roundtrip(b"\x00\x01\x02\xff\r\n\r\n") {
            Err(BadRequest::Malformed(_)) => {}
            other => panic!("want Malformed, got {other:?}"),
        }
        match roundtrip(b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n") {
            Err(BadRequest::Malformed(msg)) => assert!(msg.contains("content-length"), "{msg}"),
            other => panic!("want Malformed, got {other:?}"),
        }
    }

    #[test]
    fn chunked_writer_emits_wellformed_frames() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            let mut out = Vec::new();
            c.read_to_end(&mut out).unwrap();
            out
        });
        let (mut server, _) = listener.accept().unwrap();
        start_chunked(&mut server, 200).unwrap();
        write_chunk_line(&mut server, r#"{"event":"a"}"#).unwrap();
        write_chunk_line(&mut server, r#"{"event":"b"}"#).unwrap();
        end_chunked(&mut server).unwrap();
        drop(server);
        let text = String::from_utf8(reader.join().unwrap()).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Transfer-Encoding: chunked"), "{text}");
        // 14 bytes = 13-byte line + the newline folded into the chunk.
        assert!(text.contains("e\r\n{\"event\":\"a\"}\n\r\n"), "{text}");
        assert!(text.ends_with("0\r\n\r\n"), "{text}");
    }
}
