//! Training-as-a-service control plane (DESIGN.md ADR-009).
//!
//! `lgp serve` binds a plain-std `TcpListener` and hosts
//! [`crate::session::TrainSession`]s behind a minimal HTTP/1.1 + JSONL
//! surface — zero new dependencies, same offline constraint as
//! everything else. One short-lived handler thread per connection, one
//! request per connection, every buffer bounded ([`http`]).
//!
//! Routes:
//! - `POST /sessions` — body is the ADR-004 JSON config dialect; it goes
//!   through the hardened `Json::parse` and the strict
//!   `SessionBuilder::apply_json`, so adversarial or mistyped documents
//!   come back as structured 400s (field name or byte offset included),
//!   never a panic. Success spawns the session thread and answers 201.
//! - `GET /sessions` / `GET /sessions/:id` — status documents.
//! - `GET /sessions/:id/events` — the ADR-005 observer pipeline as a
//!   chunked JSONL stream ([`hub::ServeObserver`] → bounded
//!   [`hub::EventHub`] → this socket), with evicted-line gaps surfaced
//!   as `{"event":"dropped","count":n}` markers.
//! - `POST /sessions/:id/cancel` — flips the session's
//!   [`CancelToken`]; the run loop sees it at the next update boundary,
//!   writes its ADR-008 final checkpoint, and exits cleanly. The
//!   process-global SIGINT flag is never touched, so hosted sessions
//!   cancel independently of each other and of the server's own Ctrl-C.
//! - `GET /healthz` — liveness probe.

pub mod http;
pub mod hub;

use crate::config::RunConfig;
use crate::session::SessionBuilder;
use crate::util::json::{self, Json};
use crate::util::shutdown::CancelToken;
use anyhow::Context;
use http::Request;
use hub::{EventHub, ServeObserver, EVENT_QUEUE_CAP};
use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How long the event stream waits per hub poll. Bounds how quickly a
/// streaming handler notices its own socket died (each wakeup flushes).
const STREAM_POLL: Duration = Duration::from_millis(200);

/// Lifecycle of a hosted session.
#[derive(Clone, Debug)]
pub enum Status {
    /// Accepted; the session thread is still loading artifacts.
    Pending,
    Running,
    Done { steps: usize, final_val_acc: f64 },
    /// Cancelled at an update boundary — the final checkpoint (if a
    /// checkpoint dir was configured) is on disk.
    Cancelled { steps: usize },
    Failed { error: String },
}

impl Status {
    pub fn name(&self) -> &'static str {
        match self {
            Status::Pending => "pending",
            Status::Running => "running",
            Status::Done { .. } => "done",
            Status::Cancelled { .. } => "cancelled",
            Status::Failed { .. } => "failed",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, Status::Done { .. } | Status::Cancelled { .. } | Status::Failed { .. })
    }
}

/// One hosted training session — the registry's handle to its thread.
pub struct Hosted {
    pub id: u64,
    status: Mutex<Status>,
    cancel: CancelToken,
    pub events: Arc<EventHub>,
}

impl Hosted {
    pub fn status(&self) -> Status {
        self.status.lock().unwrap().clone()
    }

    fn set_status(&self, s: Status) {
        *self.status.lock().unwrap() = s;
    }

    /// Requests a graceful stop; idempotent. The run loop polls the
    /// token at update boundaries (never mid-update), checkpoints, and
    /// exits — same path as a SIGINT on a CLI run.
    pub fn request_cancel(&self) {
        self.cancel.cancel();
    }

    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Status document served by `GET /sessions/:id`.
    pub fn status_json(&self) -> Json {
        let st = self.status();
        let mut pairs = vec![
            ("id", json::num(self.id as f64)),
            ("status", json::s(st.name())),
        ];
        match &st {
            Status::Done { steps, final_val_acc } => {
                pairs.push(("steps", json::num(*steps as f64)));
                pairs.push((
                    "final_val_acc",
                    if final_val_acc.is_finite() { json::num(*final_val_acc) } else { Json::Null },
                ));
            }
            Status::Cancelled { steps } => pairs.push(("steps", json::num(*steps as f64))),
            Status::Failed { error } => pairs.push(("error", json::s(error))),
            Status::Pending | Status::Running => {}
        }
        json::obj(pairs)
    }
}

/// Shared session table behind the HTTP surface.
#[derive(Default)]
pub struct Registry {
    next_id: AtomicU64,
    sessions: Mutex<HashMap<u64, Arc<Hosted>>>,
}

impl Registry {
    /// Validates a config document and spawns its session thread.
    /// Errors out of here (unknown field, lossy number, bad range) are
    /// the caller's 400; once this returns `Ok`, later failures surface
    /// as status `failed` on the hosted session instead.
    pub fn submit(&self, cfg_doc: &Json) -> anyhow::Result<Arc<Hosted>> {
        let builder = SessionBuilder::new().apply_json(cfg_doc)?;
        let cfg: RunConfig = builder.config().clone();
        cfg.validate()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let hosted = Arc::new(Hosted {
            id,
            status: Mutex::new(Status::Pending),
            cancel: CancelToken::new(),
            events: Arc::new(EventHub::new(EVENT_QUEUE_CAP)),
        });
        self.sessions.lock().unwrap().insert(id, hosted.clone());
        let h = hosted.clone();
        std::thread::Builder::new()
            .name(format!("lgp-session-{id}"))
            .spawn(move || host_run(&h, cfg))
            .context("spawning session thread")?;
        Ok(hosted)
    }

    pub fn get(&self, id: u64) -> Option<Arc<Hosted>> {
        self.sessions.lock().unwrap().get(&id).cloned()
    }

    /// Session ids in submission order.
    pub fn ids(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.sessions.lock().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }
}

/// Body of a session thread. The (Send, Clone) `RunConfig` crosses the
/// thread boundary; the `SessionBuilder` — which holds boxed trait
/// objects — is rebuilt on this side of it. The per-session token and
/// the `ServeObserver` are wired here, so a hosted run never installs
/// the process-global SIGINT handler.
fn host_run(h: &Hosted, cfg: RunConfig) {
    let mut sess = match SessionBuilder::from_config(cfg)
        .cancel_token(h.cancel_token())
        .observer(Box::new(ServeObserver::new(h.events.clone())))
        .build()
    {
        Ok(s) => s,
        Err(e) => {
            let msg = format!("{e:#}");
            h.events.push(error_line(&msg));
            h.set_status(Status::Failed { error: msg });
            h.events.close();
            return;
        }
    };
    h.set_status(Status::Running);
    match sess.run() {
        Ok(()) => {
            let steps = sess.step_count();
            if h.cancel_token().is_cancelled() {
                h.set_status(Status::Cancelled { steps });
            } else {
                h.set_status(Status::Done { steps, final_val_acc: sess.final_val_acc() });
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            h.events.push(error_line(&msg));
            h.set_status(Status::Failed { error: msg });
        }
    }
    h.events.close();
}

/// Terminal `{"event":"error",...}` line for failed runs, JSON-escaped.
fn error_line(msg: &str) -> String {
    json::obj(vec![("event", json::s("error")), ("message", json::s(msg))]).to_string()
}

/// The control-plane listener.
pub struct Server {
    listener: TcpListener,
    registry: Arc<Registry>,
}

impl Server {
    /// Binds the control plane; `host:0` picks an ephemeral port, read
    /// it back with [`Server::local_addr`].
    pub fn bind(addr: &str) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding control plane on {addr}"))?;
        Ok(Server { listener, registry: Arc::new(Registry::default()) })
    }

    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        self.listener.local_addr().context("reading bound address")
    }

    pub fn registry(&self) -> Arc<Registry> {
        self.registry.clone()
    }

    /// Accept loop: one short-lived handler thread per connection, one
    /// request per connection. Runs until the listener dies.
    pub fn run(self) -> anyhow::Result<()> {
        for conn in self.listener.incoming() {
            match conn {
                Ok(stream) => {
                    let registry = self.registry.clone();
                    let spawned = std::thread::Builder::new()
                        .name("lgp-serve-conn".to_string())
                        .spawn(move || handle_connection(&registry, stream));
                    if let Err(e) = spawned {
                        crate::log_warn!("serve: handler spawn failed: {e}");
                    }
                }
                Err(e) => crate::log_warn!("serve: accept failed: {e}"),
            }
        }
        Ok(())
    }

    /// Embedding/test convenience: runs the accept loop on a background
    /// thread and returns the bound address plus the shared registry.
    /// The thread (and any hosted sessions) live until process exit.
    pub fn spawn(self) -> anyhow::Result<(SocketAddr, Arc<Registry>)> {
        let addr = self.local_addr()?;
        let registry = self.registry();
        std::thread::Builder::new()
            .name("lgp-serve-accept".to_string())
            .spawn(move || {
                let _ = self.run();
            })
            .context("spawning accept loop")?;
        Ok((addr, registry))
    }
}

/// Reads exactly one bounded request and answers it. Every failure mode
/// is a structured JSON error — hostile input must never panic a
/// handler, and a dead socket is just an early return.
fn handle_connection(registry: &Registry, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(http::IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(http::IO_TIMEOUT));
    let req = match http::read_request(&mut stream) {
        Ok(r) => r,
        Err(http::BadRequest::TooLarge { status, what }) => {
            let _ = http::respond_error(&mut stream, status, what);
            finish_rejected(&mut stream);
            return;
        }
        Err(http::BadRequest::Malformed(msg)) => {
            let _ = http::respond_error(&mut stream, 400, &msg);
            finish_rejected(&mut stream);
            return;
        }
        Err(http::BadRequest::Io(_)) => return,
    };
    // Route errors are write failures: the peer is gone, nothing to do.
    let _ = route(registry, &mut stream, &req);
}

/// After rejecting a request mid-read: half-close so the client sees
/// the error response + EOF, then discard (bounded) whatever it was
/// still sending — closing with unread data would RST the connection
/// and can destroy the in-flight error response. The discard buffer is
/// a fixed scratch array; per-connection memory stays bounded even
/// here, and the socket read timeout bounds the time.
fn finish_rejected(stream: &mut TcpStream) {
    use std::io::Read;
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let mut scratch = [0u8; 4096];
    let mut budget: usize = 256 * 1024;
    while budget > 0 {
        match stream.read(&mut scratch) {
            Ok(0) | Err(_) => break,
            Ok(n) => budget = budget.saturating_sub(n),
        }
    }
}

fn route(registry: &Registry, stream: &mut TcpStream, req: &Request) -> std::io::Result<()> {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => http::respond_json(stream, 200, r#"{"ok":true}"#),
        ("POST", ["sessions"]) => post_session(registry, stream, &req.body),
        ("GET", ["sessions"]) => {
            let docs: Vec<Json> = registry
                .ids()
                .into_iter()
                .filter_map(|id| registry.get(id))
                .map(|h| h.status_json())
                .collect();
            http::respond_json(stream, 200, &Json::Arr(docs).to_string())
        }
        ("GET", ["sessions", id]) => with_session(registry, stream, id, |stream, h| {
            http::respond_json(stream, 200, &h.status_json().to_string())
        }),
        ("POST", ["sessions", id, "cancel"]) => with_session(registry, stream, id, |stream, h| {
            h.request_cancel();
            http::respond_json(stream, 202, &h.status_json().to_string())
        }),
        ("GET", ["sessions", id, "events"]) => {
            with_session(registry, stream, id, stream_events)
        }
        _ => http::respond_error(
            stream,
            404,
            &format!("no route for {} {}", req.method, req.path),
        ),
    }
}

/// Resolves `:id`, answering 404 for unknown or non-numeric ids.
fn with_session<F>(
    registry: &Registry,
    stream: &mut TcpStream,
    id: &str,
    f: F,
) -> std::io::Result<()>
where
    F: FnOnce(&mut TcpStream, &Hosted) -> std::io::Result<()>,
{
    let Ok(id) = id.parse::<u64>() else {
        return http::respond_error(stream, 404, &format!("bad session id {id:?}"));
    };
    match registry.get(id) {
        Some(h) => f(stream, &h),
        None => http::respond_error(stream, 404, &format!("no session {id}")),
    }
}

/// `POST /sessions`: parse with the hardened `Json::parse` (adversarial
/// documents come back as 400s naming the byte offset), apply through
/// the strict builder (400 naming the field), spawn, answer 201 with
/// the status document.
fn post_session(registry: &Registry, stream: &mut TcpStream, body: &[u8]) -> std::io::Result<()> {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return http::respond_error(stream, 400, "request body is not UTF-8"),
    };
    let doc = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => return http::respond_error(stream, 400, &format!("{e}")),
    };
    match registry.submit(&doc) {
        Ok(h) => http::respond_json(stream, 201, &h.status_json().to_string()),
        Err(e) => http::respond_error(stream, 400, &format!("{e:#}")),
    }
}

/// `GET /sessions/:id/events`: replays the retained window, then
/// follows live events as chunked JSONL until the run ends. Gaps the
/// drop-oldest policy evicted unseen are surfaced as
/// `{"event":"dropped","count":n}` markers, never silently skipped.
fn stream_events(stream: &mut TcpStream, h: &Hosted) -> std::io::Result<()> {
    http::start_chunked(stream, 200)?;
    let mut cursor: Option<u64> = None;
    loop {
        let batch = h.events.read_after(cursor, STREAM_POLL);
        if batch.dropped > 0 {
            http::write_chunk_line(
                stream,
                &format!(r#"{{"event":"dropped","count":{}}}"#, batch.dropped),
            )?;
        }
        for (seq, line) in &batch.lines {
            http::write_chunk_line(stream, line)?;
            cursor = Some(*seq);
        }
        if batch.done {
            break;
        }
        // Push partial progress now; also surfaces a dead peer as an
        // error on the next wakeup instead of looping forever.
        stream.flush()?;
    }
    http::end_chunked(stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hosted(status: Status) -> Hosted {
        Hosted {
            id: 7,
            status: Mutex::new(status),
            cancel: CancelToken::new(),
            events: Arc::new(EventHub::new(8)),
        }
    }

    #[test]
    fn submit_rejects_bad_documents_with_structured_errors() {
        let reg = Registry::default();
        for (doc, needle) in [
            (r#"{"shards": -1}"#, "shards"),
            (r#"{"steps": 1.5}"#, "steps"),
            (r#"{"max_steps": 1.5}"#, "max_steps"),
            (r#"{"banana": 1}"#, "banana"),
            (r#"[1, 2, 3]"#, "object"),
        ] {
            let j = Json::parse(doc).unwrap();
            let err = reg.submit(&j).expect_err(doc);
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{doc}: {msg}");
        }
        assert!(reg.ids().is_empty(), "rejected documents must not register sessions");
    }

    #[test]
    fn status_documents_carry_terminal_details() {
        use crate::util::json::Json;
        let h = hosted(Status::Pending);
        let j = h.status_json();
        assert_eq!(j.get("id").and_then(Json::as_u64), Some(7));
        assert_eq!(j.get("status").and_then(Json::as_str), Some("pending"));
        assert!(!h.status().is_terminal());

        let h = hosted(Status::Done { steps: 12, final_val_acc: 0.5 });
        let j = h.status_json();
        assert_eq!(j.get("status").and_then(Json::as_str), Some("done"));
        assert_eq!(j.get("steps").and_then(Json::as_usize), Some(12));
        assert!(h.status().is_terminal());

        let h = hosted(Status::Failed { error: "boom \"quoted\"".into() });
        let text = h.status_json().to_string();
        let parsed = Json::parse(&text).expect("error strings must stay JSON-escaped");
        assert_eq!(parsed.get("error").and_then(Json::as_str), Some("boom \"quoted\""));
    }

    #[test]
    fn cancel_is_per_session_and_idempotent() {
        let a = hosted(Status::Running);
        let b = hosted(Status::Running);
        a.request_cancel();
        a.request_cancel();
        assert!(a.cancel_token().is_cancelled());
        // Global-flag independence is pinned (under the SIGINT lock) by
        // rust/tests/graceful_shutdown.rs; here just the token isolation.
        assert!(!b.cancel_token().is_cancelled(), "tokens must be independent");
    }
}
