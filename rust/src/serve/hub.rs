//! Bounded event queue between a hosted session and its streaming
//! readers (DESIGN.md ADR-009 backpressure policy).
//!
//! The training loop must never block on a slow (or absent) HTTP
//! client, and per-session memory must stay bounded no matter how long
//! a run goes unobserved. So the hub is a fixed-capacity ring: pushes
//! always succeed, evicting the *oldest* retained line when full.
//! Lines carry dense sequence numbers; a reader whose cursor falls
//! behind the retained window sees the gap explicitly (the stream
//! surfaces it as a `{"event":"dropped","count":n}` marker) instead of
//! silently missing events.

use crate::metrics::LogRow;
use crate::observer::{
    self, CheckpointEvent, RefitEvent, RunSummary, TrainObserver,
};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Retained-line cap per session. At the tiny preset's event rate this
/// holds an entire short run; long runs keep the newest window, which is
/// what a late-attaching poller wants anyway.
pub const EVENT_QUEUE_CAP: usize = 256;

/// Fixed-capacity, seq-numbered event queue (`Mutex` + `Condvar`).
pub struct EventHub {
    cap: usize,
    state: Mutex<HubState>,
    cond: Condvar,
}

struct HubState {
    /// `(seq, jsonl line)` — seq is dense from 0, so a gap between a
    /// reader's cursor and the oldest retained seq counts exactly the
    /// lines drop-oldest evicted unseen.
    lines: VecDeque<(u64, String)>,
    next_seq: u64,
    closed: bool,
}

/// One blocking read: everything after the cursor, plus the size of any
/// evicted gap.
pub struct Batch {
    /// Lines evicted before the reader saw them (0 when caught up).
    pub dropped: u64,
    pub lines: Vec<(u64, String)>,
    /// True once the hub is closed *and* the cursor has drained it — the
    /// stream can terminate.
    pub done: bool,
}

impl EventHub {
    pub fn new(cap: usize) -> EventHub {
        EventHub {
            cap: cap.max(1),
            state: Mutex::new(HubState { lines: VecDeque::new(), next_seq: 0, closed: false }),
            cond: Condvar::new(),
        }
    }

    /// Appends a line, evicting the oldest when at capacity. Never
    /// blocks beyond the lock; ignored after [`EventHub::close`].
    pub fn push(&self, line: String) {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return;
        }
        if s.lines.len() >= self.cap {
            s.lines.pop_front();
        }
        let seq = s.next_seq;
        s.next_seq += 1;
        s.lines.push_back((seq, line));
        drop(s);
        self.cond.notify_all();
    }

    /// Marks the producer finished; readers drain what is retained and
    /// then see `done`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Lines strictly after `after` (`None` = from the start of the
    /// retained window), blocking up to `timeout` for new ones. An empty
    /// non-`done` batch means the wait timed out — callers loop, which
    /// keeps them responsive to their own transport dying.
    pub fn read_after(&self, after: Option<u64>, timeout: Duration) -> Batch {
        let mut s = self.state.lock().unwrap();
        loop {
            let next_wanted = after.map_or(0, |a| a + 1);
            let newest = s.lines.back().map(|(seq, _)| *seq);
            if newest.map_or(false, |n| n >= next_wanted) {
                let oldest = s.lines.front().map_or(next_wanted, |(seq, _)| *seq);
                let dropped = oldest.saturating_sub(next_wanted);
                let lines: Vec<(u64, String)> =
                    s.lines.iter().filter(|(seq, _)| *seq >= next_wanted).cloned().collect();
                return Batch { dropped, lines, done: false };
            }
            if s.closed {
                return Batch { dropped: 0, lines: Vec::new(), done: true };
            }
            let (guard, res) = self.cond.wait_timeout(s, timeout).unwrap();
            s = guard;
            if res.timed_out() {
                return Batch { dropped: 0, lines: Vec::new(), done: false };
            }
        }
    }
}

/// ADR-005 observer that renders events with the shared
/// [`observer::step_line`]-family formatters — byte-identical to the
/// `JsonlObserver` file format — and pushes them into an [`EventHub`].
/// Purely in-memory: the training loop never waits on a network peer.
pub struct ServeObserver {
    hub: Arc<EventHub>,
}

impl ServeObserver {
    pub fn new(hub: Arc<EventHub>) -> ServeObserver {
        ServeObserver { hub }
    }
}

impl TrainObserver for ServeObserver {
    fn on_step(&mut self, row: &LogRow) -> anyhow::Result<()> {
        self.hub.push(observer::step_line(row));
        Ok(())
    }

    fn on_eval(&mut self, step: usize, val_acc: f64) -> anyhow::Result<()> {
        self.hub.push(observer::eval_line(step, val_acc));
        Ok(())
    }

    fn on_refit(&mut self, ev: &RefitEvent) -> anyhow::Result<()> {
        self.hub.push(observer::refit_line(ev));
        Ok(())
    }

    fn on_checkpoint(&mut self, ev: &CheckpointEvent) -> anyhow::Result<()> {
        self.hub.push(observer::checkpoint_line(ev));
        Ok(())
    }

    fn on_end(&mut self, s: &RunSummary) -> anyhow::Result<()> {
        self.hub.push(observer::end_line(s));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(hub: &EventHub, after: Option<u64>) -> Batch {
        hub.read_after(after, Duration::from_millis(10))
    }

    #[test]
    fn drop_oldest_keeps_newest_and_reports_the_gap() {
        let hub = EventHub::new(4);
        for i in 0..10 {
            hub.push(format!("l{i}"));
        }
        let b = drain(&hub, None);
        assert_eq!(b.dropped, 6, "six lines were evicted unseen");
        let texts: Vec<&str> = b.lines.iter().map(|(_, l)| l.as_str()).collect();
        assert_eq!(texts, ["l6", "l7", "l8", "l9"]);
        assert_eq!(b.lines.first().unwrap().0, 6);
        assert!(!b.done);
    }

    #[test]
    fn cursor_reads_see_only_new_lines_without_gaps() {
        let hub = EventHub::new(8);
        hub.push("a".into());
        hub.push("b".into());
        let b = drain(&hub, None);
        assert_eq!(b.dropped, 0);
        assert_eq!(b.lines.len(), 2);
        let cursor = b.lines.last().unwrap().0;
        hub.push("c".into());
        let b = drain(&hub, Some(cursor));
        assert_eq!(b.dropped, 0);
        assert_eq!(b.lines.len(), 1);
        assert_eq!(b.lines[0].1, "c");
        // Caught up: an idle wait times out as a non-done empty batch.
        let b = drain(&hub, Some(b.lines[0].0));
        assert!(b.lines.is_empty() && !b.done);
    }

    #[test]
    fn close_wakes_blocked_readers_and_drains_cleanly() {
        let hub = Arc::new(EventHub::new(8));
        let h = hub.clone();
        let reader = std::thread::spawn(move || {
            let mut cursor = None;
            let mut got = Vec::new();
            loop {
                let b = h.read_after(cursor, Duration::from_secs(5));
                for (seq, line) in b.lines {
                    got.push(line);
                    cursor = Some(seq);
                }
                if b.done {
                    return got;
                }
            }
        });
        hub.push("x".into());
        hub.push("y".into());
        hub.close();
        assert_eq!(reader.join().unwrap(), ["x", "y"]);
    }

    #[test]
    fn push_after_close_is_ignored() {
        let hub = EventHub::new(8);
        hub.push("kept".into());
        hub.close();
        hub.push("lost".into());
        let b = drain(&hub, None);
        assert_eq!(b.lines.len(), 1);
        assert_eq!(b.lines[0].1, "kept");
    }

    #[test]
    fn serve_observer_formats_match_the_jsonl_file_format() {
        use crate::util::json::Json;
        let hub = Arc::new(EventHub::new(8));
        let mut obs = ServeObserver::new(hub.clone());
        let row = LogRow {
            step: 3,
            wall_secs: 0.5,
            loss: 1.25,
            train_acc: 0.5,
            val_acc: f64::NAN,
            rho: f64::NAN,
            kappa: f64::NAN,
            phi: f64::NAN,
            examples_seen: 96,
        };
        obs.on_step(&row).unwrap();
        obs.on_eval(3, 0.75).unwrap();
        obs.on_end(&RunSummary {
            steps: 3,
            final_val_acc: 0.75,
            examples_seen: 96,
            cost_units: 9.0,
            wall_secs: 0.5,
        })
        .unwrap();
        let b = drain(&hub, None);
        assert_eq!(b.lines.len(), 3);
        assert_eq!(b.lines[0].1, observer::step_line(&row), "wire and file formats must agree");
        for (_, line) in &b.lines {
            let j = Json::parse(line).unwrap_or_else(|e| panic!("bad line {line}: {e}"));
            assert!(j.get("event").and_then(Json::as_str).is_some());
        }
        assert_eq!(
            Json::parse(&b.lines[1].1).unwrap().get("event").and_then(Json::as_str),
            Some("eval")
        );
    }
}
