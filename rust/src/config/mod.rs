//! Typed run configuration: manifest-derived model facts + user-tunable
//! training knobs.
//!
//! The *architecture* lives in the AOT manifest (shapes are baked into the
//! HLO artifacts); this module carries everything the session may vary at
//! run time without re-lowering: control fraction f, optimizer choice and
//! learning rate, accumulation, refit period, budgets, seeds.
//!
//! Since ADR-005, configuration *construction* belongs to
//! `crate::session::SessionBuilder` (typed chainable setters, JSON
//! config files, the CLI adapter in `crate::session::cli`); this module
//! owns the value type, its validation, and the enum flag tables that
//! keep the parsers and `--help` in lockstep.

use crate::tensor::backend::BackendKind;
use crate::util::cli::{parse_enum, EnumSpec};
use crate::util::json::Json;
use std::path::PathBuf;
use std::str::FromStr;

/// Which training algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Algorithm 2: vanilla mini-batch gradient descent (the baseline).
    Baseline,
    /// Algorithm 1: predicted gradient descent with control variates (GPR).
    Gpr,
}

impl Algo {
    /// Single source of truth for the parser and the `--help` option
    /// list (`util::cli::options(Algo::SPECS)`).
    pub const SPECS: &'static [EnumSpec<Algo>] = &[
        EnumSpec { name: "baseline", aliases: &["vanilla"], value: Algo::Baseline },
        EnumSpec { name: "gpr", aliases: &["predicted"], value: Algo::Gpr },
    ];

    pub fn parse(s: &str) -> anyhow::Result<Algo> {
        s.parse()
    }
}

impl FromStr for Algo {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Algo> {
        parse_enum(Algo::SPECS, "algo", s)
    }
}

/// Gradient-estimator zoo selection (DESIGN.md ADR-006). `None` in
/// [`RunConfig::estimator`] keeps the legacy [`Algo`] mapping
/// (baseline → true-backprop, gpr → control-variate); setting a kind —
/// via `SessionBuilder::estimator_kind`, the `estimator` JSON key, or
/// `--estimator` — picks a zoo member explicitly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EstimatorKind {
    /// Algorithm 2: full Forward+Backward on every example.
    TrueBackprop,
    /// Algorithm 1 (GPR): eq. (1) with the linear NTK predictor.
    ControlVariate,
    /// The biased no-correction blend (the Sec. 3 ablation).
    PredictedLgp,
    /// K-tangent forward gradients (arXiv 2410.17764).
    MultiTangent,
    /// Learned MLP control-variate predictor (arXiv 1806.00159).
    NeuralCv,
}

impl EstimatorKind {
    /// Single source of truth for the parser and the `--help` option
    /// list. Names match `GradientEstimator::name()` so bench labels,
    /// logs and flags agree.
    pub const SPECS: &'static [EnumSpec<EstimatorKind>] = &[
        EnumSpec {
            name: "true-backprop",
            aliases: &["backprop"],
            value: EstimatorKind::TrueBackprop,
        },
        EnumSpec {
            name: "control-variate",
            aliases: &["cv", "gpr"],
            value: EstimatorKind::ControlVariate,
        },
        EnumSpec { name: "predicted-lgp", aliases: &["lgp"], value: EstimatorKind::PredictedLgp },
        EnumSpec {
            name: "multi-tangent",
            aliases: &["mtf", "forward"],
            value: EstimatorKind::MultiTangent,
        },
        EnumSpec { name: "neural-cv", aliases: &["ncv"], value: EstimatorKind::NeuralCv },
    ];

    /// Every zoo member, in canonical sweep order.
    pub const ALL: &'static [EstimatorKind] = &[
        EstimatorKind::TrueBackprop,
        EstimatorKind::ControlVariate,
        EstimatorKind::PredictedLgp,
        EstimatorKind::MultiTangent,
        EstimatorKind::NeuralCv,
    ];

    /// Canonical name (the `GradientEstimator::name()` string).
    pub fn as_str(self) -> &'static str {
        match self {
            EstimatorKind::TrueBackprop => "true-backprop",
            EstimatorKind::ControlVariate => "control-variate",
            EstimatorKind::PredictedLgp => "predicted-lgp",
            EstimatorKind::MultiTangent => "multi-tangent",
            EstimatorKind::NeuralCv => "neural-cv",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<EstimatorKind> {
        s.parse()
    }
}

impl FromStr for EstimatorKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<EstimatorKind> {
        parse_enum(EstimatorKind::SPECS, "estimator", s)
    }
}

/// Optimizer selection (paper trains with Muon, lr 0.02).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimKind {
    Sgd,
    Momentum,
    AdamW,
    Muon,
}

impl OptimKind {
    /// Single source of truth for the parser and the `--help` option
    /// list.
    pub const SPECS: &'static [EnumSpec<OptimKind>] = &[
        EnumSpec { name: "muon", aliases: &[], value: OptimKind::Muon },
        EnumSpec { name: "adamw", aliases: &[], value: OptimKind::AdamW },
        EnumSpec { name: "sgd", aliases: &[], value: OptimKind::Sgd },
        EnumSpec { name: "momentum", aliases: &[], value: OptimKind::Momentum },
    ];

    pub fn parse(s: &str) -> anyhow::Result<OptimKind> {
        s.parse()
    }
}

impl FromStr for OptimKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<OptimKind> {
        parse_enum(OptimKind::SPECS, "optimizer", s)
    }
}

/// Full run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Directory holding manifest.json + *.hlo.txt for the chosen preset.
    pub artifacts_dir: PathBuf,
    pub algo: Algo,
    /// Explicit estimator-zoo selection (ADR-006); `None` derives the
    /// estimator from `algo`.
    pub estimator: Option<EstimatorKind>,
    /// Tangent-direction count K for the multi-tangent estimator.
    pub tangents: usize,
    /// Control fraction f ∈ (0, 1]; the paper's headline run uses 1/4.
    pub f: f64,
    /// Gradient-accumulation micro-batches per optimizer update (paper: 8).
    pub accum: usize,
    pub optimizer: OptimKind,
    /// Muon learning rate default follows the paper (0.02).
    pub lr: f64,
    pub weight_decay: f64,
    /// Wall-clock budget in seconds; 0 disables the budget.
    pub budget_secs: f64,
    /// Maximum optimizer updates; 0 = unlimited (budget governs).
    pub max_steps: usize,
    /// Predictor refit period in optimizer updates.
    pub refit_every: usize,
    /// Ridge regularizer for the kernel-ridge coefficient fit.
    pub ridge_lambda: f64,
    /// Dataset sizes (synthetic CIFAR-10 substitute).
    pub train_size: usize,
    pub val_size: usize,
    /// Pre-augmentation multiplier (paper: 2x -> 100k from 50k).
    pub aug_multiplier: usize,
    pub seed: u64,
    /// Evaluate validation accuracy every N updates (0 = only at end).
    pub eval_every: usize,
    /// Directory for CSV/JSONL outputs.
    pub out_dir: PathBuf,
    /// Track ρ̂/κ̂ alignment diagnostics on control batches.
    pub track_alignment: bool,
    /// Adaptive control fraction (Theorem 4 online): steer f toward the
    /// quantized f*(ρ̂, κ̂) among the fractions with lowered artifacts.
    pub adaptive_f: bool,
    /// Host tensor backend for the dense hot paths (`--backend`); `Auto`
    /// runs the one-shot calibration probe at startup (DESIGN.md §2).
    pub backend: BackendKind,
    /// Data-parallel worker shards per optimizer update (`--shards`,
    /// DESIGN.md ADR-004). Micro-batches scatter round-robin over this
    /// many threads; 1 = serial. Any value yields bit-identical results —
    /// the fixed-topology reduction is the determinism contract.
    pub shards: usize,
    /// Directory for crash-safe session checkpoints (`--checkpoint-dir`,
    /// DESIGN.md ADR-008); `None` disables checkpointing entirely.
    pub checkpoint_dir: Option<PathBuf>,
    /// Write a checkpoint every N optimizer updates (0 = only on
    /// graceful shutdown). Ignored without `checkpoint_dir`.
    pub checkpoint_every: usize,
    /// Retain only the newest K valid checkpoint artifacts after each
    /// successful write (`--checkpoint-keep`, 0 = keep everything). The
    /// artifact just written is never pruned; torn artifacts never count
    /// toward K and are pruned last.
    pub checkpoint_keep: usize,
    /// Resume from the newest valid checkpoint in `checkpoint_dir`
    /// before training (`--resume`); a fresh run if the dir is empty.
    pub resume: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts/tiny"),
            algo: Algo::Gpr,
            estimator: None,
            tangents: 8,
            f: 0.25,
            accum: 8,
            optimizer: OptimKind::Muon,
            lr: 0.02,
            weight_decay: 0.0,
            budget_secs: 0.0,
            max_steps: 50,
            refit_every: 20,
            ridge_lambda: 1e-4,
            train_size: 2000,
            val_size: 500,
            aug_multiplier: 2,
            seed: 0,
            eval_every: 10,
            out_dir: PathBuf::from("runs"),
            track_alignment: true,
            adaptive_f: false,
            backend: BackendKind::Auto,
            shards: 1,
            checkpoint_dir: None,
            checkpoint_every: 0,
            checkpoint_keep: 0,
            resume: false,
        }
    }
}

/// `LGP_SHARDS` override for test harnesses: the integration suites call
/// this so `LGP_SHARDS=2 cargo test -q` exercises the parallel executor
/// without editing every config literal. Not consulted by `RunConfig`
/// itself — CLI/JSON stay the single source of truth for real runs.
///
/// A malformed value (`LGP_SHARDS=abc`, `LGP_SHARDS=0`) is a hard error
/// naming the variable and the offending value — never a silent fallback
/// to the serial path, which would quietly skip the coverage the caller
/// asked for.
pub fn shards_env_override() -> anyhow::Result<Option<usize>> {
    let shards = crate::util::env_parse::<usize>("LGP_SHARDS")?;
    if let Some(s) = shards {
        anyhow::ensure!(s >= 1, "LGP_SHARDS must be >= 1, got {s}");
    }
    Ok(shards)
}

/// Validate a multi-process partition (DESIGN.md ADR-010): `procs`
/// processes each own a contiguous group of `accum / procs` micro-batch
/// slots, so the slot count must tile evenly — a ragged partition would
/// change which stream positions exist and break the bit-identity
/// contract with `--shards P*S` single-process runs. Used by both
/// `lgp launch` and the dist handshake, so the launcher and a hand-rolled
/// follower reject the same geometries.
pub fn validate_dist(procs: usize, accum: usize) -> anyhow::Result<()> {
    anyhow::ensure!(procs >= 1, "dist procs must be >= 1, got {procs}");
    anyhow::ensure!(
        accum % procs == 0 && accum / procs >= 1,
        "accum {accum} does not tile over {procs} processes \
         (need accum % procs == 0 with at least one slot each)"
    );
    Ok(())
}

impl RunConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.f > 0.0 && self.f <= 1.0, "f must be in (0,1], got {}", self.f);
        anyhow::ensure!(self.accum >= 1, "accum must be >= 1");
        anyhow::ensure!(self.lr > 0.0, "lr must be positive");
        anyhow::ensure!(
            self.budget_secs > 0.0 || self.max_steps > 0,
            "need a wall-clock budget or a step limit"
        );
        anyhow::ensure!(self.train_size >= 16, "train_size too small");
        anyhow::ensure!(self.shards >= 1, "shards must be >= 1, got {}", self.shards);
        anyhow::ensure!(self.tangents >= 1, "tangents must be >= 1, got {}", self.tangents);
        anyhow::ensure!(
            !self.resume || self.checkpoint_dir.is_some(),
            "resume requires a checkpoint directory (--resume needs --checkpoint-dir)"
        );
        Ok(())
    }

    pub fn load_json_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::options;

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn invalid_f_rejected() {
        let mut c = RunConfig::default();
        c.f = 0.0;
        assert!(c.validate().is_err());
        c.f = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn zero_shards_rejected() {
        let mut c = RunConfig::default();
        c.shards = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn missing_termination_rejected() {
        let mut c = RunConfig::default();
        c.max_steps = 0;
        c.budget_secs = 0.0;
        let err = c.validate().unwrap_err();
        assert!(format!("{err}").contains("budget or a step limit"), "{err}");
    }

    #[test]
    fn enum_tables_drive_fromstr_and_aliases() {
        assert_eq!("gpr".parse::<Algo>().unwrap(), Algo::Gpr);
        assert_eq!("predicted".parse::<Algo>().unwrap(), Algo::Gpr);
        assert_eq!("vanilla".parse::<Algo>().unwrap(), Algo::Baseline);
        assert_eq!(Algo::parse("baseline").unwrap(), Algo::Baseline);
        assert!(Algo::parse("nope").is_err());
        assert_eq!("muon".parse::<OptimKind>().unwrap(), OptimKind::Muon);
        assert_eq!(OptimKind::parse("adamw").unwrap(), OptimKind::AdamW);
        assert!(OptimKind::parse("lion").is_err());
    }

    #[test]
    fn option_lists_match_parsers() {
        // The help text renders options(SPECS); every listed name must
        // round-trip through the parser — the no-drift contract.
        assert_eq!(options(Algo::SPECS), "baseline|gpr");
        assert_eq!(options(OptimKind::SPECS), "muon|adamw|sgd|momentum");
        for spec in Algo::SPECS {
            assert_eq!(spec.name.parse::<Algo>().unwrap(), spec.value);
        }
        for spec in OptimKind::SPECS {
            assert_eq!(spec.name.parse::<OptimKind>().unwrap(), spec.value);
        }
    }

    #[test]
    fn unknown_enum_error_names_the_options() {
        let err = "nope".parse::<Algo>().unwrap_err();
        assert_eq!(format!("{err}"), "unknown algo 'nope' (want baseline|gpr)");
    }

    #[test]
    fn estimator_zoo_table_round_trips() {
        assert_eq!(
            options(EstimatorKind::SPECS),
            "true-backprop|control-variate|predicted-lgp|multi-tangent|neural-cv"
        );
        for spec in EstimatorKind::SPECS {
            assert_eq!(spec.name.parse::<EstimatorKind>().unwrap(), spec.value);
            // name == as_str == GradientEstimator::name() — one label
            // everywhere (flags, logs, bench records).
            assert_eq!(spec.name, spec.value.as_str());
        }
        assert_eq!("cv".parse::<EstimatorKind>().unwrap(), EstimatorKind::ControlVariate);
        assert_eq!("mtf".parse::<EstimatorKind>().unwrap(), EstimatorKind::MultiTangent);
        assert_eq!("ncv".parse::<EstimatorKind>().unwrap(), EstimatorKind::NeuralCv);
        assert!(EstimatorKind::parse("nope").is_err());
        assert_eq!(EstimatorKind::ALL.len(), EstimatorKind::SPECS.len());
    }

    #[test]
    fn resume_without_checkpoint_dir_rejected() {
        let mut c = RunConfig::default();
        c.resume = true;
        let err = c.validate().unwrap_err();
        assert!(format!("{err}").contains("--checkpoint-dir"), "{err}");
        c.checkpoint_dir = Some(PathBuf::from("ckpts"));
        c.validate().unwrap();
    }

    #[test]
    fn zero_tangents_rejected() {
        let mut c = RunConfig::default();
        c.tangents = 0;
        let err = c.validate().unwrap_err();
        assert!(format!("{err}").contains("tangents"), "{err}");
    }

    // shards_env_override itself is exercised by the integration suites
    // (mutating LGP_SHARDS here would race the `LGP_SHARDS=2 cargo test`
    // smoke run); the parse/error behavior is pinned on util::env_parse
    // with a test-private variable name.
}
