//! Typed run configuration: manifest-derived model facts + user-tunable
//! training knobs, with JSON config-file loading and CLI overrides.
//!
//! The *architecture* lives in the AOT manifest (shapes are baked into the
//! HLO artifacts); this module carries everything the coordinator may vary
//! at run time without re-lowering: control fraction f, optimizer choice
//! and learning rate, accumulation, refit period, budgets, seeds.

use crate::tensor::backend::BackendKind;
use crate::util::cli::Args;
use crate::util::json::Json;
use std::path::PathBuf;

/// Which training algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Algorithm 2: vanilla mini-batch gradient descent (the baseline).
    Baseline,
    /// Algorithm 1: predicted gradient descent with control variates (GPR).
    Gpr,
}

impl Algo {
    pub fn parse(s: &str) -> anyhow::Result<Algo> {
        match s {
            "baseline" | "vanilla" => Ok(Algo::Baseline),
            "gpr" | "predicted" => Ok(Algo::Gpr),
            other => anyhow::bail!("unknown algo '{other}' (want baseline|gpr)"),
        }
    }
}

/// Optimizer selection (paper trains with Muon, lr 0.02).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimKind {
    Sgd,
    Momentum,
    AdamW,
    Muon,
}

impl OptimKind {
    pub fn parse(s: &str) -> anyhow::Result<OptimKind> {
        match s {
            "sgd" => Ok(OptimKind::Sgd),
            "momentum" => Ok(OptimKind::Momentum),
            "adamw" => Ok(OptimKind::AdamW),
            "muon" => Ok(OptimKind::Muon),
            other => anyhow::bail!("unknown optimizer '{other}'"),
        }
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Directory holding manifest.json + *.hlo.txt for the chosen preset.
    pub artifacts_dir: PathBuf,
    pub algo: Algo,
    /// Control fraction f ∈ (0, 1]; the paper's headline run uses 1/4.
    pub f: f64,
    /// Gradient-accumulation micro-batches per optimizer update (paper: 8).
    pub accum: usize,
    pub optimizer: OptimKind,
    /// Muon learning rate default follows the paper (0.02).
    pub lr: f64,
    pub weight_decay: f64,
    /// Wall-clock budget in seconds; 0 disables the budget.
    pub budget_secs: f64,
    /// Maximum optimizer updates; 0 = unlimited (budget governs).
    pub max_steps: usize,
    /// Predictor refit period in optimizer updates.
    pub refit_every: usize,
    /// Ridge regularizer for the kernel-ridge coefficient fit.
    pub ridge_lambda: f64,
    /// Dataset sizes (synthetic CIFAR-10 substitute).
    pub train_size: usize,
    pub val_size: usize,
    /// Pre-augmentation multiplier (paper: 2x -> 100k from 50k).
    pub aug_multiplier: usize,
    pub seed: u64,
    /// Evaluate validation accuracy every N updates (0 = only at end).
    pub eval_every: usize,
    /// Directory for CSV/JSONL outputs.
    pub out_dir: PathBuf,
    /// Track ρ̂/κ̂ alignment diagnostics on control batches.
    pub track_alignment: bool,
    /// Adaptive control fraction (Theorem 4 online): steer f toward the
    /// quantized f*(ρ̂, κ̂) among the fractions with lowered artifacts.
    pub adaptive_f: bool,
    /// Host tensor backend for the dense hot paths (`--backend`); `Auto`
    /// runs the one-shot calibration probe at startup (DESIGN.md §2).
    pub backend: BackendKind,
    /// Data-parallel worker shards per optimizer update (`--shards`,
    /// DESIGN.md ADR-004). Micro-batches scatter round-robin over this
    /// many threads; 1 = serial. Any value yields bit-identical results —
    /// the fixed-topology reduction is the determinism contract.
    pub shards: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts/tiny"),
            algo: Algo::Gpr,
            f: 0.25,
            accum: 8,
            optimizer: OptimKind::Muon,
            lr: 0.02,
            weight_decay: 0.0,
            budget_secs: 0.0,
            max_steps: 50,
            refit_every: 20,
            ridge_lambda: 1e-4,
            train_size: 2000,
            val_size: 500,
            aug_multiplier: 2,
            seed: 0,
            eval_every: 10,
            out_dir: PathBuf::from("runs"),
            track_alignment: true,
            adaptive_f: false,
            backend: BackendKind::Auto,
            shards: 1,
        }
    }
}

/// `LGP_SHARDS` override for test harnesses: the integration suites call
/// this so `LGP_SHARDS=2 cargo test -q` exercises the parallel executor
/// without editing every config literal. Not consulted by `RunConfig`
/// itself — CLI/JSON stay the single source of truth for real runs.
pub fn shards_env_override() -> Option<usize> {
    std::env::var("LGP_SHARDS").ok()?.trim().parse().ok().filter(|&s| s >= 1)
}

impl RunConfig {
    /// Apply a JSON config document (same keys as the CLI flags).
    pub fn apply_json(&mut self, j: &Json) -> anyhow::Result<()> {
        if let Some(v) = j.get("artifacts_dir").and_then(Json::as_str) {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("algo").and_then(Json::as_str) {
            self.algo = Algo::parse(v)?;
        }
        if let Some(v) = j.get("optimizer").and_then(Json::as_str) {
            self.optimizer = OptimKind::parse(v)?;
        }
        if let Some(v) = j.get("out_dir").and_then(Json::as_str) {
            self.out_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("backend").and_then(Json::as_str) {
            self.backend = BackendKind::parse(v)?;
        }
        macro_rules! num {
            ($key:literal, $field:expr, $ty:ty) => {
                if let Some(v) = j.get($key).and_then(Json::as_f64) {
                    $field = v as $ty;
                }
            };
        }
        num!("f", self.f, f64);
        num!("accum", self.accum, usize);
        num!("lr", self.lr, f64);
        num!("weight_decay", self.weight_decay, f64);
        num!("budget_secs", self.budget_secs, f64);
        num!("max_steps", self.max_steps, usize);
        num!("refit_every", self.refit_every, usize);
        num!("ridge_lambda", self.ridge_lambda, f64);
        num!("train_size", self.train_size, usize);
        num!("val_size", self.val_size, usize);
        num!("aug_multiplier", self.aug_multiplier, usize);
        num!("seed", self.seed, u64);
        num!("eval_every", self.eval_every, usize);
        num!("shards", self.shards, usize);
        if let Some(v) = j.get("track_alignment").and_then(|x| x.as_bool()) {
            self.track_alignment = v;
        }
        if let Some(v) = j.get("adaptive_f").and_then(|x| x.as_bool()) {
            self.adaptive_f = v;
        }
        self.validate()
    }

    /// Apply CLI overrides (highest precedence). `--config file.json` is
    /// handled by the caller before this.
    pub fn apply_args(&mut self, a: &Args) -> anyhow::Result<()> {
        if let Some(v) = a.str_opt("artifacts") {
            self.artifacts_dir = PathBuf::from(v);
        } else if let Some(p) = a.str_opt("preset") {
            self.artifacts_dir = PathBuf::from(format!("artifacts/{p}"));
        }
        if let Some(v) = a.str_opt("algo") {
            self.algo = Algo::parse(&v)?;
        }
        if let Some(v) = a.str_opt("optimizer") {
            self.optimizer = OptimKind::parse(&v)?;
        }
        if let Some(v) = a.str_opt("out") {
            self.out_dir = PathBuf::from(v);
        }
        if let Some(v) = a.str_opt("backend") {
            self.backend = BackendKind::parse(&v)?;
        }
        self.f = a.f64_or("f", self.f);
        self.accum = a.usize_or("accum", self.accum);
        self.lr = a.f64_or("lr", self.lr);
        self.weight_decay = a.f64_or("weight-decay", self.weight_decay);
        self.budget_secs = a.f64_or("budget", self.budget_secs);
        self.max_steps = a.usize_or("steps", self.max_steps);
        self.refit_every = a.usize_or("refit-every", self.refit_every);
        self.ridge_lambda = a.f64_or("ridge", self.ridge_lambda);
        self.train_size = a.usize_or("train-size", self.train_size);
        self.val_size = a.usize_or("val-size", self.val_size);
        self.aug_multiplier = a.usize_or("aug-mult", self.aug_multiplier);
        self.seed = a.u64_or("seed", self.seed);
        self.eval_every = a.usize_or("eval-every", self.eval_every);
        self.shards = a.usize_or("shards", self.shards);
        if a.flag("no-alignment") {
            self.track_alignment = false;
        }
        if a.flag("adaptive-f") {
            self.adaptive_f = true;
        }
        self.validate()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.f > 0.0 && self.f <= 1.0, "f must be in (0,1], got {}", self.f);
        anyhow::ensure!(self.accum >= 1, "accum must be >= 1");
        anyhow::ensure!(self.lr > 0.0, "lr must be positive");
        anyhow::ensure!(
            self.budget_secs > 0.0 || self.max_steps > 0,
            "need a wall-clock budget or a step limit"
        );
        anyhow::ensure!(self.train_size >= 16, "train_size too small");
        anyhow::ensure!(self.shards >= 1, "shards must be >= 1, got {}", self.shards);
        Ok(())
    }

    pub fn load_json_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn json_overrides() {
        let mut c = RunConfig::default();
        let j = Json::parse(
            r#"{"algo":"baseline","f":0.5,"lr":0.1,"optimizer":"adamw",
                "max_steps":7,"track_alignment":false,"backend":"micro"}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.algo, Algo::Baseline);
        assert_eq!(c.optimizer, OptimKind::AdamW);
        assert_eq!(c.max_steps, 7);
        assert!(!c.track_alignment);
        assert!((c.f - 0.5).abs() < 1e-12);
        assert_eq!(c.backend, BackendKind::Micro);
    }

    #[test]
    fn cli_overrides_beat_defaults() {
        let mut c = RunConfig::default();
        let a = Args::parse(
            "train --preset small --algo gpr --f 0.125 --steps 3 --seed 9 --backend blocked"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_args(&a).unwrap();
        assert_eq!(c.artifacts_dir, PathBuf::from("artifacts/small"));
        assert_eq!(c.seed, 9);
        assert!((c.f - 0.125).abs() < 1e-12);
        assert_eq!(c.backend, BackendKind::Blocked);
    }

    #[test]
    fn bad_backend_string_rejected() {
        let mut c = RunConfig::default();
        let j = Json::parse(r#"{"backend":"gpu"}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
    }

    #[test]
    fn invalid_f_rejected() {
        let mut c = RunConfig::default();
        c.f = 0.0;
        assert!(c.validate().is_err());
        c.f = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn shards_parse_and_validate() {
        let mut c = RunConfig::default();
        let j = Json::parse(r#"{"shards":4}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.shards, 4);
        let a = Args::parse(
            "train --shards 2".split_whitespace().map(String::from),
        )
        .unwrap();
        c.apply_args(&a).unwrap();
        assert_eq!(c.shards, 2);
        c.shards = 0;
        assert!(c.validate().is_err());
        // (shards_env_override is exercised by the integration suites —
        // mutating the process environment here would race the parallel
        // unit tests that read env vars, e.g. the log-level checks.)
    }

    #[test]
    fn bad_algo_string_rejected() {
        assert!(Algo::parse("nope").is_err());
        assert_eq!(Algo::parse("gpr").unwrap(), Algo::Gpr);
        assert_eq!(OptimKind::parse("muon").unwrap(), OptimKind::Muon);
    }
}
