//! Training metrics: the paper's Section 5.3 monitoring quantities
//! (cosine alignment ρ̂, scale ratio κ̂, implied variance inflation φ̂ and
//! break-even margin), plus loss/accuracy meters and run logging.

use crate::theory::{self, CostModel};

/// Streaming estimator of the alignment statistics of Sec. 5:
///   σ_g² = E‖g−μ‖², σ_h² = E‖h−μ_h‖², τ = E⟨g−μ, h−μ_h⟩,
///   ρ = τ/(σ_g σ_h), κ = σ_h/σ_g,
/// estimated from per-example (g, h) pairs collected on control batches.
/// Means are estimated from the same sample (plug-in), which is standard
/// for a monitoring metric.
#[derive(Default)]
pub struct AlignmentTracker {
    /// Batches of per-example pairs pushed since the last `snapshot`.
    pairs: Vec<(Vec<f32>, Vec<f32>)>,
    /// Cap on retained pairs (memory guard for big trunks).
    pub max_pairs: usize,
}

/// Point-in-time alignment estimate.
#[derive(Clone, Copy, Debug)]
pub struct Alignment {
    pub rho: f64,
    pub kappa: f64,
    pub sigma_g: f64,
    pub sigma_h: f64,
    pub n: usize,
}

impl AlignmentTracker {
    pub fn new(max_pairs: usize) -> AlignmentTracker {
        AlignmentTracker { pairs: Vec::new(), max_pairs }
    }

    /// Push one per-example (true gradient, predicted gradient) pair.
    pub fn push(&mut self, g: Vec<f32>, h: Vec<f32>) {
        debug_assert_eq!(g.len(), h.len());
        if self.pairs.len() >= self.max_pairs.max(4) {
            self.pairs.remove(0); // sliding window
        }
        self.pairs.push((g, h));
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Compute (ρ̂, κ̂) from the retained window. None if < 2 pairs.
    pub fn snapshot(&self) -> Option<Alignment> {
        alignment_of(&self.pairs)
    }
}

/// One-shot alignment computation over (g, h) pair slices — the cheap
/// path the coordinator uses at refit time (no pair retention; a single
/// pass over the data). `AlignmentTracker` remains for streaming use.
pub fn alignment_of(pairs: &[(Vec<f32>, Vec<f32>)]) -> Option<Alignment> {
    let n = pairs.len();
    if n < 2 {
        return None;
    }
    let dim = pairs[0].0.len();
    let mut mu = vec![0.0f64; dim];
    let mut mu_h = vec![0.0f64; dim];
    for (g, h) in pairs {
        for i in 0..dim {
            mu[i] += g[i] as f64;
            mu_h[i] += h[i] as f64;
        }
    }
    for i in 0..dim {
        mu[i] /= n as f64;
        mu_h[i] /= n as f64;
    }
    let (mut sg2, mut sh2, mut tau) = (0.0f64, 0.0f64, 0.0f64);
    for (g, h) in pairs {
        for i in 0..dim {
            let u = g[i] as f64 - mu[i];
            let v = h[i] as f64 - mu_h[i];
            sg2 += u * u;
            sh2 += v * v;
            tau += u * v;
        }
    }
    sg2 /= n as f64;
    sh2 /= n as f64;
    tau /= n as f64;
    if sg2 < 1e-24 || sh2 < 1e-24 {
        return None;
    }
    Some(Alignment {
        rho: tau / (sg2.sqrt() * sh2.sqrt()),
        kappa: (sh2 / sg2).sqrt(),
        sigma_g: sg2.sqrt(),
        sigma_h: sh2.sqrt(),
        n,
    })
}

/// Cached alignment holder: updated once per predictor refit, queried
/// every logging step for free.
#[derive(Default)]
pub struct AlignmentMeter {
    last: Option<Alignment>,
}

impl AlignmentMeter {
    pub fn update(&mut self, a: Option<Alignment>) {
        if a.is_some() {
            self.last = a;
        }
    }

    pub fn snapshot(&self) -> Option<Alignment> {
        self.last
    }
}

impl Alignment {
    /// Variance inflation φ(f, ρ̂, κ̂) implied by the current estimate.
    pub fn phi(&self, f: f64) -> f64 {
        theory::phi(f, self.rho, self.kappa)
    }

    /// Break-even margin 1 − φγ (positive ⇒ beating vanilla under equal
    /// compute, Theorem 3).
    pub fn break_even_margin(&self, f: f64, cost: &CostModel) -> f64 {
        1.0 - theory::q_objective(f, self.rho, self.kappa, cost)
    }

    /// Paper-optimal control fraction f*(ρ̂, κ̂) (Theorem 4) — what an
    /// adaptive-f controller would pick right now.
    pub fn f_star(&self, cost: &CostModel) -> f64 {
        theory::f_star(self.rho, self.kappa, cost)
    }
}

/// Classification accuracy from probabilities (row-major m x C).
pub fn accuracy(probs: &[f32], labels: &[i32], classes: usize) -> f64 {
    let m = labels.len();
    if m == 0 {
        return 0.0;
    }
    let mut correct = 0usize;
    for (i, &y) in labels.iter().enumerate() {
        let row = &probs[i * classes..(i + 1) * classes];
        let mut best = 0usize;
        for (j, &p) in row.iter().enumerate() {
            if p > row[best] {
                best = j;
            }
        }
        if best == y as usize {
            correct += 1;
        }
    }
    correct as f64 / m as f64
}

/// Exponential moving average meter for smoothed loss curves.
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    pub value: f64,
    alpha: f64,
    initialized: bool,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        Ema { value: 0.0, alpha, initialized: false }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        if self.initialized {
            self.value = self.alpha * x + (1.0 - self.alpha) * self.value;
        } else {
            self.value = x;
            self.initialized = true;
        }
        self.value
    }

    /// `(value, alpha, initialized)` for checkpointing (ADR-008).
    pub fn parts(&self) -> (f64, f64, bool) {
        (self.value, self.alpha, self.initialized)
    }

    /// Rebuild a meter from [`parts`](Self::parts) output.
    pub fn from_parts(value: f64, alpha: f64, initialized: bool) -> Ema {
        Ema { value, alpha, initialized }
    }
}

/// One row of the training log (shared by both algorithms so curves are
/// directly comparable — the Figure 1 data schema).
#[derive(Clone, Debug)]
pub struct LogRow {
    pub step: usize,
    pub wall_secs: f64,
    pub loss: f64,
    pub train_acc: f64,
    pub val_acc: f64,
    pub rho: f64,
    pub kappa: f64,
    pub phi: f64,
    pub examples_seen: usize,
}

impl LogRow {
    pub const HEADER: [&'static str; 9] = [
        "step", "wall_secs", "loss", "train_acc", "val_acc", "rho", "kappa", "phi",
        "examples_seen",
    ];

    pub fn values(&self) -> [f64; 9] {
        [
            self.step as f64,
            self.wall_secs,
            self.loss,
            self.train_acc,
            self.val_acc,
            self.rho,
            self.kappa,
            self.phi,
            self.examples_seen as f64,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn tracker_detects_perfect_alignment() {
        let mut t = AlignmentTracker::new(64);
        let mut rng = Pcg64::seeded(1);
        for _ in 0..32 {
            let mut g = vec![0.0f32; 50];
            rng.fill_normal(&mut g, 1.0);
            t.push(g.clone(), g);
        }
        let a = t.snapshot().unwrap();
        assert!((a.rho - 1.0).abs() < 1e-6, "rho={}", a.rho);
        assert!((a.kappa - 1.0).abs() < 1e-6);
        assert!((a.phi(0.25) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tracker_detects_known_correlation() {
        let mut t = AlignmentTracker::new(600);
        let mut rng = Pcg64::seeded(2);
        let rho = 0.8f32;
        for _ in 0..500 {
            let mut u = vec![0.0f32; 30];
            let mut w = vec![0.0f32; 30];
            rng.fill_normal(&mut u, 1.0);
            rng.fill_normal(&mut w, 1.0);
            let h: Vec<f32> = u
                .iter()
                .zip(&w)
                .map(|(ui, wi)| 2.0 * (rho * ui + (1.0 - rho * rho).sqrt() * wi))
                .collect();
            t.push(u, h);
        }
        let a = t.snapshot().unwrap();
        assert!((a.rho - 0.8).abs() < 0.05, "rho={}", a.rho);
        assert!((a.kappa - 2.0).abs() < 0.1, "kappa={}", a.kappa);
    }

    #[test]
    fn tracker_orthogonal_gradients() {
        let mut t = AlignmentTracker::new(300);
        let mut rng = Pcg64::seeded(3);
        for _ in 0..200 {
            let mut g = vec![0.0f32; 40];
            let mut h = vec![0.0f32; 40];
            rng.fill_normal(&mut g, 1.0);
            rng.fill_normal(&mut h, 1.0);
            t.push(g, h);
        }
        let a = t.snapshot().unwrap();
        assert!(a.rho.abs() < 0.1, "rho={}", a.rho);
    }

    #[test]
    fn tracker_window_caps_memory() {
        let mut t = AlignmentTracker::new(8);
        for i in 0..100 {
            t.push(vec![i as f32; 4], vec![1.0; 4]);
        }
        assert_eq!(t.len(), 8);
    }

    #[test]
    fn accuracy_counts_argmax() {
        let probs = vec![
            0.1, 0.9, // -> 1
            0.7, 0.3, // -> 0
            0.5, 0.5, // tie -> 0 (first argmax)
        ];
        assert!((accuracy(&probs, &[1, 0, 1], 2) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(accuracy(&[], &[], 2), 0.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        e.push(10.0);
        assert_eq!(e.value, 10.0);
        for _ in 0..30 {
            e.push(2.0);
        }
        assert!((e.value - 2.0).abs() < 1e-6);
    }

    #[test]
    fn break_even_margin_sign() {
        let good = Alignment { rho: 0.95, kappa: 1.0, sigma_g: 1.0, sigma_h: 1.0, n: 10 };
        let bad = Alignment { rho: 0.3, kappa: 1.0, sigma_g: 1.0, sigma_h: 1.0, n: 10 };
        let cost = CostModel::default();
        assert!(good.break_even_margin(0.25, &cost) > 0.0);
        assert!(bad.break_even_margin(0.25, &cost) < 0.0);
        assert!(good.f_star(&cost) < 1.0);
        assert_eq!(bad.f_star(&cost), 1.0);
    }
}
