//! manifest.json loader — the typed contract between `python/compile/aot.py`
//! and the Rust runtime. Everything shape-related is validated here once so
//! the hot path can index blindly.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One trunk parameter's slot in the flat vector.
#[derive(Clone, Debug)]
pub struct TrunkParam {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
    /// Eligible for Muon's matrix update (2-D hidden-layer weights).
    pub muon: bool,
}

/// Metadata for one AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    /// (name, shape, dtype) per positional argument.
    pub args: Vec<(String, Vec<usize>, String)>,
    pub outs: Vec<(String, Vec<usize>, String)>,
}

/// Parsed + validated manifest for one preset's artifact directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub preset: String,
    // model dims
    pub image: usize,
    pub classes: usize,
    pub width: usize,
    pub label_smoothing: f64,
    // predictor dims
    pub rank: usize,
    pub n_chunk: usize,
    pub n_fit: usize,
    pub feat_dim: usize,
    // parameter dims
    pub trunk_params: usize,
    pub total_params: usize,
    // batching
    pub micro_batch: usize,
    pub fs: Vec<f64>,
    pub val_batch: usize,
    pub trunk_layout: Vec<TrunkParam>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub init_trunk: PathBuf,
    pub init_head_w: PathBuf,
    pub init_head_b: PathBuf,
}

fn req_usize(j: &Json, path: &[&str]) -> anyhow::Result<usize> {
    j.at(path)
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("manifest missing numeric field {path:?}"))
}

fn args_list(j: &Json) -> anyhow::Result<Vec<(String, Vec<usize>, String)>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected array of arg metadata"))?
        .iter()
        .map(|a| {
            Ok((
                a.at(&["name"]).as_str().unwrap_or("?").to_string(),
                a.at(&["shape"])
                    .as_usize_vec()
                    .ok_or_else(|| anyhow::anyhow!("bad shape in arg metadata"))?,
                a.at(&["dtype"]).as_str().unwrap_or("f32").to_string(),
            ))
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} — did you run `make artifacts`? ({e})",
                path.display()
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing manifest: {e}"))?;

        let mut layout = Vec::new();
        for item in j
            .at(&["trunk_layout"])
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest missing trunk_layout"))?
        {
            layout.push(TrunkParam {
                name: item.at(&["name"]).as_str().unwrap_or("?").to_string(),
                shape: item
                    .at(&["shape"])
                    .as_usize_vec()
                    .ok_or_else(|| anyhow::anyhow!("bad trunk_layout shape"))?,
                offset: req_usize(item, &["offset"])?,
                len: req_usize(item, &["len"])?,
                muon: item.at(&["muon"]).as_bool().unwrap_or(false),
            });
        }

        let mut artifacts = BTreeMap::new();
        for (name, meta) in j
            .at(&["artifacts"])
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?
        {
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: dir.join(
                        meta.at(&["file"])
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("artifact {name} missing file"))?,
                    ),
                    args: args_list(meta.at(&["args"]))?,
                    outs: args_list(meta.at(&["outs"]))?,
                },
            );
        }

        let m = Manifest {
            dir: dir.to_path_buf(),
            preset: j.at(&["preset"]).as_str().unwrap_or("?").to_string(),
            image: req_usize(&j, &["model", "image"])?,
            classes: req_usize(&j, &["model", "classes"])?,
            width: req_usize(&j, &["model", "width"])?,
            label_smoothing: j
                .at(&["model", "label_smoothing"])
                .as_f64()
                .unwrap_or(0.05),
            rank: req_usize(&j, &["predictor", "rank"])?,
            n_chunk: req_usize(&j, &["predictor", "n_chunk"])?,
            n_fit: req_usize(&j, &["predictor", "n_fit"])?,
            feat_dim: req_usize(&j, &["predictor", "feat_dim"])?,
            trunk_params: req_usize(&j, &["dims", "trunk_params"])?,
            total_params: req_usize(&j, &["dims", "total_params"])?,
            micro_batch: req_usize(&j, &["batch", "micro"])?,
            fs: j
                .at(&["batch", "fs"])
                .as_arr()
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default(),
            val_batch: req_usize(&j, &["batch", "val"])?,
            trunk_layout: layout,
            artifacts,
            init_trunk: dir.join(j.at(&["init", "trunk"]).as_str().unwrap_or("init_trunk.bin")),
            init_head_w: dir.join(j.at(&["init", "head_w"]).as_str().unwrap_or("init_head_w.bin")),
            init_head_b: dir.join(j.at(&["init", "head_b"]).as_str().unwrap_or("init_head_b.bin")),
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> anyhow::Result<()> {
        // Layout must tile the trunk vector exactly.
        let mut off = 0;
        for p in &self.trunk_layout {
            anyhow::ensure!(
                p.offset == off,
                "trunk_layout gap at {} (offset {} != {})",
                p.name,
                p.offset,
                off
            );
            anyhow::ensure!(
                p.len == p.shape.iter().product::<usize>(),
                "trunk_layout len mismatch at {}",
                p.name
            );
            off += p.len;
        }
        anyhow::ensure!(
            off == self.trunk_params,
            "trunk_layout covers {off} of {} params",
            self.trunk_params
        );
        anyhow::ensure!(
            self.total_params == self.trunk_params + self.width * self.classes + self.classes,
            "total_params inconsistent"
        );
        anyhow::ensure!(!self.artifacts.is_empty(), "no artifacts in manifest");
        Ok(())
    }

    /// Micro-batch split sizes for control fraction f: (m_c, m_p).
    pub fn split_sizes(&self, f: f64) -> (usize, usize) {
        let mc = ((f * self.micro_batch as f64).round() as usize)
            .clamp(1, self.micro_batch);
        (mc, self.micro_batch - mc)
    }

    /// Find an artifact by logical name, with a helpful error.
    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact '{name}' not in manifest (have: {:?}) — \
                 re-run `make artifacts` with the right --fs",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn train_grads_name(&self, batch: usize) -> String {
        format!("train_grads_b{batch}")
    }

    pub fn cheap_fwd_name(&self, batch: usize) -> String {
        format!("cheap_fwd_b{batch}")
    }

    pub fn predict_grad_name(&self, batch: usize) -> String {
        format!("predict_grad_b{batch}")
    }

    pub fn per_example_grads_name(&self) -> String {
        format!("per_example_grads_b{}", self.n_chunk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir(preset: &str) -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(preset);
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_tiny_manifest() {
        let Some(dir) = artifacts_dir("tiny") else {
            eprintln!("skipping: tiny artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.preset, "tiny");
        assert!(m.trunk_params > 1000);
        assert_eq!(m.classes, 10);
        assert!(m.artifacts.contains_key("cv_combine"));
        assert!(m.artifact("nonexistent").is_err());
        // Every referenced file exists.
        for a in m.artifacts.values() {
            assert!(a.file.exists(), "{:?}", a.file);
        }
        assert!(m.init_trunk.exists());
    }

    #[test]
    fn split_sizes_partition_the_micro_batch() {
        let Some(dir) = artifacts_dir("tiny") else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        for &f in &[0.1, 0.25, 0.5, 0.99] {
            let (mc, mp) = m.split_sizes(f);
            assert_eq!(mc + mp, m.micro_batch);
            assert!(mc >= 1);
        }
    }
}
