//! Host-side parameter store: the flat trunk vector plus the head, loaded
//! from the AOT init bins and updated in place by the optimizer.

use super::manifest::Manifest;
use crate::checkpoint;
use crate::tensor::Tensor;
use crate::util;
use anyhow::Context as _;

/// The three parameter tensors the whole system revolves around.
/// Trunk layout is defined by the manifest; `head_w` is (D, C) row-major.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub trunk: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
    pub width: usize,
    pub classes: usize,
}

impl ParamStore {
    /// Load initial parameters written by aot.py (matches the jax init
    /// exactly, so Rust and python tests see the same model).
    pub fn load_init(m: &Manifest) -> anyhow::Result<ParamStore> {
        let trunk = util::read_f32_file(&m.init_trunk)?;
        anyhow::ensure!(
            trunk.len() == m.trunk_params,
            "init_trunk has {} values, manifest says {}",
            trunk.len(),
            m.trunk_params
        );
        let head_w = util::read_f32_file(&m.init_head_w)?;
        anyhow::ensure!(head_w.len() == m.width * m.classes, "init_head_w size mismatch");
        let head_b = util::read_f32_file(&m.init_head_b)?;
        anyhow::ensure!(head_b.len() == m.classes, "init_head_b size mismatch");
        Ok(ParamStore { trunk, head_w, head_b, width: m.width, classes: m.classes })
    }

    /// Total parameter count (trunk + head).
    pub fn total_len(&self) -> usize {
        self.trunk.len() + self.head_w.len() + self.head_b.len()
    }

    /// View one trunk parameter as a Tensor copy (for Muon's per-matrix
    /// math). Hot loops use `slice` instead to avoid the copy.
    pub fn trunk_tensor(&self, p: &super::TrunkParam) -> Tensor {
        Tensor::from_vec(self.trunk[p.offset..p.offset + p.len].to_vec(), &p.shape)
    }

    pub fn trunk_slice(&self, p: &super::TrunkParam) -> &[f32] {
        &self.trunk[p.offset..p.offset + p.len]
    }

    pub fn trunk_slice_mut(&mut self, p: &super::TrunkParam) -> &mut [f32] {
        &mut self.trunk[p.offset..p.offset + p.len]
    }

    /// Concatenate all parameters into one flat vector
    /// [trunk | head_w | head_b] — the cv_combine artifact layout.
    pub fn flatten_all(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total_len());
        out.extend_from_slice(&self.trunk);
        out.extend_from_slice(&self.head_w);
        out.extend_from_slice(&self.head_b);
        out
    }

    /// File name of the parameter checkpoint artifact under the target
    /// directory (one versioned, CRC-guarded container — ADR-008).
    pub const CKPT_FILE: &str = "params.lgpckpt";

    /// Fingerprint over the store's shape: restoring into a differently
    /// shaped model is an incompatibility (hard error), not corruption.
    fn shape_fingerprint(&self) -> u64 {
        checkpoint::fingerprint_of(&[
            ("trunk", self.trunk.len().to_string()),
            ("head_w", self.head_w.len().to_string()),
            ("head_b", self.head_b.len().to_string()),
            ("width", self.width.to_string()),
            ("classes", self.classes.to_string()),
        ])
    }

    /// Save a parameter checkpoint: a single `params.lgpckpt` artifact
    /// written through the atomic tmp+fsync+rename protocol (ADR-008).
    /// Replaces the pre-ADR-008 layout of three raw `.bin` files.
    pub fn save(&self, dir: &std::path::Path) -> anyhow::Result<()> {
        let mut ck = checkpoint::Checkpoint::new(self.shape_fingerprint());
        ck.add(checkpoint::state::PARAMS, checkpoint::state::encode_params(self));
        checkpoint::write_atomic(dir, Self::CKPT_FILE, &ck.encode())?;
        Ok(())
    }

    /// Restore a checkpoint saved by [`save`](Self::save). Prefers the
    /// versioned artifact; falls back — with a deprecation warning — to
    /// the legacy three-`.bin` layout for one release of read-compat.
    pub fn restore(&mut self, dir: &std::path::Path) -> anyhow::Result<()> {
        let path = dir.join(Self::CKPT_FILE);
        if path.exists() {
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading parameter checkpoint {}", path.display()))?;
            let ck = checkpoint::Checkpoint::decode(&bytes)
                .with_context(|| format!("decoding parameter checkpoint {}", path.display()))?;
            anyhow::ensure!(
                ck.fingerprint == self.shape_fingerprint(),
                "{} was written for a differently shaped model \
                 (fingerprint {:016x}, expected {:016x})",
                path.display(),
                ck.fingerprint,
                self.shape_fingerprint()
            );
            return checkpoint::state::decode_params(
                self,
                ck.section(checkpoint::state::PARAMS)?,
            );
        }
        crate::log_warn!(
            "restoring legacy three-.bin parameter checkpoint from {} — deprecated; \
             re-save to produce a single {} artifact",
            dir.display(),
            Self::CKPT_FILE
        );
        let trunk = util::read_f32_file(&dir.join("trunk.bin"))?;
        anyhow::ensure!(trunk.len() == self.trunk.len(), "checkpoint trunk size mismatch");
        let head_w = util::read_f32_file(&dir.join("head_w.bin"))?;
        anyhow::ensure!(head_w.len() == self.head_w.len(), "checkpoint head_w size mismatch");
        let head_b = util::read_f32_file(&dir.join("head_b.bin"))?;
        anyhow::ensure!(head_b.len() == self.head_b.len(), "checkpoint head_b size mismatch");
        self.trunk = trunk;
        self.head_w = head_w;
        self.head_b = head_b;
        Ok(())
    }
}

/// A flat gradient in the same [trunk | head_w | head_b] layout.
#[derive(Clone, Debug)]
pub struct FlatGrad {
    pub trunk: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

impl FlatGrad {
    pub fn zeros_like(p: &ParamStore) -> FlatGrad {
        FlatGrad {
            trunk: vec![0.0; p.trunk.len()],
            head_w: vec![0.0; p.head_w.len()],
            head_b: vec![0.0; p.head_b.len()],
        }
    }

    pub fn axpy(&mut self, s: f32, other: &FlatGrad) {
        for (x, y) in self.trunk.iter_mut().zip(&other.trunk) {
            *x += s * y;
        }
        for (x, y) in self.head_w.iter_mut().zip(&other.head_w) {
            *x += s * y;
        }
        for (x, y) in self.head_b.iter_mut().zip(&other.head_b) {
            *x += s * y;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in self.trunk.iter_mut().chain(&mut self.head_w).chain(&mut self.head_b) {
            *x *= s;
        }
    }

    pub fn norm(&self) -> f32 {
        let t = crate::tensor::stats::dot_f64(&self.trunk, &self.trunk)
            + crate::tensor::stats::dot_f64(&self.head_w, &self.head_w)
            + crate::tensor::stats::dot_f64(&self.head_b, &self.head_b);
        t.sqrt() as f32
    }

    /// Split a single concatenated vector back into a FlatGrad.
    pub fn from_concat(v: &[f32], trunk_len: usize, head_w_len: usize) -> FlatGrad {
        FlatGrad {
            trunk: v[..trunk_len].to_vec(),
            head_w: v[trunk_len..trunk_len + head_w_len].to_vec(),
            head_b: v[trunk_len + head_w_len..].to_vec(),
        }
    }

    pub fn concat(&self) -> Vec<f32> {
        let mut out =
            Vec::with_capacity(self.trunk.len() + self.head_w.len() + self.head_b.len());
        out.extend_from_slice(&self.trunk);
        out.extend_from_slice(&self.head_w);
        out.extend_from_slice(&self.head_b);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy() -> ParamStore {
        ParamStore {
            trunk: (0..20).map(|i| i as f32).collect(),
            head_w: vec![1.0; 6],
            head_b: vec![0.5; 3],
            width: 2,
            classes: 3,
        }
    }

    #[test]
    fn flatten_layout() {
        let p = dummy();
        let flat = p.flatten_all();
        assert_eq!(flat.len(), p.total_len());
        assert_eq!(flat[0], 0.0);
        assert_eq!(flat[20], 1.0);
        assert_eq!(flat[26], 0.5);
    }

    #[test]
    fn flat_grad_round_trip() {
        let p = dummy();
        let mut g = FlatGrad::zeros_like(&p);
        g.trunk[3] = 2.0;
        g.head_w[1] = -1.0;
        g.head_b[2] = 0.25;
        let cat = g.concat();
        let g2 = FlatGrad::from_concat(&cat, 20, 6);
        assert_eq!(g2.trunk, g.trunk);
        assert_eq!(g2.head_w, g.head_w);
        assert_eq!(g2.head_b, g.head_b);
    }

    #[test]
    fn axpy_and_scale() {
        let p = dummy();
        let mut a = FlatGrad::zeros_like(&p);
        let mut b = FlatGrad::zeros_like(&p);
        b.trunk[0] = 4.0;
        b.head_b[0] = 2.0;
        a.axpy(0.5, &b);
        assert_eq!(a.trunk[0], 2.0);
        assert_eq!(a.head_b[0], 1.0);
        a.scale(2.0);
        assert_eq!(a.trunk[0], 4.0);
        assert!((a.norm() - (16.0f32 + 4.0).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn checkpoint_round_trip() {
        let dir = std::env::temp_dir().join("lgp_params_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut p = dummy();
        p.save(&dir).unwrap();
        assert!(dir.join(ParamStore::CKPT_FILE).exists(), "single-artifact layout");
        assert!(!dir.join("trunk.bin").exists(), "legacy .bin layout is gone");
        let orig = p.clone();
        p.trunk[0] = 99.0;
        p.restore(&dir).unwrap();
        assert_eq!(p.trunk, orig.trunk);
    }

    #[test]
    fn new_format_takes_precedence_over_stale_legacy_bins() {
        let dir = std::env::temp_dir().join("lgp_params_test_precedence");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Stale legacy checkpoint with different values.
        let mut stale = dummy();
        stale.trunk.iter_mut().for_each(|v| *v = -7.0);
        crate::util::write_f32_file(&dir.join("trunk.bin"), &stale.trunk).unwrap();
        crate::util::write_f32_file(&dir.join("head_w.bin"), &stale.head_w).unwrap();
        crate::util::write_f32_file(&dir.join("head_b.bin"), &stale.head_b).unwrap();
        let p = dummy();
        p.save(&dir).unwrap();
        let mut q = dummy();
        q.trunk.iter_mut().for_each(|v| *v = 0.0);
        q.restore(&dir).unwrap();
        assert_eq!(q.trunk, p.trunk, "versioned artifact must win over stale .bin files");
    }

    #[test]
    fn legacy_three_bin_layout_still_restores() {
        let dir = std::env::temp_dir().join("lgp_params_test_legacy");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dummy();
        crate::util::write_f32_file(&dir.join("trunk.bin"), &p.trunk).unwrap();
        crate::util::write_f32_file(&dir.join("head_w.bin"), &p.head_w).unwrap();
        crate::util::write_f32_file(&dir.join("head_b.bin"), &p.head_b).unwrap();
        let mut q = dummy();
        q.trunk.iter_mut().for_each(|v| *v = 0.0);
        q.restore(&dir).unwrap();
        assert_eq!(q.trunk, p.trunk);
    }

    #[test]
    fn restore_rejects_differently_shaped_store() {
        let dir = std::env::temp_dir().join("lgp_params_test_shape");
        let _ = std::fs::remove_dir_all(&dir);
        dummy().save(&dir).unwrap();
        let mut wrong = ParamStore {
            trunk: vec![0.0; 8],
            head_w: vec![0.0; 6],
            head_b: vec![0.0; 3],
            width: 2,
            classes: 3,
        };
        let err = wrong.restore(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("differently shaped"), "{err:#}");
    }
}
