//! Host-side parameter store: the flat trunk vector plus the head, loaded
//! from the AOT init bins and updated in place by the optimizer.

use super::manifest::Manifest;
use crate::tensor::Tensor;
use crate::util;

/// The three parameter tensors the whole system revolves around.
/// Trunk layout is defined by the manifest; `head_w` is (D, C) row-major.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub trunk: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
    pub width: usize,
    pub classes: usize,
}

impl ParamStore {
    /// Load initial parameters written by aot.py (matches the jax init
    /// exactly, so Rust and python tests see the same model).
    pub fn load_init(m: &Manifest) -> anyhow::Result<ParamStore> {
        let trunk = util::read_f32_file(&m.init_trunk)?;
        anyhow::ensure!(
            trunk.len() == m.trunk_params,
            "init_trunk has {} values, manifest says {}",
            trunk.len(),
            m.trunk_params
        );
        let head_w = util::read_f32_file(&m.init_head_w)?;
        anyhow::ensure!(head_w.len() == m.width * m.classes, "init_head_w size mismatch");
        let head_b = util::read_f32_file(&m.init_head_b)?;
        anyhow::ensure!(head_b.len() == m.classes, "init_head_b size mismatch");
        Ok(ParamStore { trunk, head_w, head_b, width: m.width, classes: m.classes })
    }

    /// Total parameter count (trunk + head).
    pub fn total_len(&self) -> usize {
        self.trunk.len() + self.head_w.len() + self.head_b.len()
    }

    /// View one trunk parameter as a Tensor copy (for Muon's per-matrix
    /// math). Hot loops use `slice` instead to avoid the copy.
    pub fn trunk_tensor(&self, p: &super::TrunkParam) -> Tensor {
        Tensor::from_vec(self.trunk[p.offset..p.offset + p.len].to_vec(), &p.shape)
    }

    pub fn trunk_slice(&self, p: &super::TrunkParam) -> &[f32] {
        &self.trunk[p.offset..p.offset + p.len]
    }

    pub fn trunk_slice_mut(&mut self, p: &super::TrunkParam) -> &mut [f32] {
        &mut self.trunk[p.offset..p.offset + p.len]
    }

    /// Concatenate all parameters into one flat vector
    /// [trunk | head_w | head_b] — the cv_combine artifact layout.
    pub fn flatten_all(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total_len());
        out.extend_from_slice(&self.trunk);
        out.extend_from_slice(&self.head_w);
        out.extend_from_slice(&self.head_b);
        out
    }

    /// Save a checkpoint (three .bin files under `dir`).
    pub fn save(&self, dir: &std::path::Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        util::write_f32_file(&dir.join("trunk.bin"), &self.trunk)?;
        util::write_f32_file(&dir.join("head_w.bin"), &self.head_w)?;
        util::write_f32_file(&dir.join("head_b.bin"), &self.head_b)?;
        Ok(())
    }

    /// Restore a checkpoint saved by `save`.
    pub fn restore(&mut self, dir: &std::path::Path) -> anyhow::Result<()> {
        let trunk = util::read_f32_file(&dir.join("trunk.bin"))?;
        anyhow::ensure!(trunk.len() == self.trunk.len(), "checkpoint trunk size mismatch");
        let head_w = util::read_f32_file(&dir.join("head_w.bin"))?;
        anyhow::ensure!(head_w.len() == self.head_w.len(), "checkpoint head_w size mismatch");
        let head_b = util::read_f32_file(&dir.join("head_b.bin"))?;
        anyhow::ensure!(head_b.len() == self.head_b.len(), "checkpoint head_b size mismatch");
        self.trunk = trunk;
        self.head_w = head_w;
        self.head_b = head_b;
        Ok(())
    }
}

/// A flat gradient in the same [trunk | head_w | head_b] layout.
#[derive(Clone, Debug)]
pub struct FlatGrad {
    pub trunk: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

impl FlatGrad {
    pub fn zeros_like(p: &ParamStore) -> FlatGrad {
        FlatGrad {
            trunk: vec![0.0; p.trunk.len()],
            head_w: vec![0.0; p.head_w.len()],
            head_b: vec![0.0; p.head_b.len()],
        }
    }

    pub fn axpy(&mut self, s: f32, other: &FlatGrad) {
        for (x, y) in self.trunk.iter_mut().zip(&other.trunk) {
            *x += s * y;
        }
        for (x, y) in self.head_w.iter_mut().zip(&other.head_w) {
            *x += s * y;
        }
        for (x, y) in self.head_b.iter_mut().zip(&other.head_b) {
            *x += s * y;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in self.trunk.iter_mut().chain(&mut self.head_w).chain(&mut self.head_b) {
            *x *= s;
        }
    }

    pub fn norm(&self) -> f32 {
        let t = crate::tensor::stats::dot_f64(&self.trunk, &self.trunk)
            + crate::tensor::stats::dot_f64(&self.head_w, &self.head_w)
            + crate::tensor::stats::dot_f64(&self.head_b, &self.head_b);
        t.sqrt() as f32
    }

    /// Split a single concatenated vector back into a FlatGrad.
    pub fn from_concat(v: &[f32], trunk_len: usize, head_w_len: usize) -> FlatGrad {
        FlatGrad {
            trunk: v[..trunk_len].to_vec(),
            head_w: v[trunk_len..trunk_len + head_w_len].to_vec(),
            head_b: v[trunk_len + head_w_len..].to_vec(),
        }
    }

    pub fn concat(&self) -> Vec<f32> {
        let mut out =
            Vec::with_capacity(self.trunk.len() + self.head_w.len() + self.head_b.len());
        out.extend_from_slice(&self.trunk);
        out.extend_from_slice(&self.head_w);
        out.extend_from_slice(&self.head_b);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy() -> ParamStore {
        ParamStore {
            trunk: (0..20).map(|i| i as f32).collect(),
            head_w: vec![1.0; 6],
            head_b: vec![0.5; 3],
            width: 2,
            classes: 3,
        }
    }

    #[test]
    fn flatten_layout() {
        let p = dummy();
        let flat = p.flatten_all();
        assert_eq!(flat.len(), p.total_len());
        assert_eq!(flat[0], 0.0);
        assert_eq!(flat[20], 1.0);
        assert_eq!(flat[26], 0.5);
    }

    #[test]
    fn flat_grad_round_trip() {
        let p = dummy();
        let mut g = FlatGrad::zeros_like(&p);
        g.trunk[3] = 2.0;
        g.head_w[1] = -1.0;
        g.head_b[2] = 0.25;
        let cat = g.concat();
        let g2 = FlatGrad::from_concat(&cat, 20, 6);
        assert_eq!(g2.trunk, g.trunk);
        assert_eq!(g2.head_w, g.head_w);
        assert_eq!(g2.head_b, g.head_b);
    }

    #[test]
    fn axpy_and_scale() {
        let p = dummy();
        let mut a = FlatGrad::zeros_like(&p);
        let mut b = FlatGrad::zeros_like(&p);
        b.trunk[0] = 4.0;
        b.head_b[0] = 2.0;
        a.axpy(0.5, &b);
        assert_eq!(a.trunk[0], 2.0);
        assert_eq!(a.head_b[0], 1.0);
        a.scale(2.0);
        assert_eq!(a.trunk[0], 4.0);
        assert!((a.norm() - (16.0f32 + 4.0).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn checkpoint_round_trip() {
        let dir = std::env::temp_dir().join("lgp_params_test");
        let mut p = dummy();
        p.save(&dir).unwrap();
        let orig = p.clone();
        p.trunk[0] = 99.0;
        p.restore(&dir).unwrap();
        assert_eq!(p.trunk, orig.trunk);
    }
}
