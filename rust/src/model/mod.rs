//! Model-side host state: the AOT manifest (the contract with the python
//! compile path) and the parameter store the optimizer updates.

pub mod manifest;
pub mod params;

pub use manifest::{ArtifactMeta, Manifest, TrunkParam};
pub use params::ParamStore;
