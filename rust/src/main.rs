//! lgp — leader entrypoint: a thin adapter from CLI flags to the
//! library-first session API (`lgp::session`, DESIGN.md ADR-005). All
//! configuration goes through `session::cli::builder_from_args`; this
//! file only wires observers, prints summaries, and formats tables.
//!
//! Subcommands:
//!   train      run Algorithm 1 (GPR) or Algorithm 2 (baseline)
//!   serve      host training sessions over HTTP/JSONL (DESIGN.md ADR-009)
//!   theory     print the Section 5 closed-form tables (Thm 3/4, cost model)
//!   sweep-f    train short runs across control fractions f
//!   data       generate + describe the synthetic dataset
//!   info       show manifest / artifact inventory
//!
//! Examples:
//!   lgp train --preset tiny --algo gpr --f 0.25 --steps 30
//!   lgp train --preset small --algo baseline --budget 60
//!   lgp theory
//!   lgp sweep-f --preset small --fs 0.125,0.25,0.5 --steps 20

use lgp::bench_support::Table;
use lgp::config::{Algo, OptimKind};
use lgp::observer::{CsvObserver, JsonlObserver};
use lgp::session::cli::builder_from_args;
use lgp::session::SessionBuilder;
use lgp::tensor::BackendKind;
use lgp::theory::{self, CostModel};
use lgp::util::cli::{options, Args};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("train") => run(cmd_train(&args)),
        Some("serve") => run(cmd_serve(&args)),
        Some("theory") => run(cmd_theory(&args)),
        Some("sweep-f") => run(cmd_sweep_f(&args)),
        Some("data") => run(cmd_data(&args)),
        Some("info") => run(cmd_info(&args)),
        _ => {
            eprint!("{}", help());
            2
        }
    };
    std::process::exit(code);
}

/// Help text with the enum option lists generated from the same
/// `EnumSpec` tables the parsers use — the lists cannot drift.
fn help() -> String {
    format!(
        "\
lgp — Linear Gradient Prediction with Control Variates (paper reproduction)

USAGE: lgp <subcommand> [--key value]...

SUBCOMMANDS
  train    --preset tiny|small|paper --algo {algo} [--f 0.25]
           [--steps N] [--budget SECS] [--accum K] [--optimizer {optim}]
           [--lr 0.02] [--refit-every N] [--seed S] [--csv out.csv] [--jsonl out.jsonl]
           [--backend {backend}]   (host tensor kernels; auto = probe)
           [--shards N]   (data-parallel worker threads per update;
                           bit-identical to --shards 1, DESIGN.md ADR-004)
           [--checkpoint-dir DIR] [--checkpoint-every N] [--resume]
           [--checkpoint-keep K]   (prune to the newest K valid artifacts;
                           crash-safe checkpoints + bit-identical resume;
                           SIGINT checkpoints then exits, DESIGN.md ADR-008)
  serve    --addr 127.0.0.1:7878   (0 = ephemeral port, printed on stdout)
           training-as-a-service control plane (DESIGN.md ADR-009):
           POST /sessions (JSON config), GET /sessions/:id,
           GET /sessions/:id/events (JSONL stream), POST /sessions/:id/cancel
  theory   print Theorem 3/4 tables and the cost model
  sweep-f  --fs 0.125,0.25,0.5 plus the train flags
  data     --n 100 --side 32 [--seed S]  describe synthetic data
  info     --preset tiny  show the artifact manifest

See also: `bench_report` (validates the BENCH_*.json bench trajectory,
EXPERIMENTS.md) and DESIGN.md for the architecture.
",
        algo = options(Algo::SPECS),
        optim = options(OptimKind::SPECS),
        backend = options(BackendKind::SPECS),
    )
}

fn run(r: anyhow::Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Builder from flags, with the typo guard applied after every train
/// flag has been consumed.
fn checked_builder(args: &Args) -> anyhow::Result<SessionBuilder> {
    let b = builder_from_args(args)?;
    let unknown = args.unknown_keys();
    anyhow::ensure!(unknown.is_empty(), "unknown flags: {unknown:?}");
    Ok(b)
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let csv_path = args.str_opt("csv");
    let jsonl_path = args.str_opt("jsonl");
    let show_artifact_times = args.flag("artifact-times");
    let mut b = checked_builder(args)?;
    if let Some(p) = &csv_path {
        b = b.observer(Box::new(CsvObserver::create(std::path::Path::new(p))?));
    }
    if let Some(p) = &jsonl_path {
        b = b.observer(Box::new(JsonlObserver::create(std::path::Path::new(p))?));
    }
    let algo = b.config().algo;
    let mut session = b.build()?;
    let t0 = std::time::Instant::now();
    session.run()?;
    let dt = t0.elapsed().as_secs_f64();
    let st = session.rt.stats_snapshot();
    println!(
        "algo={algo:?} backend={} shards={} steps={} wall={dt:.1}s final_val_acc={:.4} examples={} cost_units={:.0}",
        session.backend.name(),
        session.shards(),
        session.step_count(),
        session.final_val_acc(),
        session.examples_seen,
        session.cost_units,
    );
    println!(
        "runtime: calls={} exec={:.2}s upload={:.2}s download={:.2}s compile={:.2}s",
        st.calls, st.exec_secs, st.upload_secs, st.download_secs, st.compile_secs
    );
    if show_artifact_times {
        for (name, (n, secs)) in &st.per_artifact {
            println!("  {name:<28} calls={n:<4} total={secs:.2}s avg={:.1}ms", secs / *n as f64 * 1e3);
        }
    }
    if let Some(a) = session.tracker.snapshot() {
        let cost = CostModel::default();
        let f = session.control_fraction();
        println!(
            "alignment: rho={:.3} kappa={:.3} phi(f)={:.3} break_even_margin={:+.3} f*={:.3}",
            a.rho,
            a.kappa,
            a.phi(f),
            a.break_even_margin(f, &cost),
            a.f_star(&cost)
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let unknown = args.unknown_keys();
    anyhow::ensure!(unknown.is_empty(), "unknown flags: {unknown:?}");
    let server = lgp::serve::Server::bind(&addr)?;
    // Machine-readable first line so scripts can scrape the bound
    // address when `--addr host:0` picked an ephemeral port.
    println!("lgp-serve listening on http://{}", server.local_addr()?);
    println!("  POST /sessions | GET /sessions/:id | GET /sessions/:id/events | POST /sessions/:id/cancel");
    server.run()
}

fn cmd_theory(_args: &Args) -> anyhow::Result<()> {
    let cost = CostModel::default();
    println!("Cost model: Forward=1, Backward=2, CheapForward=0.7\n");
    println!("Theorem 3 — break-even alignment rho*(f, kappa):");
    let mut t = Table::new(&["f", "gamma(f)", "rho*(k=0.8)", "rho*(k=1)", "rho*(k=1.2)"]);
    for &f in &[0.05, 0.1, 0.2, 0.25, 0.5, 0.75, 1.0] {
        t.row(vec![
            format!("{f:.2}"),
            format!("{:.3}", cost.gamma(f)),
            format!("{:.3}", theory::rho_star(f, 0.8, &cost)),
            format!("{:.3}", theory::rho_star(f, 1.0, &cost)),
            format!("{:.3}", theory::rho_star(f, 1.2, &cost)),
        ]);
    }
    t.print();
    println!("\nTheorem 4 — regime switch and optimal control fraction:");
    let mut t = Table::new(&["kappa", "rho_switch", "f*(rho=0.7)", "f*(rho=0.8)", "f*(rho=0.9)"]);
    for &k in &[0.8, 0.9, 1.0, 1.1, 1.2] {
        t.row(vec![
            format!("{k:.1}"),
            format!("{:.4}", theory::rho_switch(k, &cost)),
            format!("{:.3}", theory::f_star(0.7, k, &cost)),
            format!("{:.3}", theory::f_star(0.8, k, &cost)),
            format!("{:.3}", theory::f_star(0.9, k, &cost)),
        ]);
    }
    t.print();
    println!("\nPaper quotes: rho*(0.1,1)≈0.876, rho*(0.2,1)≈0.802, rho*(0.5,1)≈0.689,");
    println!("              rho_switch(1)≈0.6167, f*(0.8,1)≈0.45");
    Ok(())
}

fn cmd_sweep_f(args: &Args) -> anyhow::Result<()> {
    let fs = args.f64_list("fs", &[0.125, 0.25, 0.5]);
    // Parse flags (and read any --config file) exactly once; each sweep
    // point builds from a clone of the resolved configuration.
    let base = checked_builder(args)?.config().clone();
    let mut t = Table::new(&["f", "steps", "wall_s", "val_acc", "rho", "cost_units"]);
    for &f in &fs {
        let mut session =
            SessionBuilder::from_config(base.clone()).algo(Algo::Gpr).f(f).build()?;
        let t0 = std::time::Instant::now();
        session.run()?;
        let rho = session.tracker.snapshot().map_or(f64::NAN, |a| a.rho);
        t.row(vec![
            format!("{f:.3}"),
            format!("{}", session.step_count()),
            format!("{:.1}", t0.elapsed().as_secs_f64()),
            format!("{:.4}", session.final_val_acc()),
            format!("{rho:.3}"),
            format!("{:.0}", session.cost_units),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_data(args: &Args) -> anyhow::Result<()> {
    let n = args.usize_or("n", 100);
    let side = args.usize_or("side", 32);
    let seed = args.u64_or("seed", 0);
    let ds = lgp::data::synthetic::generate(n, side, 10, seed);
    let mut counts = [0usize; 10];
    let mut mean = 0.0f64;
    let mut mx = f32::MIN;
    for (im, &l) in ds.images.iter().zip(&ds.labels) {
        counts[l as usize] += 1;
        for &v in &im.data {
            mean += v as f64;
            mx = mx.max(v.abs());
        }
    }
    mean /= (n * 3 * side * side) as f64;
    println!("synthetic dataset: n={n} side={side} seed={seed}");
    println!("class counts: {counts:?}");
    println!("pixel mean={mean:.4} max|v|={mx:.2}");
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = checked_builder(args)?.config().artifacts_dir.clone();
    let m = lgp::model::Manifest::load(&dir)?;
    println!("preset={} image={} width={} classes={}", m.preset, m.image, m.width, m.classes);
    println!(
        "trunk_params={} total_params={} rank={} n_fit={} micro_batch={} fs={:?}",
        m.trunk_params, m.total_params, m.rank, m.n_fit, m.micro_batch, m.fs
    );
    let mut t = Table::new(&["artifact", "args", "outs", "file"]);
    for (name, a) in &m.artifacts {
        t.row(vec![
            name.clone(),
            a.args.len().to_string(),
            a.outs.len().to_string(),
            a.file.file_name().unwrap().to_string_lossy().into_owned(),
        ]);
    }
    t.print();
    Ok(())
}
