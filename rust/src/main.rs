//! lgp — leader entrypoint.
//!
//! Subcommands:
//!   train      run Algorithm 1 (GPR) or Algorithm 2 (baseline)
//!   theory     print the Section 5 closed-form tables (Thm 3/4, cost model)
//!   sweep-f    train short runs across control fractions f
//!   data       generate + describe the synthetic dataset
//!   info       show manifest / artifact inventory
//!
//! Examples:
//!   lgp train --preset tiny --algo gpr --f 0.25 --steps 30
//!   lgp train --preset small --algo baseline --budget 60
//!   lgp theory
//!   lgp sweep-f --preset small --fs 0.125,0.25,0.5 --steps 20

use lgp::bench_support::Table;
use lgp::config::RunConfig;
use lgp::coordinator::Trainer;
use lgp::theory::{self, CostModel};
use lgp::util::cli::Args;
use lgp::util::CsvWriter;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("train") => run(cmd_train(&args)),
        Some("theory") => run(cmd_theory(&args)),
        Some("sweep-f") => run(cmd_sweep_f(&args)),
        Some("data") => run(cmd_data(&args)),
        Some("info") => run(cmd_info(&args)),
        _ => {
            eprint!("{}", HELP);
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
lgp — Linear Gradient Prediction with Control Variates (paper reproduction)

USAGE: lgp <subcommand> [--key value]...

SUBCOMMANDS
  train    --preset tiny|small|paper --algo gpr|baseline [--f 0.25]
           [--steps N] [--budget SECS] [--accum K] [--optimizer muon|adamw|sgd|momentum]
           [--lr 0.02] [--refit-every N] [--seed S] [--csv out.csv]
           [--backend naive|blocked|micro|auto]   (host tensor kernels; auto = probe)
           [--shards N]   (data-parallel worker threads per update;
                           bit-identical to --shards 1, DESIGN.md ADR-004)
  theory   print Theorem 3/4 tables and the cost model
  sweep-f  --fs 0.125,0.25,0.5 plus the train flags
  data     --n 100 --side 32 [--seed S]  describe synthetic data
  info     --preset tiny  show the artifact manifest

See also: `bench_report` (validates the BENCH_*.json bench trajectory,
EXPERIMENTS.md) and DESIGN.md for the architecture.
";

fn run(r: anyhow::Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn build_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.str_opt("config") {
        let j = RunConfig::load_json_file(std::path::Path::new(&path))?;
        cfg.apply_json(&j)?;
    }
    cfg.apply_args(args)?;
    let unknown = args.unknown_keys();
    anyhow::ensure!(unknown.is_empty(), "unknown flags: {unknown:?}");
    Ok(cfg)
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let csv_path = args.str_opt("csv");
    let show_artifact_times = args.flag("artifact-times");
    let cfg = build_config(args)?;
    let algo = cfg.algo;
    let mut trainer = Trainer::new(cfg)?;
    let mut csv = match &csv_path {
        Some(p) => Some(CsvWriter::create(
            std::path::Path::new(p),
            &lgp::metrics::LogRow::HEADER,
        )?),
        None => None,
    };
    let t0 = std::time::Instant::now();
    trainer.train(csv.as_mut())?;
    let dt = t0.elapsed().as_secs_f64();
    let st = trainer.rt.stats_snapshot();
    println!(
        "algo={algo:?} backend={} shards={} steps={} wall={dt:.1}s final_val_acc={:.4} examples={} cost_units={:.0}",
        trainer.backend.name(),
        trainer.shards(),
        trainer.step_count(),
        trainer.final_val_acc(),
        trainer.examples_seen,
        trainer.cost_units,
    );
    println!(
        "runtime: calls={} exec={:.2}s upload={:.2}s download={:.2}s compile={:.2}s",
        st.calls, st.exec_secs, st.upload_secs, st.download_secs, st.compile_secs
    );
    if show_artifact_times {
        for (name, (n, secs)) in &st.per_artifact {
            println!("  {name:<28} calls={n:<4} total={secs:.2}s avg={:.1}ms", secs / *n as f64 * 1e3);
        }
    }
    if let Some(a) = trainer.tracker.snapshot() {
        let cost = CostModel::default();
        println!(
            "alignment: rho={:.3} kappa={:.3} phi(f)={:.3} break_even_margin={:+.3} f*={:.3}",
            a.rho,
            a.kappa,
            a.phi(trainer.cfg.f),
            a.break_even_margin(trainer.cfg.f, &cost),
            a.f_star(&cost)
        );
    }
    Ok(())
}

fn cmd_theory(_args: &Args) -> anyhow::Result<()> {
    let cost = CostModel::default();
    println!("Cost model: Forward=1, Backward=2, CheapForward=0.7\n");
    println!("Theorem 3 — break-even alignment rho*(f, kappa):");
    let mut t = Table::new(&["f", "gamma(f)", "rho*(k=0.8)", "rho*(k=1)", "rho*(k=1.2)"]);
    for &f in &[0.05, 0.1, 0.2, 0.25, 0.5, 0.75, 1.0] {
        t.row(vec![
            format!("{f:.2}"),
            format!("{:.3}", cost.gamma(f)),
            format!("{:.3}", theory::rho_star(f, 0.8, &cost)),
            format!("{:.3}", theory::rho_star(f, 1.0, &cost)),
            format!("{:.3}", theory::rho_star(f, 1.2, &cost)),
        ]);
    }
    t.print();
    println!("\nTheorem 4 — regime switch and optimal control fraction:");
    let mut t = Table::new(&["kappa", "rho_switch", "f*(rho=0.7)", "f*(rho=0.8)", "f*(rho=0.9)"]);
    for &k in &[0.8, 0.9, 1.0, 1.1, 1.2] {
        t.row(vec![
            format!("{k:.1}"),
            format!("{:.4}", theory::rho_switch(k, &cost)),
            format!("{:.3}", theory::f_star(0.7, k, &cost)),
            format!("{:.3}", theory::f_star(0.8, k, &cost)),
            format!("{:.3}", theory::f_star(0.9, k, &cost)),
        ]);
    }
    t.print();
    println!("\nPaper quotes: rho*(0.1,1)≈0.876, rho*(0.2,1)≈0.802, rho*(0.5,1)≈0.689,");
    println!("              rho_switch(1)≈0.6167, f*(0.8,1)≈0.45");
    Ok(())
}

fn cmd_sweep_f(args: &Args) -> anyhow::Result<()> {
    let fs = args.f64_list("fs", &[0.125, 0.25, 0.5]);
    let base = build_config(args)?;
    let mut t = Table::new(&["f", "steps", "wall_s", "val_acc", "rho", "cost_units"]);
    for &f in &fs {
        let mut cfg = base.clone();
        cfg.f = f;
        cfg.algo = lgp::config::Algo::Gpr;
        let mut trainer = Trainer::new(cfg)?;
        let t0 = std::time::Instant::now();
        trainer.train(None)?;
        let rho = trainer.tracker.snapshot().map_or(f64::NAN, |a| a.rho);
        t.row(vec![
            format!("{f:.3}"),
            format!("{}", trainer.step_count()),
            format!("{:.1}", t0.elapsed().as_secs_f64()),
            format!("{:.4}", trainer.final_val_acc()),
            format!("{rho:.3}"),
            format!("{:.0}", trainer.cost_units),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_data(args: &Args) -> anyhow::Result<()> {
    let n = args.usize_or("n", 100);
    let side = args.usize_or("side", 32);
    let seed = args.u64_or("seed", 0);
    let ds = lgp::data::synthetic::generate(n, side, 10, seed);
    let mut counts = [0usize; 10];
    let mut mean = 0.0f64;
    let mut mx = f32::MIN;
    for (im, &l) in ds.images.iter().zip(&ds.labels) {
        counts[l as usize] += 1;
        for &v in &im.data {
            mean += v as f64;
            mx = mx.max(v.abs());
        }
    }
    mean /= (n * 3 * side * side) as f64;
    println!("synthetic dataset: n={n} side={side} seed={seed}");
    println!("class counts: {counts:?}");
    println!("pixel mean={mean:.4} max|v|={mx:.2}");
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let cfg = build_config(args)?;
    let m = lgp::model::Manifest::load(&cfg.artifacts_dir)?;
    println!("preset={} image={} width={} classes={}", m.preset, m.image, m.width, m.classes);
    println!(
        "trunk_params={} total_params={} rank={} n_fit={} micro_batch={} fs={:?}",
        m.trunk_params, m.total_params, m.rank, m.n_fit, m.micro_batch, m.fs
    );
    let mut t = Table::new(&["artifact", "args", "outs", "file"]);
    for (name, a) in &m.artifacts {
        t.row(vec![
            name.clone(),
            a.args.len().to_string(),
            a.outs.len().to_string(),
            a.file.file_name().unwrap().to_string_lossy().into_owned(),
        ]);
    }
    t.print();
    Ok(())
}
