//! lgp — leader entrypoint: a thin adapter from CLI flags to the
//! library-first session API (`lgp::session`, DESIGN.md ADR-005). All
//! configuration goes through `session::cli::builder_from_args`; this
//! file only wires observers, prints summaries, and formats tables.
//!
//! Subcommands:
//!   train      run Algorithm 1 (GPR) or Algorithm 2 (baseline)
//!   launch     multi-process run: spawn followers + lead (DESIGN.md ADR-010)
//!   reshard    rewrite a checkpoint for a new shard geometry (ADR-010)
//!   serve      host training sessions over HTTP/JSONL (DESIGN.md ADR-009)
//!   theory     print the Section 5 closed-form tables (Thm 3/4, cost model)
//!   sweep-f    train short runs across control fractions f
//!   data       generate + describe the synthetic dataset
//!   info       show manifest / artifact inventory
//!
//! Examples:
//!   lgp train --preset tiny --algo gpr --f 0.25 --steps 30
//!   lgp train --preset small --algo baseline --budget 60
//!   lgp launch --procs 2 --preset tiny --shards 2 --steps 30
//!   lgp reshard --dir ckpts --out ckpts8 --from 4 --to 8
//!   lgp theory
//!   lgp sweep-f --preset small --fs 0.125,0.25,0.5 --steps 20

use lgp::bench_support::Table;
use lgp::config::{Algo, OptimKind};
use lgp::observer::{CsvObserver, JsonlObserver};
use lgp::session::cli::builder_from_args;
use lgp::session::SessionBuilder;
use lgp::tensor::BackendKind;
use lgp::theory::{self, CostModel};
use lgp::util::cli::{options, Args};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("train") => run(cmd_train(&args)),
        Some("launch") => run(cmd_launch(&args)),
        Some("reshard") => run(cmd_reshard(&args)),
        Some("serve") => run(cmd_serve(&args)),
        Some("theory") => run(cmd_theory(&args)),
        Some("sweep-f") => run(cmd_sweep_f(&args)),
        Some("data") => run(cmd_data(&args)),
        Some("info") => run(cmd_info(&args)),
        _ => {
            eprint!("{}", help());
            2
        }
    };
    std::process::exit(code);
}

/// Help text with the enum option lists generated from the same
/// `EnumSpec` tables the parsers use — the lists cannot drift.
fn help() -> String {
    format!(
        "\
lgp — Linear Gradient Prediction with Control Variates (paper reproduction)

USAGE: lgp <subcommand> [--key value]...

SUBCOMMANDS
  train    --preset tiny|small|paper --algo {algo} [--f 0.25]
           [--steps N] [--budget SECS] [--accum K] [--optimizer {optim}]
           [--lr 0.02] [--refit-every N] [--seed S] [--csv out.csv] [--jsonl out.jsonl]
           [--backend {backend}]   (host tensor kernels; auto = probe)
           [--shards N]   (data-parallel worker threads per update;
                           bit-identical to --shards 1, DESIGN.md ADR-004)
           [--checkpoint-dir DIR] [--checkpoint-every N] [--resume]
           [--checkpoint-keep K]   (prune to the newest K valid artifacts;
                           crash-safe checkpoints + bit-identical resume;
                           SIGINT checkpoints then exits, DESIGN.md ADR-008)
  launch   --procs P plus the train flags: elastic multi-process runner
           (DESIGN.md ADR-010). Spawns P-1 follower processes over
           loopback sockets; P procs x S shards is bit-identical to
           --shards P*S. SIGINT / peer death -> coordinated final
           checkpoint on the leader, nonzero exit.
  reshard  --ckpt FILE | --dir DIR (newest) --out DIR --from N --to M
           rewrite a .lgpckpt for a new shard geometry: every section
           CRC-checked and re-derived, output proven byte-stable
  serve    --addr 127.0.0.1:7878   (0 = ephemeral port, printed on stdout)
           training-as-a-service control plane (DESIGN.md ADR-009):
           POST /sessions (JSON config), GET /sessions/:id,
           GET /sessions/:id/events (JSONL stream), POST /sessions/:id/cancel
  theory   print Theorem 3/4 tables and the cost model
  sweep-f  --fs 0.125,0.25,0.5 plus the train flags
  data     --n 100 --side 32 [--seed S]  describe synthetic data
  info     --preset tiny  show the artifact manifest

See also: `bench_report` (validates the BENCH_*.json bench trajectory,
EXPERIMENTS.md) and DESIGN.md for the architecture.
",
        algo = options(Algo::SPECS),
        optim = options(OptimKind::SPECS),
        backend = options(BackendKind::SPECS),
    )
}

fn run(r: anyhow::Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Builder from flags, with the typo guard applied after every train
/// flag has been consumed.
fn checked_builder(args: &Args) -> anyhow::Result<SessionBuilder> {
    let b = builder_from_args(args)?;
    let unknown = args.unknown_keys();
    anyhow::ensure!(unknown.is_empty(), "unknown flags: {unknown:?}");
    Ok(b)
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let csv_path = args.str_opt("csv");
    let jsonl_path = args.str_opt("jsonl");
    let show_artifact_times = args.flag("artifact-times");
    // Follower wiring for `lgp launch` (DESIGN.md ADR-010): the leader
    // re-spawns this binary with these three flags appended.
    let dist_connect = args.str_opt("dist-connect");
    let dist_rank = args.parsed::<usize>("dist-rank")?;
    let dist_procs = args.parsed::<usize>("dist-procs")?;
    let follower = match (&dist_connect, dist_rank, dist_procs) {
        (None, None, None) => None,
        (Some(addr), Some(rank), Some(procs)) => Some((addr.clone(), rank, procs)),
        _ => anyhow::bail!(
            "--dist-connect, --dist-rank and --dist-procs go together (lgp launch sets them)"
        ),
    };
    let mut b = checked_builder(args)?;
    if let Some(p) = &csv_path {
        b = b.observer(Box::new(CsvObserver::create(std::path::Path::new(p))?));
    }
    if let Some(p) = &jsonl_path {
        b = b.observer(Box::new(JsonlObserver::create(std::path::Path::new(p))?));
    }
    if follower.is_some() {
        // A follower must outlive a group SIGINT: the leader checkpoints
        // and broadcasts the coordinated shutdown, and the follower's
        // blocked exchange is what receives it. A token nobody cancels
        // makes the run loop ignore the process-global flag...
        b = b.cancel_token(lgp::util::shutdown::CancelToken::new());
    }
    let algo = b.config().algo;
    let mut session = b.build()?;
    if let Some((addr, rank, procs)) = follower {
        anyhow::ensure!(
            session.cfg.checkpoint_every == 0,
            "a dist follower must not write periodic checkpoints (the leader owns them); \
             drop --checkpoint-every"
        );
        // ...and installing the handler turns the terminal's
        // process-group SIGINT into a harmless flag set instead of the
        // default kill.
        lgp::util::shutdown::install();
        let geom = session.dist_geometry(procs);
        let d = lgp::dist::connect(&addr, rank, &geom)?;
        session.attach_dist(d)?;
    }
    let t0 = std::time::Instant::now();
    session.run()?;
    let dt = t0.elapsed().as_secs_f64();
    if let Some((rank, procs)) = session.dist_info() {
        if rank != 0 {
            // The leader owns the group summary; a follower line would
            // interleave with it on the shared terminal.
            println!(
                "dist follower rank {rank}/{procs} done: steps={} wall={dt:.1}s",
                session.step_count()
            );
            return Ok(());
        }
    }
    print_train_summary(&session, algo, dt, show_artifact_times);
    Ok(())
}

fn print_train_summary(
    session: &lgp::session::TrainSession,
    algo: Algo,
    dt: f64,
    show_artifact_times: bool,
) {
    let st = session.rt.stats_snapshot();
    println!(
        "algo={algo:?} backend={} shards={} steps={} wall={dt:.1}s final_val_acc={:.4} examples={} cost_units={:.0}",
        session.backend.name(),
        session.shards(),
        session.step_count(),
        session.final_val_acc(),
        session.examples_seen,
        session.cost_units,
    );
    println!(
        "runtime: calls={} exec={:.2}s upload={:.2}s download={:.2}s compile={:.2}s",
        st.calls, st.exec_secs, st.upload_secs, st.download_secs, st.compile_secs
    );
    if show_artifact_times {
        for (name, (n, secs)) in &st.per_artifact {
            println!("  {name:<28} calls={n:<4} total={secs:.2}s avg={:.1}ms", secs / *n as f64 * 1e3);
        }
    }
    if let Some(a) = session.tracker.snapshot() {
        let cost = CostModel::default();
        let f = session.control_fraction();
        println!(
            "alignment: rho={:.3} kappa={:.3} phi(f)={:.3} break_even_margin={:+.3} f*={:.3}",
            a.rho,
            a.kappa,
            a.phi(f),
            a.break_even_margin(f, &cost),
            a.f_star(&cost)
        );
    }
}

/// `lgp launch --procs P <train flags>` — elastic multi-process runner
/// (DESIGN.md ADR-010): bind a loopback listener, re-spawn this binary
/// `P-1` times as `train --dist-connect` followers, run rank 0 in-process
/// as the leader, and supervise the children. `--procs P --shards S` is
/// bit-identical to a single-process `--shards P*S` run.
fn cmd_launch(args: &Args) -> anyhow::Result<()> {
    use anyhow::Context as _;
    use std::process::{Child, Command};

    let procs = args.parsed::<usize>("procs")?.unwrap_or(2);
    anyhow::ensure!(procs >= 1, "--procs must be >= 1 (got {procs})");
    let csv_path = args.str_opt("csv");
    let jsonl_path = args.str_opt("jsonl");
    let show_artifact_times = args.flag("artifact-times");
    let mut b = checked_builder(args)?;
    if let Some(p) = &csv_path {
        b = b.observer(Box::new(CsvObserver::create(std::path::Path::new(p))?));
    }
    if let Some(p) = &jsonl_path {
        b = b.observer(Box::new(JsonlObserver::create(std::path::Path::new(p))?));
    }
    let algo = b.config().algo;
    let mut session = b.build()?;
    if procs == 1 {
        // Degenerate group: exactly `lgp train`.
        let t0 = std::time::Instant::now();
        session.run()?;
        print_train_summary(&session, algo, t0.elapsed().as_secs_f64(), show_artifact_times);
        return Ok(());
    }
    lgp::config::validate_dist(procs, session.cfg.accum)?;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").context("binding dist listener")?;
    let addr = listener.local_addr()?.to_string();

    // Follower argv: this command's own flags minus the leader-only ones
    // (observer sinks, wall budget, periodic checkpoint writing), plus
    // the dist wiring. `--checkpoint-dir`/`--resume` stay so a resumed
    // group restores every rank from the same artifact.
    const LEADER_ONLY: &[&str] = &[
        "procs",
        "csv",
        "jsonl",
        "budget",
        "checkpoint-every",
        "checkpoint-keep",
        "artifact-times",
    ];
    let mut follower_argv: Vec<String> = vec!["train".into()];
    for (k, v) in args.entries() {
        if LEADER_ONLY.contains(&k) {
            continue;
        }
        follower_argv.push(format!("--{k}"));
        follower_argv.push(v.to_string());
    }
    follower_argv.push("--dist-connect".into());
    follower_argv.push(addr);
    follower_argv.push("--dist-procs".into());
    follower_argv.push(procs.to_string());
    if session.cfg.max_steps == 0 {
        // Budget-driven leader: followers get a far-off step limit so
        // their config validates; they actually stop when the leader's
        // budget expires and its shutdown broadcast lands in their
        // blocked exchange.
        follower_argv.push("--steps".into());
        follower_argv.push("1000000000".into());
    }

    let exe = std::env::current_exe().context("locating own binary for follower spawn")?;
    let mut children: Vec<(usize, Child)> = Vec::new();
    for rank in 1..procs {
        let child = Command::new(&exe)
            .args(&follower_argv)
            .arg("--dist-rank")
            .arg(rank.to_string())
            .spawn()
            .with_context(|| format!("spawning follower rank {rank}"))?;
        children.push((rank, child));
    }

    let geom = session.dist_geometry(procs);
    let accepted = lgp::dist::accept_followers(&listener, &geom, || {
        for (rank, ch) in children.iter_mut() {
            if let Some(status) = ch.try_wait()? {
                anyhow::bail!("follower rank {rank} exited during handshake: {status}");
            }
        }
        Ok(())
    });
    let d = match accepted {
        Ok(d) => d,
        Err(e) => {
            // A half-formed group cannot make progress; reap everything
            // so no orphan keeps retrying against a dead listener.
            for (_, ch) in children.iter_mut() {
                let _ = ch.kill();
                let _ = ch.wait();
            }
            return Err(e.context("dist handshake failed"));
        }
    };
    session.attach_dist(d)?;

    let t0 = std::time::Instant::now();
    let run_result = session.run();
    let interrupted = lgp::util::shutdown::requested();
    let dt = t0.elapsed().as_secs_f64();

    // Reap every follower before judging the run: the leader's finish
    // broadcast (or its own death) is what unblocks them, so this
    // converges quickly.
    let mut follower_fail = false;
    for (rank, ch) in children.iter_mut() {
        match ch.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => {
                eprintln!("follower rank {rank} exited with {status}");
                follower_fail = true;
            }
            Err(e) => {
                eprintln!("follower rank {rank} not reaped: {e}");
                follower_fail = true;
            }
        }
    }
    run_result?;
    print_train_summary(&session, algo, dt, show_artifact_times);
    anyhow::ensure!(
        !interrupted,
        "interrupted: coordinated final checkpoint written, exiting nonzero (ADR-008/010)"
    );
    anyhow::ensure!(!follower_fail, "one or more followers exited nonzero");
    Ok(())
}

/// `lgp reshard` — validate a checkpoint end-to-end and rewrite it for a
/// new shard geometry (`checkpoint::reshard`, DESIGN.md ADR-010).
fn cmd_reshard(args: &Args) -> anyhow::Result<()> {
    use anyhow::Context as _;

    let ckpt = args.str_opt("ckpt");
    let dir = args.str_opt("dir");
    let out = args.str_opt("out").context("--out DIR is required")?;
    let from = args.parsed::<usize>("from")?.context("--from N (old shard count) is required")?;
    let to = args.parsed::<usize>("to")?.context("--to M (new shard count) is required")?;
    let unknown = args.unknown_keys();
    anyhow::ensure!(unknown.is_empty(), "unknown flags: {unknown:?}");
    let input = match (ckpt, dir) {
        (Some(f), None) => std::path::PathBuf::from(f),
        (None, Some(d)) => newest_checkpoint(std::path::Path::new(&d))?,
        _ => anyhow::bail!("give exactly one of --ckpt FILE or --dir DIR"),
    };
    let report =
        lgp::checkpoint::reshard::reshard_file(&input, std::path::Path::new(&out), from, to)?;
    println!(
        "resharded {from} -> {to} shards: step={} sections={} fit_rows={} cursor={} -> {} ({} bytes)",
        report.step,
        report.sections,
        report.fitbuf_rows,
        report.cursor,
        report.path.display(),
        report.bytes,
    );
    Ok(())
}

/// Highest-step `ckpt-*.lgpckpt` in `dir` (the artifact `--resume` would
/// pick), so `lgp reshard --dir` reshards what a resume would load.
fn newest_checkpoint(dir: &std::path::Path) -> anyhow::Result<std::path::PathBuf> {
    use anyhow::Context as _;
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))?;
    let mut best: Option<(u64, std::path::PathBuf)> = None;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(step) = lgp::checkpoint::parse_step(&name.to_string_lossy()) else {
            continue;
        };
        if best.as_ref().map_or(true, |(s, _)| step > *s) {
            best = Some((step, entry.path()));
        }
    }
    best.map(|(_, p)| p)
        .with_context(|| format!("no ckpt-*.lgpckpt checkpoints in {}", dir.display()))
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7878");
    let unknown = args.unknown_keys();
    anyhow::ensure!(unknown.is_empty(), "unknown flags: {unknown:?}");
    let server = lgp::serve::Server::bind(&addr)?;
    // Machine-readable first line so scripts can scrape the bound
    // address when `--addr host:0` picked an ephemeral port.
    println!("lgp-serve listening on http://{}", server.local_addr()?);
    println!("  POST /sessions | GET /sessions/:id | GET /sessions/:id/events | POST /sessions/:id/cancel");
    server.run()
}

fn cmd_theory(_args: &Args) -> anyhow::Result<()> {
    let cost = CostModel::default();
    println!("Cost model: Forward=1, Backward=2, CheapForward=0.7\n");
    println!("Theorem 3 — break-even alignment rho*(f, kappa):");
    let mut t = Table::new(&["f", "gamma(f)", "rho*(k=0.8)", "rho*(k=1)", "rho*(k=1.2)"]);
    for &f in &[0.05, 0.1, 0.2, 0.25, 0.5, 0.75, 1.0] {
        t.row(vec![
            format!("{f:.2}"),
            format!("{:.3}", cost.gamma(f)),
            format!("{:.3}", theory::rho_star(f, 0.8, &cost)),
            format!("{:.3}", theory::rho_star(f, 1.0, &cost)),
            format!("{:.3}", theory::rho_star(f, 1.2, &cost)),
        ]);
    }
    t.print();
    println!("\nTheorem 4 — regime switch and optimal control fraction:");
    let mut t = Table::new(&["kappa", "rho_switch", "f*(rho=0.7)", "f*(rho=0.8)", "f*(rho=0.9)"]);
    for &k in &[0.8, 0.9, 1.0, 1.1, 1.2] {
        t.row(vec![
            format!("{k:.1}"),
            format!("{:.4}", theory::rho_switch(k, &cost)),
            format!("{:.3}", theory::f_star(0.7, k, &cost)),
            format!("{:.3}", theory::f_star(0.8, k, &cost)),
            format!("{:.3}", theory::f_star(0.9, k, &cost)),
        ]);
    }
    t.print();
    println!("\nPaper quotes: rho*(0.1,1)≈0.876, rho*(0.2,1)≈0.802, rho*(0.5,1)≈0.689,");
    println!("              rho_switch(1)≈0.6167, f*(0.8,1)≈0.45");
    Ok(())
}

fn cmd_sweep_f(args: &Args) -> anyhow::Result<()> {
    let fs = args.f64_list("fs", &[0.125, 0.25, 0.5]);
    // Parse flags (and read any --config file) exactly once; each sweep
    // point builds from a clone of the resolved configuration.
    let base = checked_builder(args)?.config().clone();
    let mut t = Table::new(&["f", "steps", "wall_s", "val_acc", "rho", "cost_units"]);
    for &f in &fs {
        let mut session =
            SessionBuilder::from_config(base.clone()).algo(Algo::Gpr).f(f).build()?;
        let t0 = std::time::Instant::now();
        session.run()?;
        let rho = session.tracker.snapshot().map_or(f64::NAN, |a| a.rho);
        t.row(vec![
            format!("{f:.3}"),
            format!("{}", session.step_count()),
            format!("{:.1}", t0.elapsed().as_secs_f64()),
            format!("{:.4}", session.final_val_acc()),
            format!("{rho:.3}"),
            format!("{:.0}", session.cost_units),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_data(args: &Args) -> anyhow::Result<()> {
    let n = args.usize_or("n", 100);
    let side = args.usize_or("side", 32);
    let seed = args.u64_or("seed", 0);
    let ds = lgp::data::synthetic::generate(n, side, 10, seed);
    let mut counts = [0usize; 10];
    let mut mean = 0.0f64;
    let mut mx = f32::MIN;
    for (im, &l) in ds.images.iter().zip(&ds.labels) {
        counts[l as usize] += 1;
        for &v in &im.data {
            mean += v as f64;
            mx = mx.max(v.abs());
        }
    }
    mean /= (n * 3 * side * side) as f64;
    println!("synthetic dataset: n={n} side={side} seed={seed}");
    println!("class counts: {counts:?}");
    println!("pixel mean={mean:.4} max|v|={mx:.2}");
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = checked_builder(args)?.config().artifacts_dir.clone();
    let m = lgp::model::Manifest::load(&dir)?;
    println!("preset={} image={} width={} classes={}", m.preset, m.image, m.width, m.classes);
    println!(
        "trunk_params={} total_params={} rank={} n_fit={} micro_batch={} fs={:?}",
        m.trunk_params, m.total_params, m.rank, m.n_fit, m.micro_batch, m.fs
    );
    let mut t = Table::new(&["artifact", "args", "outs", "file"]);
    for (name, a) in &m.artifacts {
        t.row(vec![
            name.clone(),
            a.args.len().to_string(),
            a.outs.len().to_string(),
            a.file.file_name().unwrap().to_string_lossy().into_owned(),
        ]);
    }
    t.print();
    Ok(())
}
