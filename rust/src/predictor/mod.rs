//! The paper's NTK-inspired linear gradient predictor (Section 4).
//!
//! State: the rank-r basis `U ∈ R^{P_T×r}` and the bilinear coefficient
//! matrix `B ∈ R^{r×(D+1)D}` such that for one example
//!
//!     ĝ_trunk(x) = U · B · vec([a(x); 1] h(x)^T),   h = W_a^T r_cls.
//!
//! `fit.rs` estimates (U, B) from collected per-example gradients; this
//! module holds the state, the host-side batched predictor (mirror of the
//! L1 pallas kernel — used for diagnostics and as a CPU fallback), and the
//! refit scheduler.

pub mod fit;

use crate::tensor::{matmul, Tensor};

/// Predictor parameters + bookkeeping.
pub struct Predictor {
    /// (P_T, r) orthonormal-column basis of the gradient subspace.
    pub u: Tensor,
    /// (r, (D+1)*D) bilinear coefficients.
    pub b: Tensor,
    pub width: usize,
    pub rank: usize,
    /// Number of completed fits (0 = never fitted; predictions are zero,
    /// which the control variate debiases to plain — smaller-batch — SGD).
    pub fits: usize,
    /// Monotone version counter used by the runtime to invalidate
    /// device-resident copies of U and B.
    pub version: u64,
}

impl Predictor {
    /// Zero-initialized predictor (predicts ĝ = 0 until first fit).
    pub fn new(trunk_params: usize, width: usize, rank: usize) -> Predictor {
        Predictor {
            u: Tensor::zeros(&[trunk_params, rank]),
            b: Tensor::zeros(&[rank, (width + 1) * width]),
            width,
            rank,
            fits: 0,
            version: 0,
        }
    }

    /// Install freshly fitted (U, B).
    pub fn install(&mut self, u: Tensor, b: Tensor) {
        assert_eq!(u.shape, self.u.shape, "U shape changed");
        assert_eq!(b.shape, self.b.shape, "B shape changed");
        self.u = u;
        self.b = b;
        self.fits += 1;
        self.version += 1;
    }

    /// Batched trunk-gradient prediction — the same three matmuls as the
    /// pallas kernel (`python/compile/kernels/predict_grad.py`):
    ///   F = A1^T H / m;  c = B vec(F);  ĝ = U c.
    ///
    /// `a`: (m, D) activations; `h`: (m, D) backprop features W_a^T r.
    pub fn predict_mean_trunk(&self, a: &Tensor, h: &Tensor) -> Vec<f32> {
        let m = a.rows();
        let d = self.width;
        assert_eq!(a.cols(), d);
        assert_eq!(h.shape, vec![m, d]);
        // F = [A;1]^T H / m, built directly without materializing A1.
        let mut f = vec![0.0f32; (d + 1) * d];
        for j in 0..m {
            let arow = a.row(j);
            let hrow = h.row(j);
            for i in 0..d {
                let ai = arow[i];
                if ai == 0.0 {
                    continue;
                }
                let frow = &mut f[i * d..(i + 1) * d];
                for (fv, hv) in frow.iter_mut().zip(hrow) {
                    *fv += ai * hv;
                }
            }
            // bias row of A1 (all ones)
            let frow = &mut f[d * d..(d + 1) * d];
            for (fv, hv) in frow.iter_mut().zip(hrow) {
                *fv += hv;
            }
        }
        let inv_m = 1.0 / m as f32;
        for v in &mut f {
            *v *= inv_m;
        }
        let c = matmul::matvec(&self.b, &f);
        matmul::matvec(&self.u, &c)
    }

    /// Per-example prediction ĝ_j (for the Sec. 5.3 ρ̂/κ̂ diagnostics).
    pub fn predict_one_trunk(&self, a_row: &[f32], h_row: &[f32]) -> Vec<f32> {
        let d = self.width;
        let a1 = Tensor::from_vec(
            a_row.iter().copied().chain(std::iter::once(1.0)).collect(),
            &[1, d + 1],
        );
        let h = Tensor::from_vec(h_row.to_vec(), &[1, d]);
        // reuse the batched path with m = 1 (mean over one example)
        let a = Tensor::from_vec(a_row.to_vec(), &[1, d]);
        let _ = a1;
        self.predict_mean_trunk(&a, &h)
    }

    /// Backprop features H = R W_a, where `resid` is (m, C) and head_w is
    /// row-major (D, C): h_j = W_a^T r_j = head_w · r_j.
    pub fn backprop_features(resid: &Tensor, head_w: &[f32], d: usize) -> Tensor {
        let mut h = Tensor::zeros(&[resid.rows(), d]);
        Predictor::backprop_features_into(resid, head_w, d, &mut h);
        h
    }

    /// [`backprop_features`](Self::backprop_features) into a caller-owned
    /// (m, D) output — the sharded refit collectors draw it from their
    /// per-worker `Workspace` (ADR-004). Every cell is overwritten.
    pub fn backprop_features_into(resid: &Tensor, head_w: &[f32], d: usize, h: &mut Tensor) {
        let (m, c) = (resid.rows(), resid.cols());
        assert_eq!(head_w.len(), d * c);
        assert_eq!(h.shape, [m, d], "backprop_features output shape mismatch");
        for j in 0..m {
            let r = resid.row(j);
            let out = &mut h.data[j * d..(j + 1) * d];
            for i in 0..d {
                out[i] = crate::tensor::stats::dot(&head_w[i * c..(i + 1) * c], r);
            }
        }
    }

    /// Exact head gradients from activations + residuals (Sec. 4.3):
    /// (g_w (D*C), g_b (C)).
    pub fn head_grads(a: &Tensor, resid: &Tensor) -> (Vec<f32>, Vec<f32>) {
        let (m, d) = (a.rows(), a.cols());
        let c = resid.cols();
        let mut gw = vec![0.0f32; d * c];
        let mut gb = vec![0.0f32; c];
        for j in 0..m {
            let arow = a.row(j);
            let rrow = resid.row(j);
            for i in 0..d {
                let ai = arow[i];
                let out = &mut gw[i * c..(i + 1) * c];
                for (o, rv) in out.iter_mut().zip(rrow) {
                    *o += ai * rv;
                }
            }
            for (o, rv) in gb.iter_mut().zip(rrow) {
                *o += rv;
            }
        }
        let inv_m = 1.0 / m as f32;
        for v in gw.iter_mut().chain(gb.iter_mut()) {
            *v *= inv_m;
        }
        (gw, gb)
    }
}

/// Classification residuals r = p − y_smooth (m, C).
pub fn residuals(probs: &[f32], labels: &[i32], classes: usize, smoothing: f32) -> Tensor {
    let m = labels.len();
    let mut r = Tensor::from_vec(probs.to_vec(), &[m, classes]);
    let uniform = smoothing / classes as f32;
    for (j, &y) in labels.iter().enumerate() {
        let row = &mut r.data[j * classes..(j + 1) * classes];
        for (k, v) in row.iter_mut().enumerate() {
            *v -= uniform + if k == y as usize { 1.0 - smoothing } else { 0.0 };
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_t(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    #[test]
    fn zero_predictor_predicts_zero() {
        let p = Predictor::new(100, 4, 2);
        let mut rng = Pcg64::seeded(0);
        let a = rand_t(&mut rng, &[3, 4]);
        let h = rand_t(&mut rng, &[3, 4]);
        assert!(p.predict_mean_trunk(&a, &h).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn predict_matches_explicit_linear_algebra() {
        let mut rng = Pcg64::seeded(1);
        let (m, d, r, pt) = (5usize, 6usize, 3usize, 200usize);
        let mut p = Predictor::new(pt, d, r);
        p.install(rand_t(&mut rng, &[pt, r]), rand_t(&mut rng, &[r, (d + 1) * d]));
        let a = rand_t(&mut rng, &[m, d]);
        let h = rand_t(&mut rng, &[m, d]);
        // explicit: mean_j U B vec([a_j;1] h_j^T)
        let mut want = vec![0.0f32; pt];
        for j in 0..m {
            let mut phi = vec![0.0f32; (d + 1) * d];
            for i in 0..d {
                for k in 0..d {
                    phi[i * d + k] = a.at(j, i) * h.at(j, k);
                }
            }
            for k in 0..d {
                phi[d * d + k] = h.at(j, k);
            }
            let c = matmul::matvec(&p.b, &phi);
            let g = matmul::matvec(&p.u, &c);
            for (w, g) in want.iter_mut().zip(&g) {
                *w += g / m as f32;
            }
        }
        let got = p.predict_mean_trunk(&a, &h);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn residuals_sum_to_zero_at_uniform_probs() {
        // With uniform probs and no smoothing, residual sums to 0 per row.
        let probs = vec![0.25f32; 8];
        let r = residuals(&probs, &[1, 3], 4, 0.0);
        for j in 0..2 {
            let s: f32 = r.row(j).iter().sum();
            assert!(s.abs() < 1e-6);
        }
        // Label entry is probs - (1 - s) at the label coordinate.
        assert!((r.at(0, 1) - (0.25 - 1.0)).abs() < 1e-6);
    }

    #[test]
    fn head_grads_match_formula() {
        let mut rng = Pcg64::seeded(2);
        let a = rand_t(&mut rng, &[4, 3]);
        let resid = rand_t(&mut rng, &[4, 2]);
        let (gw, gb) = Predictor::head_grads(&a, &resid);
        // gw = A^T R / m
        let want = matmul::matmul(&a.t(), &resid);
        for (x, y) in gw.iter().zip(&want.data) {
            assert!((x - y / 4.0).abs() < 1e-5);
        }
        for k in 0..2 {
            let want_b: f32 = (0..4).map(|j| resid.at(j, k)).sum::<f32>() / 4.0;
            assert!((gb[k] - want_b).abs() < 1e-6);
        }
    }

    #[test]
    fn backprop_features_orientation() {
        // h_j = head_w · r_j with head_w (D, C) row-major.
        let head_w = vec![1.0, 0.0, 0.0, 2.0]; // D=2, C=2: rows [1,0],[0,2]
        let resid = Tensor::from_vec(vec![3.0, 4.0], &[1, 2]);
        let h = Predictor::backprop_features(&resid, &head_w, 2);
        assert_eq!(h.data, vec![3.0, 8.0]);
    }

    #[test]
    fn install_bumps_version() {
        let mut p = Predictor::new(10, 2, 1);
        let v0 = p.version;
        p.install(Tensor::zeros(&[10, 1]), Tensor::zeros(&[1, 6]));
        assert_eq!(p.version, v0 + 1);
        assert_eq!(p.fits, 1);
    }
}
