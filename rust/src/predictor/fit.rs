//! Predictor fitting: "a standard least squares technique" (paper Sec. 4),
//! made concrete and scalable:
//!
//! 1. **Basis U** — per-example trunk gradients G (n × P_T) are collected
//!    from the `per_example_grads` artifact; the rank-r left-singular
//!    basis of G^T comes from the n×n Gram eigendecomposition
//!    (P_T ≫ n makes a direct SVD infeasible): K = G G^T = V Λ V^T,
//!    U = G^T V_r Λ_r^{-1/2}.
//! 2. **Coefficients B** — kernel ridge regression in the dual. The
//!    bilinear feature Gram factorizes elementwise,
//!    K_Φ = (A1 A1^T) ⊙ (H H^T), so fitting costs O(n²(D+C)) instead of
//!    O(n² D²). α = (K_Φ + λI)^{-1} C with targets C = G U (free from the
//!    SVD), then B = Σ_j α_j ⊗ φ_j materialized as r rank-weighted
//!    A1^T diag(α_i) H products.
//!
//! The numpy mirror of this file is tested in
//! `python/tests/test_predictor_fit.py`; the Rust tests reuse the same
//! synthetic low-rank constructions.

use super::Predictor;
use crate::tensor::{backend, backend::Backend, linalg, stats, Tensor};

/// Accumulates fit samples between refits.
pub struct FitBuffer {
    /// Per-example trunk gradients, one row each (n, P_T).
    pub grads: Vec<Vec<f32>>,
    /// Activations with bias coordinate [a; 1], one row each (n, D+1).
    pub a1: Vec<Vec<f32>>,
    /// Backprop features h = W_a^T r, one row each (n, D).
    pub h: Vec<Vec<f32>>,
    pub capacity: usize,
}

impl FitBuffer {
    pub fn new(capacity: usize) -> FitBuffer {
        FitBuffer { grads: Vec::new(), a1: Vec::new(), h: Vec::new(), capacity }
    }

    pub fn len(&self) -> usize {
        self.grads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grads.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.len() >= self.capacity
    }

    pub fn clear(&mut self) {
        self.grads.clear();
        self.a1.clear();
        self.h.clear();
    }

    /// Push one example (drops oldest beyond capacity — sliding window).
    pub fn push(&mut self, grad: Vec<f32>, mut a: Vec<f32>, h: Vec<f32>) {
        a.push(1.0); // append the bias coordinate once, at collection time
        if self.len() >= self.capacity {
            self.grads.remove(0);
            self.a1.remove(0);
            self.h.remove(0);
        }
        self.grads.push(grad);
        self.a1.push(a);
        self.h.push(h);
    }
}

/// Outcome diagnostics of one fit.
#[derive(Clone, Copy, Debug)]
pub struct FitReport {
    pub n: usize,
    pub rank: usize,
    /// Fraction of gradient energy captured by the top-r subspace —
    /// the empirical check of the paper's low-effective-rank claim.
    pub energy_captured: f64,
    /// Training-set relative prediction error of the fitted predictor.
    pub rel_error: f64,
}

/// Fit (U, B) from the buffer and install into `pred`, using the active
/// tensor backend for the dense reductions.
pub fn fit(pred: &mut Predictor, buf: &FitBuffer, lambda: f32) -> anyhow::Result<FitReport> {
    fit_with(backend::active(), pred, buf, lambda)
}

/// [`fit`] with an explicit tensor backend (the coordinator threads its
/// configured backend through here; equivalence tests pin each one).
pub fn fit_with(
    be: Backend,
    pred: &mut Predictor,
    buf: &FitBuffer,
    lambda: f32,
) -> anyhow::Result<FitReport> {
    let n = buf.len();
    let r = pred.rank;
    anyhow::ensure!(n >= 2 * r, "need at least 2r = {} fit samples, have {n}", 2 * r);
    let p_t = buf.grads[0].len();
    let d = pred.width;

    // ---- 1. basis U via the Gram trick --------------------------------
    // K = G G^T (n, n). f32 unrolled dot via the backend: at P_T ~
    // 10^5..10^7 the relative error is ~1e-5·sqrt(P_T) of norm — far below
    // the fit's own noise — and 5-10x faster than the f64 path (perf pass,
    // EXPERIMENTS.md).
    let mut k = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in i..n {
            let dot = be.dot(&buf.grads[i], &buf.grads[j]);
            k.set(i, j, dot);
            k.set(j, i, dot);
        }
    }
    let (evals, evecs) = linalg::eigh_jacobi(&k); // ascending
    let total_energy: f64 = evals.iter().map(|&e| e.max(0.0) as f64).sum();
    let top_energy: f64 = evals
        .iter()
        .rev()
        .take(r)
        .map(|&e| e.max(0.0) as f64)
        .sum();

    // U = G^T V_r Λ_r^{-1/2}, columns ordered by decreasing eigenvalue.
    // Built column-major first (contiguous axpy per sample), transposed
    // into the row-major U at the end — 10x over the strided write loop.
    let mut scaled_v = Tensor::zeros(&[n, r]); // V_r Λ^{-1/2}
    for c in 0..r {
        let src = n - 1 - c; // descending order
        let lam = evals[src].max(1e-12);
        let inv_sqrt = 1.0 / lam.sqrt();
        for row in 0..n {
            scaled_v.set(row, c, evecs.at(row, src) * inv_sqrt);
        }
    }
    let mut u_cols = Tensor::zeros(&[r, p_t]); // column c is row c here
    for c in 0..r {
        let col = &mut u_cols.data[c * p_t..(c + 1) * p_t];
        for j in 0..n {
            let w = scaled_v.at(j, c);
            if w == 0.0 {
                continue;
            }
            let g = &buf.grads[j];
            for (o, gv) in col.iter_mut().zip(g) {
                *o += w * gv;
            }
        }
    }

    // ---- 2. targets C = G U  (contiguous f32 dots over u_cols) ---------
    let mut targets = Tensor::zeros(&[n, r]);
    for j in 0..n {
        let g = &buf.grads[j];
        for c in 0..r {
            targets.set(j, c, be.dot(g, &u_cols.data[c * p_t..(c + 1) * p_t]));
        }
    }
    let u = u_cols.t(); // (p_t, r) row-major

    // ---- 3. dual kernel ridge for B ------------------------------------
    // K_phi = (A1 A1^T) o (H H^T) + lambda I
    let mut k_phi = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in i..n {
            let ka = stats::dot_f64(&buf.a1[i], &buf.a1[j]);
            let kh = stats::dot_f64(&buf.h[i], &buf.h[j]);
            let v = (ka * kh) as f32;
            k_phi.set(i, j, v);
            k_phi.set(j, i, v);
        }
    }
    // scale-aware ridge: λ * mean diagonal keeps conditioning stable
    let diag_mean: f32 =
        (0..n).map(|i| k_phi.at(i, i)).sum::<f32>() / n as f32;
    let ridge = (lambda * diag_mean.max(1e-12)).max(1e-10);
    for i in 0..n {
        k_phi.data[i * n + i] += ridge;
    }
    let alpha = linalg::cholesky_solve(&k_phi, &targets)?; // (n, r)

    // B[i] = sum_j alpha[j, i] * vec(a1_j h_j^T)  == A1^T diag(alpha_i) H
    let mut b = Tensor::zeros(&[r, (d + 1) * d]);
    for i in 0..r {
        let brow = &mut b.data[i * (d + 1) * d..(i + 1) * (d + 1) * d];
        for j in 0..n {
            let w = alpha.at(j, i);
            if w == 0.0 {
                continue;
            }
            let a1 = &buf.a1[j];
            let h = &buf.h[j];
            for p in 0..=d {
                // row p of vec([a1;_] h^T)
                let coef = w * a1[p];
                if coef == 0.0 {
                    continue;
                }
                let dst = &mut brow[p * d..(p + 1) * d];
                for (o, hv) in dst.iter_mut().zip(h) {
                    *o += coef * hv;
                }
            }
        }
    }

    // ---- 4. training-set relative error (diagnostic) -------------------
    let mut err_num = 0.0f64;
    let mut err_den = 0.0f64;
    {
        let tmp = Predictor {
            u: u.clone(),
            b: b.clone(),
            width: d,
            rank: r,
            fits: 0,
            version: 0,
        };
        for j in 0..n {
            let a_no_bias = &buf.a1[j][..d];
            let pred_g = tmp.predict_one_trunk(a_no_bias, &buf.h[j]);
            let g = &buf.grads[j];
            let mut num = 0.0f64;
            for p in 0..p_t {
                let dlt = (pred_g[p] - g[p]) as f64;
                num += dlt * dlt;
            }
            err_num += num;
            err_den += stats::dot_f64(g, g);
        }
    }

    pred.install(u, b);
    Ok(FitReport {
        n,
        rank: r,
        energy_captured: if total_energy > 0.0 { top_energy / total_energy } else { 0.0 },
        rel_error: (err_num / err_den.max(1e-30)).sqrt(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::rng::Pcg64;

    /// Same synthetic family as python/tests/test_predictor_fit.py:
    /// gradients exactly U* B* vec([a;1] h^T) with rank-r* structure.
    struct Synth {
        u_true: Tensor,   // (p_t, r) orthonormal-ish
        b_true: Tensor,   // (r, (d+1)*d)
        d: usize,
        p_t: usize,
    }

    impl Synth {
        fn new(rng: &mut Pcg64, p_t: usize, d: usize, r: usize) -> Synth {
            let mut u = Tensor::zeros(&[p_t, r]);
            rng.fill_normal(&mut u.data, (1.0 / p_t as f32).sqrt());
            let mut b = Tensor::zeros(&[r, (d + 1) * d]);
            rng.fill_normal(&mut b.data, 1.0);
            Synth { u_true: u, b_true: b, d, p_t }
        }

        fn sample(&self, rng: &mut Pcg64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let d = self.d;
            let mut a = vec![0.0f32; d];
            let mut h = vec![0.0f32; d];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut h, 1.0);
            let mut phi = vec![0.0f32; (d + 1) * d];
            for i in 0..d {
                for k in 0..d {
                    phi[i * d + k] = a[i] * h[k];
                }
            }
            phi[d * d..].copy_from_slice(&h);
            let c = matmul::matvec(&self.b_true, &phi);
            let g = matmul::matvec(&self.u_true, &c);
            debug_assert_eq!(g.len(), self.p_t);
            (g, a, h)
        }
    }

    #[test]
    fn fit_recovers_low_rank_family() {
        let mut rng = Pcg64::seeded(40);
        let (p_t, d, r) = (300usize, 6usize, 3usize);
        let synth = Synth::new(&mut rng, p_t, d, r);
        let mut buf = FitBuffer::new(64);
        for _ in 0..48 {
            let (g, a, h) = synth.sample(&mut rng);
            buf.push(g, a, h);
        }
        let mut pred = Predictor::new(p_t, d, r);
        let report = fit(&mut pred, &buf, 1e-7).unwrap();
        // Exactly rank-r data: top-r energy is everything.
        assert!(report.energy_captured > 0.999, "{report:?}");
        assert!(report.rel_error < 0.05, "{report:?}");
        // Held-out batch: predictor mean ≈ true mean gradient.
        let m = 12;
        let mut a_m = Tensor::zeros(&[m, d]);
        let mut h_m = Tensor::zeros(&[m, d]);
        let mut want = vec![0.0f32; p_t];
        for j in 0..m {
            let (g, a, h) = synth.sample(&mut rng);
            a_m.row_mut(j).copy_from_slice(&a);
            h_m.row_mut(j).copy_from_slice(&h);
            for (w, gv) in want.iter_mut().zip(&g) {
                *w += gv / m as f32;
            }
        }
        let got = pred.predict_mean_trunk(&a_m, &h_m);
        let cos = stats::cosine(&got, &want);
        assert!(cos > 0.99, "held-out cosine {cos}");
    }

    #[test]
    fn fit_needs_enough_samples() {
        let mut pred = Predictor::new(50, 4, 4);
        let buf = FitBuffer::new(16);
        assert!(fit(&mut pred, &buf, 1e-4).is_err());
    }

    #[test]
    fn fitted_u_columns_near_orthonormal() {
        let mut rng = Pcg64::seeded(41);
        let synth = Synth::new(&mut rng, 200, 5, 2);
        let mut buf = FitBuffer::new(32);
        for _ in 0..32 {
            let (g, a, h) = synth.sample(&mut rng);
            buf.push(g, a, h);
        }
        let mut pred = Predictor::new(200, 5, 2);
        fit(&mut pred, &buf, 1e-7).unwrap();
        let utu = matmul::matmul(&pred.u.t(), &pred.u);
        for i in 0..2 {
            for j in 0..2 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - want).abs() < 1e-2, "{:?}", utu.data);
            }
        }
    }

    #[test]
    fn energy_captured_partial_when_rank_deficient_model() {
        // Fit rank-1 predictor on rank-3 data: energy < 1, error > 0,
        // but it must not crash and must still install.
        let mut rng = Pcg64::seeded(42);
        let synth = Synth::new(&mut rng, 150, 5, 3);
        let mut buf = FitBuffer::new(32);
        for _ in 0..32 {
            let (g, a, h) = synth.sample(&mut rng);
            buf.push(g, a, h);
        }
        let mut pred = Predictor::new(150, 5, 1);
        let report = fit(&mut pred, &buf, 1e-6).unwrap();
        assert!(report.energy_captured < 0.999);
        assert!(report.rel_error > 0.01);
        assert_eq!(pred.fits, 1);
    }

    #[test]
    fn buffer_sliding_window() {
        let mut buf = FitBuffer::new(4);
        for i in 0..10 {
            buf.push(vec![i as f32; 3], vec![0.0; 2], vec![0.0; 2]);
        }
        assert_eq!(buf.len(), 4);
        assert_eq!(buf.grads[0][0], 6.0);
        assert_eq!(buf.a1[0].len(), 3); // bias appended
        buf.clear();
        assert!(buf.is_empty());
    }
}
