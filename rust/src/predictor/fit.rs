//! Predictor fitting: "a standard least squares technique" (paper Sec. 4),
//! made concrete and scalable:
//!
//! 1. **Basis U** — per-example trunk gradients G (n × P_T) are collected
//!    from the `per_example_grads` artifact; the rank-r left-singular
//!    basis of G^T comes from the n×n Gram eigendecomposition
//!    (P_T ≫ n makes a direct SVD infeasible): K = G G^T = V Λ V^T,
//!    U = G^T V_r Λ_r^{-1/2}.
//! 2. **Coefficients B** — kernel ridge regression in the dual. The
//!    bilinear feature Gram factorizes elementwise,
//!    K_Φ = (A1 A1^T) ⊙ (H H^T), so fitting costs O(n²(D+C)) instead of
//!    O(n² D²). α = (K_Φ + λI)^{-1} C with targets C = G U (free from the
//!    SVD), then B = Σ_j α_j ⊗ φ_j materialized as r rank-weighted
//!    A1^T diag(α_i) H products.
//!
//! Storage is allocation-conscious (ADR-003): [`FitBuffer`] keeps its
//! samples in flat contiguous ring storage (one slab per stream, sized
//! once), and [`fit_with_ws`] draws every large intermediate from the
//! caller's [`Workspace`] so repeat refits reuse the same slabs.
//!
//! The numpy mirror of this file is tested in
//! `python/tests/test_predictor_fit.py`; the Rust tests reuse the same
//! synthetic low-rank constructions.

use super::Predictor;
use crate::tensor::{backend, backend::Backend, linalg, stats, Tensor, Workspace};

/// Accumulates fit samples between refits in flat contiguous ring storage:
/// three slabs (gradients, biased activations, backprop features) of
/// `capacity` fixed-width rows each, with a sliding window implemented as
/// a ring head instead of `Vec::remove(0)` shifts. Row widths are fixed by
/// the first push after construction or [`clear`](FitBuffer::clear); the
/// slabs are sized once and every later push is two `memcpy`s — no
/// steady-state heap traffic.
pub struct FitBuffer {
    grads: Vec<f32>,
    a1: Vec<f32>,
    h: Vec<f32>,
    /// Physical slot of the oldest logical row.
    head: usize,
    len: usize,
    pub capacity: usize,
    /// Trunk-gradient row width P_T (0 until the first push).
    p_t: usize,
    /// Feature width D; `a1` rows carry D+1 (bias appended at push).
    d: usize,
}

impl FitBuffer {
    pub fn new(capacity: usize) -> FitBuffer {
        assert!(capacity >= 1, "FitBuffer capacity must be >= 1");
        FitBuffer {
            grads: Vec::new(),
            a1: Vec::new(),
            h: Vec::new(),
            head: 0,
            len: 0,
            capacity,
            p_t: 0,
            d: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.capacity
    }

    /// Drop all rows. Slab storage (and its capacity) is retained, so the
    /// next fill cycle allocates nothing unless the row widths change.
    pub fn clear(&mut self) {
        self.len = 0;
        self.head = 0;
    }

    /// Push one example (drops oldest beyond capacity — sliding window).
    /// The bias coordinate is appended to `a` at collection time. Inputs
    /// are copied into the ring; the caller keeps ownership.
    pub fn push(&mut self, grad: &[f32], a: &[f32], h: &[f32]) {
        if self.len == 0 {
            // (Re)establish row widths and slab sizes — but only when the
            // widths actually changed: re-zeroing capacity × P_T floats on
            // every post-clear() refill would memset tens of MB per refit
            // for nothing (every slot is overwritten by copy_from_slice).
            let (p_t, d) = (grad.len(), h.len());
            if p_t != self.p_t
                || d != self.d
                || self.grads.len() != self.capacity * p_t
                || self.a1.len() != self.capacity * (d + 1)
            {
                self.p_t = p_t;
                self.d = d;
                self.grads.clear();
                self.grads.resize(self.capacity * p_t, 0.0);
                self.a1.clear();
                self.a1.resize(self.capacity * (d + 1), 0.0);
                self.h.clear();
                self.h.resize(self.capacity * d, 0.0);
            }
            self.head = 0;
        }
        assert_eq!(grad.len(), self.p_t, "gradient row width changed mid-fill");
        assert_eq!(a.len(), self.d, "activation row width changed mid-fill");
        assert_eq!(h.len(), self.d, "feature row width changed mid-fill");
        let slot = if self.len < self.capacity {
            let s = (self.head + self.len) % self.capacity;
            self.len += 1;
            s
        } else {
            let s = self.head;
            self.head = (self.head + 1) % self.capacity;
            s
        };
        self.grads[slot * self.p_t..(slot + 1) * self.p_t].copy_from_slice(grad);
        let a1w = self.d + 1;
        self.a1[slot * a1w..slot * a1w + self.d].copy_from_slice(a);
        self.a1[slot * a1w + self.d] = 1.0;
        self.h[slot * self.d..(slot + 1) * self.d].copy_from_slice(h);
    }

    #[inline]
    fn slot(&self, i: usize) -> usize {
        debug_assert!(i < self.len, "row {i} out of range (len {})", self.len);
        (self.head + i) % self.capacity
    }

    /// Trunk-gradient row `i` (0 = oldest).
    pub fn grad(&self, i: usize) -> &[f32] {
        let s = self.slot(i);
        &self.grads[s * self.p_t..(s + 1) * self.p_t]
    }

    /// Biased activation row `[a; 1]` for sample `i`.
    pub fn a1(&self, i: usize) -> &[f32] {
        let w = self.d + 1;
        let s = self.slot(i);
        &self.a1[s * w..(s + 1) * w]
    }

    /// Backprop-feature row `h = W_a^T r` for sample `i`.
    pub fn h(&self, i: usize) -> &[f32] {
        let s = self.slot(i);
        &self.h[s * self.d..(s + 1) * self.d]
    }
}

/// Outcome diagnostics of one fit.
#[derive(Clone, Copy, Debug)]
pub struct FitReport {
    pub n: usize,
    pub rank: usize,
    /// Fraction of gradient energy captured by the top-r subspace —
    /// the empirical check of the paper's low-effective-rank claim.
    pub energy_captured: f64,
    /// Training-set relative prediction error of the fitted predictor.
    pub rel_error: f64,
}

/// Fit (U, B) from the buffer and install into `pred`, using the active
/// tensor backend for the dense reductions.
pub fn fit(pred: &mut Predictor, buf: &FitBuffer, lambda: f32) -> anyhow::Result<FitReport> {
    fit_with(backend::active(), pred, buf, lambda)
}

/// [`fit`] with an explicit tensor backend (equivalence tests pin each
/// one). Cold-path convenience over [`fit_with_ws`].
pub fn fit_with(
    be: Backend,
    pred: &mut Predictor,
    buf: &FitBuffer,
    lambda: f32,
) -> anyhow::Result<FitReport> {
    let mut ws = Workspace::new();
    fit_with_ws(be, pred, buf, lambda, &mut ws)
}

/// Step 1 of the fit, shared with estimators that learn their own
/// coefficient map over the same basis (ADR-006): the rank-r Gram-trick
/// basis of the buffered gradients. Returns U in *transposed* layout —
/// an (r, p_t) tensor whose row c is column c of U (contiguous, so
/// projections are plain dots) — plus the captured-energy fraction.
/// The tensor is drawn from `ws`; the caller must `give_tensor` it back.
pub fn gram_basis(
    be: Backend,
    buf: &FitBuffer,
    r: usize,
    ws: &mut Workspace,
) -> anyhow::Result<(Tensor, f64)> {
    let n = buf.len();
    anyhow::ensure!(n >= 2 * r, "need at least 2r = {} fit samples, have {n}", 2 * r);
    let p_t = buf.grad(0).len();

    // K = G G^T (n, n). f32 unrolled dot via the backend: at P_T ~
    // 10^5..10^7 the relative error is ~1e-5·sqrt(P_T) of norm — far below
    // the fit's own noise — and 5-10x faster than the f64 path (perf pass,
    // EXPERIMENTS.md).
    let mut k = ws.take_tensor(&[n, n]);
    for i in 0..n {
        let gi = buf.grad(i);
        for j in i..n {
            let dot = be.dot(gi, buf.grad(j));
            k.set(i, j, dot);
            k.set(j, i, dot);
        }
    }
    let (evals, evecs) = linalg::eigh_jacobi(&k); // ascending
    ws.give_tensor(k);
    let total_energy: f64 = evals.iter().map(|&e| e.max(0.0) as f64).sum();
    let top_energy: f64 = evals
        .iter()
        .rev()
        .take(r)
        .map(|&e| e.max(0.0) as f64)
        .sum();

    // U = G^T V_r Λ_r^{-1/2}, columns ordered by decreasing eigenvalue.
    // Built column-major first (contiguous axpy per sample), transposed
    // into the row-major U at the end — 10x over the strided write loop.
    let mut scaled_v = ws.take_tensor(&[n, r]); // V_r Λ^{-1/2}
    for c in 0..r {
        let src = n - 1 - c; // descending order
        let lam = evals[src].max(1e-12);
        let inv_sqrt = 1.0 / lam.sqrt();
        for row in 0..n {
            scaled_v.set(row, c, evecs.at(row, src) * inv_sqrt);
        }
    }
    let mut u_cols = ws.take_tensor(&[r, p_t]); // column c is row c here
    for c in 0..r {
        let col = &mut u_cols.data[c * p_t..(c + 1) * p_t];
        for j in 0..n {
            let w = scaled_v.at(j, c);
            if w == 0.0 {
                continue;
            }
            let g = buf.grad(j);
            for (o, gv) in col.iter_mut().zip(g) {
                *o += w * gv;
            }
        }
    }
    ws.give_tensor(scaled_v);
    let energy = if total_energy > 0.0 { top_energy / total_energy } else { 0.0 };
    Ok((u_cols, energy))
}

/// [`fit_with`] drawing every large intermediate (the two n×n Grams, the
/// scaled eigenvector block, the U column build, the ridge targets) from
/// the caller's [`Workspace`] — the coordinator threads one long-lived
/// arena through here so repeat refits reuse the same slabs (ADR-003).
pub fn fit_with_ws(
    be: Backend,
    pred: &mut Predictor,
    buf: &FitBuffer,
    lambda: f32,
    ws: &mut Workspace,
) -> anyhow::Result<FitReport> {
    let r = pred.rank;
    let d = pred.width;

    // ---- 1. basis U via the Gram trick --------------------------------
    let (u_cols, energy_captured) = gram_basis(be, buf, r, ws)?;
    let n = buf.len();
    let p_t = buf.grad(0).len();

    // ---- 2. targets C = G U  (contiguous f32 dots over u_cols) ---------
    let mut targets = ws.take_tensor(&[n, r]);
    for j in 0..n {
        let g = buf.grad(j);
        for c in 0..r {
            targets.set(j, c, be.dot(g, &u_cols.data[c * p_t..(c + 1) * p_t]));
        }
    }
    let u = u_cols.t(); // (p_t, r) row-major, owned by the predictor
    ws.give_tensor(u_cols);

    // ---- 3. dual kernel ridge for B ------------------------------------
    // K_phi = (A1 A1^T) o (H H^T) + lambda I
    let mut k_phi = ws.take_tensor(&[n, n]);
    for i in 0..n {
        let ai = buf.a1(i);
        let hi = buf.h(i);
        for j in i..n {
            let ka = stats::dot_f64(ai, buf.a1(j));
            let kh = stats::dot_f64(hi, buf.h(j));
            let v = (ka * kh) as f32;
            k_phi.set(i, j, v);
            k_phi.set(j, i, v);
        }
    }
    // scale-aware ridge: λ * mean diagonal keeps conditioning stable
    let diag_mean: f32 =
        (0..n).map(|i| k_phi.at(i, i)).sum::<f32>() / n as f32;
    let ridge = (lambda * diag_mean.max(1e-12)).max(1e-10);
    for i in 0..n {
        k_phi.data[i * n + i] += ridge;
    }
    let alpha = linalg::cholesky_solve(&k_phi, &targets)?; // (n, r)
    ws.give_tensor(k_phi);
    ws.give_tensor(targets);

    // B[i] = sum_j alpha[j, i] * vec(a1_j h_j^T)  == A1^T diag(alpha_i) H
    let mut b = Tensor::zeros(&[r, (d + 1) * d]);
    for i in 0..r {
        let brow = &mut b.data[i * (d + 1) * d..(i + 1) * (d + 1) * d];
        for j in 0..n {
            let w = alpha.at(j, i);
            if w == 0.0 {
                continue;
            }
            let a1 = buf.a1(j);
            let h = buf.h(j);
            for p in 0..=d {
                // row p of vec([a1;_] h^T)
                let coef = w * a1[p];
                if coef == 0.0 {
                    continue;
                }
                let dst = &mut brow[p * d..(p + 1) * d];
                for (o, hv) in dst.iter_mut().zip(h) {
                    *o += coef * hv;
                }
            }
        }
    }

    // ---- 4. training-set relative error (diagnostic) -------------------
    // Evaluated through a temporary predictor that *owns* (U, B) and hands
    // them to `install` afterwards — no defensive clones of the two
    // largest tensors in the system.
    let mut err_num = 0.0f64;
    let mut err_den = 0.0f64;
    let tmp = Predictor {
        u,
        b,
        width: d,
        rank: r,
        fits: 0,
        version: 0,
    };
    for j in 0..n {
        let a_no_bias = &buf.a1(j)[..d];
        let pred_g = tmp.predict_one_trunk(a_no_bias, buf.h(j));
        let g = buf.grad(j);
        let mut num = 0.0f64;
        for p in 0..p_t {
            let dlt = (pred_g[p] - g[p]) as f64;
            num += dlt * dlt;
        }
        err_num += num;
        err_den += stats::dot_f64(g, g);
    }

    pred.install(tmp.u, tmp.b);
    Ok(FitReport {
        n,
        rank: r,
        energy_captured,
        rel_error: (err_num / err_den.max(1e-30)).sqrt(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::rng::Pcg64;

    /// Same synthetic family as python/tests/test_predictor_fit.py:
    /// gradients exactly U* B* vec([a;1] h^T) with rank-r* structure.
    struct Synth {
        u_true: Tensor,   // (p_t, r) orthonormal-ish
        b_true: Tensor,   // (r, (d+1)*d)
        d: usize,
        p_t: usize,
    }

    impl Synth {
        fn new(rng: &mut Pcg64, p_t: usize, d: usize, r: usize) -> Synth {
            let mut u = Tensor::zeros(&[p_t, r]);
            rng.fill_normal(&mut u.data, (1.0 / p_t as f32).sqrt());
            let mut b = Tensor::zeros(&[r, (d + 1) * d]);
            rng.fill_normal(&mut b.data, 1.0);
            Synth { u_true: u, b_true: b, d, p_t }
        }

        fn sample(&self, rng: &mut Pcg64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let d = self.d;
            let mut a = vec![0.0f32; d];
            let mut h = vec![0.0f32; d];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut h, 1.0);
            let mut phi = vec![0.0f32; (d + 1) * d];
            for i in 0..d {
                for k in 0..d {
                    phi[i * d + k] = a[i] * h[k];
                }
            }
            phi[d * d..].copy_from_slice(&h);
            let c = matmul::matvec(&self.b_true, &phi);
            let g = matmul::matvec(&self.u_true, &c);
            debug_assert_eq!(g.len(), self.p_t);
            (g, a, h)
        }
    }

    #[test]
    fn fit_recovers_low_rank_family() {
        let mut rng = Pcg64::seeded(40);
        let (p_t, d, r) = (300usize, 6usize, 3usize);
        let synth = Synth::new(&mut rng, p_t, d, r);
        let mut buf = FitBuffer::new(64);
        for _ in 0..48 {
            let (g, a, h) = synth.sample(&mut rng);
            buf.push(&g, &a, &h);
        }
        let mut pred = Predictor::new(p_t, d, r);
        let report = fit(&mut pred, &buf, 1e-7).unwrap();
        // Exactly rank-r data: top-r energy is everything.
        assert!(report.energy_captured > 0.999, "{report:?}");
        assert!(report.rel_error < 0.05, "{report:?}");
        // Held-out batch: predictor mean ≈ true mean gradient.
        let m = 12;
        let mut a_m = Tensor::zeros(&[m, d]);
        let mut h_m = Tensor::zeros(&[m, d]);
        let mut want = vec![0.0f32; p_t];
        for j in 0..m {
            let (g, a, h) = synth.sample(&mut rng);
            a_m.row_mut(j).copy_from_slice(&a);
            h_m.row_mut(j).copy_from_slice(&h);
            for (w, gv) in want.iter_mut().zip(&g) {
                *w += gv / m as f32;
            }
        }
        let got = pred.predict_mean_trunk(&a_m, &h_m);
        let cos = stats::cosine(&got, &want);
        assert!(cos > 0.99, "held-out cosine {cos}");
    }

    #[test]
    fn fit_needs_enough_samples() {
        let mut pred = Predictor::new(50, 4, 4);
        let buf = FitBuffer::new(16);
        assert!(fit(&mut pred, &buf, 1e-4).is_err());
    }

    #[test]
    fn repeat_fits_reuse_workspace_slabs() {
        let mut rng = Pcg64::seeded(44);
        let synth = Synth::new(&mut rng, 120, 5, 2);
        let mut buf = FitBuffer::new(24);
        for _ in 0..24 {
            let (g, a, h) = synth.sample(&mut rng);
            buf.push(&g, &a, &h);
        }
        let mut pred = Predictor::new(120, 5, 2);
        let mut ws = Workspace::new();
        fit_with_ws(Backend::blocked(), &mut pred, &buf, 1e-7, &mut ws).unwrap();
        let warm_misses = ws.misses();
        for _ in 0..2 {
            fit_with_ws(Backend::blocked(), &mut pred, &buf, 1e-7, &mut ws).unwrap();
        }
        assert_eq!(ws.misses(), warm_misses, "repeat refits must reuse slabs");
        assert_eq!(pred.fits, 3);
    }

    #[test]
    fn fitted_u_columns_near_orthonormal() {
        let mut rng = Pcg64::seeded(41);
        let synth = Synth::new(&mut rng, 200, 5, 2);
        let mut buf = FitBuffer::new(32);
        for _ in 0..32 {
            let (g, a, h) = synth.sample(&mut rng);
            buf.push(&g, &a, &h);
        }
        let mut pred = Predictor::new(200, 5, 2);
        fit(&mut pred, &buf, 1e-7).unwrap();
        let utu = matmul::matmul(&pred.u.t(), &pred.u);
        for i in 0..2 {
            for j in 0..2 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - want).abs() < 1e-2, "{:?}", utu.data);
            }
        }
    }

    #[test]
    fn energy_captured_partial_when_rank_deficient_model() {
        // Fit rank-1 predictor on rank-3 data: energy < 1, error > 0,
        // but it must not crash and must still install.
        let mut rng = Pcg64::seeded(42);
        let synth = Synth::new(&mut rng, 150, 5, 3);
        let mut buf = FitBuffer::new(32);
        for _ in 0..32 {
            let (g, a, h) = synth.sample(&mut rng);
            buf.push(&g, &a, &h);
        }
        let mut pred = Predictor::new(150, 5, 1);
        let report = fit(&mut pred, &buf, 1e-6).unwrap();
        assert!(report.energy_captured < 0.999);
        assert!(report.rel_error > 0.01);
        assert_eq!(pred.fits, 1);
    }

    #[test]
    fn buffer_sliding_window() {
        let mut buf = FitBuffer::new(4);
        for i in 0..10 {
            buf.push(&[i as f32; 3], &[0.0; 2], &[0.0; 2]);
        }
        assert_eq!(buf.len(), 4);
        assert_eq!(buf.grad(0)[0], 6.0);
        assert_eq!(buf.grad(3)[0], 9.0);
        assert_eq!(buf.a1(0).len(), 3); // bias appended
        assert_eq!(buf.a1(0)[2], 1.0);
        buf.clear();
        assert!(buf.is_empty());
        // Widths may change after clear (slabs are re-established).
        buf.push(&[1.0; 5], &[0.0; 3], &[0.0; 3]);
        assert_eq!(buf.grad(0).len(), 5);
        assert_eq!(buf.a1(0).len(), 4);
    }

    #[test]
    fn buffer_ring_order_is_oldest_first() {
        let mut buf = FitBuffer::new(3);
        for i in 0..5 {
            buf.push(&[i as f32], &[0.0], &[0.0]);
        }
        // rows 2, 3, 4 survive, oldest first
        assert_eq!(buf.grad(0), &[2.0]);
        assert_eq!(buf.grad(1), &[3.0]);
        assert_eq!(buf.grad(2), &[4.0]);
        assert!(buf.is_full());
    }
}
