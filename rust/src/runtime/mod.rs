//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client via the `xla` crate.
//!
//! Design (see /opt/xla-example/load_hlo for the pattern this adapts):
//! - one `PjRtLoadedExecutable` per artifact, compiled on first use and
//!   cached for the life of the runtime;
//! - parameters are uploaded once per optimizer step as device buffers and
//!   shared by every micro-batch call inside the step (`DeviceParams`);
//! - predictor state (U, B) is uploaded once per refit (`DevicePredictor`),
//!   keyed by the predictor's version counter;
//! - all entry points return plain host `Vec<f32>`s — the coordinator owns
//!   scheduling, the runtime owns marshalling.
//!
//! Thread-safety (ADR-004): the sharded executor calls every entry point
//! from worker threads against one shared `&Runtime`, so the executable
//! cache is `Mutex<BTreeMap<_, Arc<_>>>` (locked only for the cache probe,
//! never across an execute) and the stats are mutex-guarded. The vendored
//! `xla` stub's handle types are plain `Send + Sync` structs; the real
//! PJRT binding's buffer/executable handles wrap thread-safe C API objects
//! the same way — revisit the `Send`/`Sync` bounds if a future binding
//! says otherwise.

use crate::model::manifest::Manifest;
use crate::model::params::ParamStore;
use crate::predictor::Predictor;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Outputs of the `train_grads` entry point (Forward + Backward).
pub struct TrainOut {
    pub loss: f32,
    pub g_trunk: Vec<f32>,
    pub g_head_w: Vec<f32>,
    pub g_head_b: Vec<f32>,
    /// Last-hidden-layer activations a(x), (m, D) row-major.
    pub a: Vec<f32>,
    /// Softmax probabilities, (m, C) row-major.
    pub probs: Vec<f32>,
}

/// Outputs of `predict_grad` (PredictGrad on one micro-batch).
pub struct PredictOut {
    pub g_trunk: Vec<f32>,
    pub g_head_w: Vec<f32>,
    pub g_head_b: Vec<f32>,
}

/// Device-resident parameter buffers, valid for one parameter version.
pub struct DeviceParams {
    trunk: xla::PjRtBuffer,
    head_w: xla::PjRtBuffer,
    head_b: xla::PjRtBuffer,
}

/// Device-resident predictor state (U, B), keyed by predictor version.
pub struct DevicePredictor {
    b: xla::PjRtBuffer,
    u: xla::PjRtBuffer,
    pub version: u64,
}

pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: Mutex<BTreeMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative marshalling/compute timers for the perf report
    /// (mutex-guarded: worker threads report concurrently).
    pub stats: Mutex<RuntimeStats>,
}

#[derive(Default, Debug, Clone)]
pub struct RuntimeStats {
    pub calls: u64,
    pub exec_secs: f64,
    pub upload_secs: f64,
    pub download_secs: f64,
    pub compile_secs: f64,
    /// Per-artifact (calls, exec seconds) — the perf-pass breakdown.
    pub per_artifact: BTreeMap<String, (u64, f64)>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn load(dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        crate::log_info!(
            "runtime: platform={} preset={} trunk_params={}",
            client.platform_name(),
            manifest.preset,
            manifest.trunk_params
        );
        Ok(Runtime {
            client,
            manifest,
            exes: Mutex::new(BTreeMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    /// Compile (or fetch cached) an executable by artifact name. The cache
    /// lock is held only for the probe/insert; compilation runs unlocked,
    /// so two shards racing on a cold artifact may both compile it — the
    /// second insert wins and the duplicate is dropped (compiles are
    /// warmup-path anyway; the trainer pre-compiles before scattering).
    pub fn exe(&self, name: &str) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self.manifest.artifact(name)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&meta.file)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {:?}: {e:?}", meta.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling artifact {name}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.stats.lock().unwrap().compile_secs += dt;
        crate::log_debug!("compiled {name} in {dt:.2}s");
        let rc = Arc::new(exe);
        let mut exes = self.exes.lock().unwrap();
        let entry = exes.entry(name.to_string()).or_insert(rc);
        Ok(entry.clone())
    }

    /// Pre-compile every artifact the run will need (avoids first-use
    /// stalls inside the wall-clock-budgeted loop).
    pub fn warmup(&self, names: &[String]) -> anyhow::Result<()> {
        for n in names {
            self.exe(n)?;
        }
        Ok(())
    }

    // ---- marshalling ----------------------------------------------------

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        let t0 = std::time::Instant::now();
        let b = self
            .client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow::anyhow!("uploading f32 buffer {dims:?}: {e:?}"))?;
        self.stats.lock().unwrap().upload_secs += t0.elapsed().as_secs_f64();
        Ok(b)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        let t0 = std::time::Instant::now();
        let b = self
            .client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(|e| anyhow::anyhow!("uploading i32 buffer {dims:?}: {e:?}"))?;
        self.stats.lock().unwrap().upload_secs += t0.elapsed().as_secs_f64();
        Ok(b)
    }

    /// Upload the current parameters (once per optimizer step).
    pub fn upload_params(&self, p: &ParamStore) -> anyhow::Result<DeviceParams> {
        Ok(DeviceParams {
            trunk: self.upload_f32(&p.trunk, &[p.trunk.len()])?,
            head_w: self.upload_f32(&p.head_w, &[p.width, p.classes])?,
            head_b: self.upload_f32(&p.head_b, &[p.classes])?,
        })
    }

    /// Upload predictor state if the cached version is stale.
    pub fn upload_predictor(
        &self,
        pred: &Predictor,
        cached: Option<DevicePredictor>,
    ) -> anyhow::Result<DevicePredictor> {
        if let Some(c) = cached {
            if c.version == pred.version {
                return Ok(c);
            }
        }
        Ok(DevicePredictor {
            b: self.upload_f32(&pred.b.data, &pred.b.shape)?,
            u: self.upload_f32(&pred.u.data, &pred.u.shape)?,
            version: pred.version,
        })
    }

    /// Execute an artifact with device-buffer args and decompose the tuple
    /// output into per-output f32 vectors (in manifest order).
    fn run(&self, name: &str, args: &[&xla::PjRtBuffer]) -> anyhow::Result<Vec<Vec<f32>>> {
        let exe = self.exe(name)?;
        let meta = self.manifest.artifact(name)?;
        anyhow::ensure!(
            args.len() == meta.args.len(),
            "artifact {name} takes {} args, got {}",
            meta.args.len(),
            args.len()
        );
        let t0 = std::time::Instant::now();
        let results = exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let exec_dt = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let lit = results[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} output: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing {name} output tuple: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == meta.outs.len(),
            "artifact {name} returned {} outputs, manifest says {}",
            parts.len(),
            meta.outs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (part, (oname, shape, _)) in parts.iter().zip(&meta.outs) {
            let v = part
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("reading output {oname} of {name}: {e:?}"))?;
            let want: usize = shape.iter().product();
            anyhow::ensure!(
                v.len() == want.max(1),
                "output {oname} of {name}: got {} values, want {}",
                v.len(),
                want.max(1)
            );
            out.push(v);
        }
        let mut st = self.stats.lock().unwrap();
        st.calls += 1;
        st.exec_secs += exec_dt;
        st.download_secs += t1.elapsed().as_secs_f64();
        let e = st.per_artifact.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += exec_dt;
        Ok(out)
    }

    // ---- typed entry points ----------------------------------------------

    /// Forward + Backward on a batch of `m` examples.
    pub fn train_grads(
        &self,
        params: &DeviceParams,
        x: &[f32],
        y: &[i32],
        m: usize,
    ) -> anyhow::Result<TrainOut> {
        let name = self.manifest.train_grads_name(m);
        let img = self.manifest.image;
        let xb = self.upload_f32(x, &[m, 3, img, img])?;
        let yb = self.upload_i32(y, &[m])?;
        let mut outs =
            self.run(&name, &[&params.trunk, &params.head_w, &params.head_b, &xb, &yb])?;
        // outs: loss, g_trunk, g_head_w, g_head_b, a, probs
        let probs = outs.pop().unwrap();
        let a = outs.pop().unwrap();
        let g_head_b = outs.pop().unwrap();
        let g_head_w = outs.pop().unwrap();
        let g_trunk = outs.pop().unwrap();
        let loss = outs.pop().unwrap()[0];
        Ok(TrainOut { loss, g_trunk, g_head_w, g_head_b, a, probs })
    }

    /// CheapForward: activations + probabilities, no autodiff cache.
    pub fn cheap_fwd(
        &self,
        params: &DeviceParams,
        x: &[f32],
        m: usize,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let name = self.manifest.cheap_fwd_name(m);
        let img = self.manifest.image;
        let xb = self.upload_f32(x, &[m, 3, img, img])?;
        let mut outs = self.run(&name, &[&params.trunk, &params.head_w, &params.head_b, &xb])?;
        let probs = outs.pop().unwrap();
        let a = outs.pop().unwrap();
        Ok((a, probs))
    }

    /// PredictGrad on a micro-batch via the pallas predictor kernels.
    pub fn predict_grad(
        &self,
        a: &[f32],
        probs: &[f32],
        y: &[i32],
        params: &DeviceParams,
        dev_pred: &DevicePredictor,
        m: usize,
    ) -> anyhow::Result<PredictOut> {
        let name = self.manifest.predict_grad_name(m);
        let d = self.manifest.width;
        let c = self.manifest.classes;
        let ab = self.upload_f32(a, &[m, d])?;
        let pb = self.upload_f32(probs, &[m, c])?;
        let yb = self.upload_i32(y, &[m])?;
        let mut outs =
            self.run(&name, &[&ab, &pb, &yb, &params.head_w, &dev_pred.b, &dev_pred.u])?;
        let g_head_b = outs.pop().unwrap();
        let g_head_w = outs.pop().unwrap();
        let g_trunk = outs.pop().unwrap();
        Ok(PredictOut { g_trunk, g_head_w, g_head_b })
    }

    /// Per-example trunk gradients for predictor fitting / diagnostics.
    /// Returns (G as n rows, a, probs).
    pub fn per_example_grads(
        &self,
        params: &DeviceParams,
        x: &[f32],
        y: &[i32],
    ) -> anyhow::Result<(Vec<Vec<f32>>, Vec<f32>, Vec<f32>)> {
        let n = self.manifest.n_chunk;
        anyhow::ensure!(y.len() == n, "per_example_grads takes exactly n_chunk={n} examples");
        let name = self.manifest.per_example_grads_name();
        let img = self.manifest.image;
        let xb = self.upload_f32(x, &[n, 3, img, img])?;
        let yb = self.upload_i32(y, &[n])?;
        let mut outs =
            self.run(&name, &[&params.trunk, &params.head_w, &params.head_b, &xb, &yb])?;
        let probs = outs.pop().unwrap();
        let a = outs.pop().unwrap();
        let g_flat = outs.pop().unwrap();
        let p_t = self.manifest.trunk_params;
        let rows = g_flat.chunks(p_t).map(|c| c.to_vec()).collect();
        Ok((rows, a, probs))
    }

    /// Control-variate combine (eq. 1) on device over the full flat
    /// gradient [trunk | head_w | head_b].
    pub fn cv_combine(
        &self,
        g_ct: &[f32],
        g_cp: &[f32],
        g_p: &[f32],
        f: f32,
    ) -> anyhow::Result<Vec<f32>> {
        let p = self.manifest.total_params;
        anyhow::ensure!(g_ct.len() == p && g_cp.len() == p && g_p.len() == p);
        let a = self.upload_f32(g_ct, &[p])?;
        let b = self.upload_f32(g_cp, &[p])?;
        let c = self.upload_f32(g_p, &[p])?;
        let fb = self.upload_f32(&[f], &[1])?;
        let mut outs = self.run("cv_combine", &[&a, &b, &c, &fb])?;
        Ok(outs.pop().unwrap())
    }

    pub fn stats_snapshot(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }
}
