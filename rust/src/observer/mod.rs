//! Training observers (DESIGN.md ADR-005): an event-sink seam between
//! the training loop and everything that wants to watch it.
//!
//! The session (`crate::session::TrainSession`) narrates its run through
//! [`TrainObserver`] callbacks — one per optimizer step, evaluation, and
//! predictor refit, plus a final summary — instead of hard-wiring a CSV
//! writer and ad-hoc printlns into the loop. Observers are owned by the
//! session (`SessionBuilder::observer`), called serially in registration
//! order, and may fail: an observer error aborts the run like any other
//! I/O error (a half-written metrics file is a broken experiment).
//!
//! Shipped sinks:
//! - [`CsvObserver`] — the Figure-1 CSV series (one row per step, the
//!   exact format the old `Trainer::train(Some(csv))` produced);
//! - [`JsonlObserver`] — one JSON object per event
//!   (step/eval/refit/checkpoint/end), NaN-safe (`null`), for
//!   programmatic consumers; the per-event line formats are exposed as
//!   [`step_line`] & co. and reused verbatim by the serve control
//!   plane's event stream (DESIGN.md ADR-009);
//! - [`Multicast`] — composes any number of observers into one.
//!
//! Custom observers implement whichever callbacks they need — every
//! method defaults to a no-op. See `examples/alignment_study.rs` for an
//! observer that captures refit diagnostics into shared state.

use crate::metrics::{Alignment, LogRow};
use crate::predictor::fit::FitReport;
use crate::util::CsvWriter;
use std::io::Write;
use std::path::{Path, PathBuf};

/// One predictor refit, as seen by observers.
#[derive(Clone, Copy, Debug)]
pub struct RefitEvent {
    /// Optimizer updates completed when the refit ran.
    pub step: usize,
    /// Fit diagnostics (sample count, rank, energy captured, rel. error).
    pub report: FitReport,
    /// Alignment snapshot (ρ̂, κ̂) measured with the freshly fitted
    /// predictor, when tracking is enabled.
    pub alignment: Option<Alignment>,
    /// Control fraction in effect after the refit (the adaptive
    /// controller may have just retuned it).
    pub f: f64,
}

/// One durable checkpoint write (DESIGN.md ADR-008), emitted after the
/// artifact has been atomically renamed into place.
#[derive(Clone, Debug)]
pub struct CheckpointEvent {
    /// Optimizer updates captured by the artifact (resume continues at
    /// `step + 1`).
    pub step: usize,
    /// Final artifact path (`ckpt-XXXXXXXX.lgpckpt`).
    pub path: PathBuf,
    /// Encoded artifact size in bytes.
    pub bytes: usize,
    /// Wall-clock seconds spent encoding + writing + fsyncing.
    pub write_secs: f64,
}

/// A distributed-runner lifecycle event (DESIGN.md ADR-010): process
/// group membership and coordinated-shutdown transitions, stamped with
/// this process's rank so per-process JSONL streams can be correlated.
#[derive(Clone, Debug)]
pub struct DistEvent {
    /// Optimizer updates completed when the event fired.
    pub step: usize,
    /// This process's rank (0 = leader).
    pub rank: usize,
    /// Total processes in the group.
    pub procs: usize,
    pub kind: DistEventKind,
    /// Human-readable context (peer rank, shutdown reason, ...).
    pub detail: String,
}

/// What happened to the process group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistEventKind {
    /// Handshake complete: this process is attached to the group.
    Joined,
    /// A peer died or desynchronized mid-run.
    PeerLost,
    /// Coordinated shutdown (leader broadcast, or follower received).
    Shutdown,
}

impl DistEventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            DistEventKind::Joined => "joined",
            DistEventKind::PeerLost => "peer_lost",
            DistEventKind::Shutdown => "shutdown",
        }
    }
}

/// End-of-run summary, emitted exactly once.
#[derive(Clone, Copy, Debug)]
pub struct RunSummary {
    pub steps: usize,
    pub final_val_acc: f64,
    pub examples_seen: usize,
    /// Analytic compute units consumed (paper cost model).
    pub cost_units: f64,
    pub wall_secs: f64,
}

/// Event sink for a training run. All methods default to no-ops so an
/// implementation only writes the callbacks it cares about.
pub trait TrainObserver: Send {
    /// After every optimizer update, with the full log row (val_acc is
    /// NaN on non-eval steps).
    fn on_step(&mut self, row: &LogRow) -> anyhow::Result<()> {
        let _ = row;
        Ok(())
    }

    /// After each validation evaluation (periodic and final).
    fn on_eval(&mut self, step: usize, val_acc: f64) -> anyhow::Result<()> {
        let _ = (step, val_acc);
        Ok(())
    }

    /// After each predictor refit.
    fn on_refit(&mut self, ev: &RefitEvent) -> anyhow::Result<()> {
        let _ = ev;
        Ok(())
    }

    /// After each durable checkpoint write (ADR-008).
    fn on_checkpoint(&mut self, ev: &CheckpointEvent) -> anyhow::Result<()> {
        let _ = ev;
        Ok(())
    }

    /// On each distributed-runner lifecycle transition (ADR-010):
    /// join, peer loss, coordinated shutdown. Never fires in
    /// single-process runs.
    fn on_dist(&mut self, ev: &DistEvent) -> anyhow::Result<()> {
        let _ = ev;
        Ok(())
    }

    /// Once, when the run completes.
    fn on_end(&mut self, summary: &RunSummary) -> anyhow::Result<()> {
        let _ = summary;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// CsvObserver
// ---------------------------------------------------------------------------

/// Streams every step row to a CSV file with the [`LogRow::HEADER`]
/// schema — the Figure-1 series format.
pub struct CsvObserver {
    w: CsvWriter,
}

impl CsvObserver {
    pub fn create(path: &Path) -> anyhow::Result<CsvObserver> {
        Ok(CsvObserver { w: CsvWriter::create(path, &LogRow::HEADER)? })
    }
}

impl TrainObserver for CsvObserver {
    fn on_step(&mut self, row: &LogRow) -> anyhow::Result<()> {
        self.w.row(&row.values())
    }
}

// ---------------------------------------------------------------------------
// JsonlObserver
// ---------------------------------------------------------------------------

/// Streams one JSON object per event to a `.jsonl` file. Non-finite
/// numbers (the NaN val_acc of non-eval steps) are written as `null`,
/// keeping every line standard-JSON parseable.
pub struct JsonlObserver {
    file: std::fs::File,
}

impl JsonlObserver {
    pub fn create(path: &Path) -> anyhow::Result<JsonlObserver> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(JsonlObserver { file: std::fs::File::create(path)? })
    }
}

/// JSON number or `null` for non-finite values.
fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

// One function per event kind, shared by [`JsonlObserver`] (file sink)
// and the serve control plane's event stream (`crate::serve`, DESIGN.md
// ADR-009) — a single source of truth so the on-disk format and the wire
// format cannot drift. Each returns one JSON object with no trailing
// newline; every emitted number is finite or `null`.

/// `"event":"step"` line for one optimizer update.
pub fn step_line(row: &LogRow) -> String {
    format!(
        r#"{{"event":"step","step":{},"wall_secs":{},"loss":{},"train_acc":{},"val_acc":{},"rho":{},"kappa":{},"phi":{},"examples_seen":{}}}"#,
        row.step,
        jnum(row.wall_secs),
        jnum(row.loss),
        jnum(row.train_acc),
        jnum(row.val_acc),
        jnum(row.rho),
        jnum(row.kappa),
        jnum(row.phi),
        row.examples_seen,
    )
}

/// `"event":"eval"` line for one validation evaluation.
pub fn eval_line(step: usize, val_acc: f64) -> String {
    format!(r#"{{"event":"eval","step":{},"val_acc":{}}}"#, step, jnum(val_acc))
}

/// `"event":"refit"` line for one predictor refit.
pub fn refit_line(ev: &RefitEvent) -> String {
    let (rho, kappa) = ev
        .alignment
        .map_or((f64::NAN, f64::NAN), |a| (a.rho, a.kappa));
    format!(
        r#"{{"event":"refit","step":{},"n":{},"rank":{},"energy_captured":{},"rel_error":{},"rho":{},"kappa":{},"f":{}}}"#,
        ev.step,
        ev.report.n,
        ev.report.rank,
        jnum(ev.report.energy_captured),
        jnum(ev.report.rel_error),
        jnum(rho),
        jnum(kappa),
        jnum(ev.f),
    )
}

/// `"event":"checkpoint"` line for one durable artifact write.
pub fn checkpoint_line(ev: &CheckpointEvent) -> String {
    format!(
        r#"{{"event":"checkpoint","step":{},"path":{:?},"bytes":{},"write_secs":{}}}"#,
        ev.step,
        ev.path.display().to_string(),
        ev.bytes,
        jnum(ev.write_secs),
    )
}

/// `"event":"dist"` line for one process-group transition (ADR-010).
pub fn dist_line(ev: &DistEvent) -> String {
    format!(
        r#"{{"event":"dist","step":{},"rank":{},"procs":{},"kind":{:?},"detail":{:?}}}"#,
        ev.step,
        ev.rank,
        ev.procs,
        ev.kind.as_str(),
        ev.detail,
    )
}

/// `"event":"end"` line, emitted exactly once per run.
pub fn end_line(s: &RunSummary) -> String {
    format!(
        r#"{{"event":"end","steps":{},"final_val_acc":{},"examples_seen":{},"cost_units":{},"wall_secs":{}}}"#,
        s.steps,
        jnum(s.final_val_acc),
        s.examples_seen,
        jnum(s.cost_units),
        jnum(s.wall_secs),
    )
}

impl TrainObserver for JsonlObserver {
    fn on_step(&mut self, row: &LogRow) -> anyhow::Result<()> {
        writeln!(self.file, "{}", step_line(row))?;
        Ok(())
    }

    fn on_eval(&mut self, step: usize, val_acc: f64) -> anyhow::Result<()> {
        writeln!(self.file, "{}", eval_line(step, val_acc))?;
        Ok(())
    }

    fn on_refit(&mut self, ev: &RefitEvent) -> anyhow::Result<()> {
        writeln!(self.file, "{}", refit_line(ev))?;
        Ok(())
    }

    fn on_checkpoint(&mut self, ev: &CheckpointEvent) -> anyhow::Result<()> {
        writeln!(self.file, "{}", checkpoint_line(ev))?;
        Ok(())
    }

    fn on_dist(&mut self, ev: &DistEvent) -> anyhow::Result<()> {
        writeln!(self.file, "{}", dist_line(ev))?;
        Ok(())
    }

    fn on_end(&mut self, s: &RunSummary) -> anyhow::Result<()> {
        writeln!(self.file, "{}", end_line(s))?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Multicast
// ---------------------------------------------------------------------------

/// Composes observers: forwards every event to each sink in order. The
/// first error aborts the fan-out (later sinks do not see the event).
#[derive(Default)]
pub struct Multicast {
    sinks: Vec<Box<dyn TrainObserver>>,
}

impl Multicast {
    pub fn new() -> Multicast {
        Multicast::default()
    }

    /// Chainable sink registration.
    pub fn with(mut self, sink: Box<dyn TrainObserver>) -> Multicast {
        self.sinks.push(sink);
        self
    }

    pub fn push(&mut self, sink: Box<dyn TrainObserver>) {
        self.sinks.push(sink);
    }

    pub fn len(&self) -> usize {
        self.sinks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sinks.is_empty()
    }
}

impl TrainObserver for Multicast {
    fn on_step(&mut self, row: &LogRow) -> anyhow::Result<()> {
        for s in &mut self.sinks {
            s.on_step(row)?;
        }
        Ok(())
    }

    fn on_eval(&mut self, step: usize, val_acc: f64) -> anyhow::Result<()> {
        for s in &mut self.sinks {
            s.on_eval(step, val_acc)?;
        }
        Ok(())
    }

    fn on_refit(&mut self, ev: &RefitEvent) -> anyhow::Result<()> {
        for s in &mut self.sinks {
            s.on_refit(ev)?;
        }
        Ok(())
    }

    fn on_checkpoint(&mut self, ev: &CheckpointEvent) -> anyhow::Result<()> {
        for s in &mut self.sinks {
            s.on_checkpoint(ev)?;
        }
        Ok(())
    }

    fn on_dist(&mut self, ev: &DistEvent) -> anyhow::Result<()> {
        for s in &mut self.sinks {
            s.on_dist(ev)?;
        }
        Ok(())
    }

    fn on_end(&mut self, summary: &RunSummary) -> anyhow::Result<()> {
        for s in &mut self.sinks {
            s.on_end(summary)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::sync::{Arc, Mutex};

    fn row(step: usize, val_acc: f64) -> LogRow {
        LogRow {
            step,
            wall_secs: 0.5,
            loss: 1.25,
            train_acc: 0.5,
            val_acc,
            rho: f64::NAN,
            kappa: f64::NAN,
            phi: f64::NAN,
            examples_seen: 64,
        }
    }

    fn refit_event(step: usize) -> RefitEvent {
        RefitEvent {
            step,
            report: FitReport { n: 8, rank: 2, energy_captured: 0.9, rel_error: 0.1 },
            alignment: None,
            f: 0.25,
        }
    }

    /// Counts events into shared state (the pattern custom observers use
    /// to hand results back out of the session).
    #[derive(Clone, Default)]
    struct Counter(Arc<Mutex<(usize, usize, usize, usize, usize)>>);

    impl TrainObserver for Counter {
        fn on_step(&mut self, _row: &LogRow) -> anyhow::Result<()> {
            self.0.lock().unwrap().0 += 1;
            Ok(())
        }
        fn on_eval(&mut self, _step: usize, _val: f64) -> anyhow::Result<()> {
            self.0.lock().unwrap().1 += 1;
            Ok(())
        }
        fn on_refit(&mut self, _ev: &RefitEvent) -> anyhow::Result<()> {
            self.0.lock().unwrap().2 += 1;
            Ok(())
        }
        fn on_checkpoint(&mut self, _ev: &CheckpointEvent) -> anyhow::Result<()> {
            self.0.lock().unwrap().4 += 1;
            Ok(())
        }
        fn on_end(&mut self, _s: &RunSummary) -> anyhow::Result<()> {
            self.0.lock().unwrap().3 += 1;
            Ok(())
        }
    }

    #[test]
    fn multicast_forwards_every_event_to_every_sink() {
        let a = Counter::default();
        let b = Counter::default();
        let mut m = Multicast::new().with(Box::new(a.clone())).with(Box::new(b.clone()));
        assert_eq!(m.len(), 2);
        m.on_step(&row(1, f64::NAN)).unwrap();
        m.on_step(&row(2, 0.5)).unwrap();
        m.on_eval(2, 0.5).unwrap();
        m.on_refit(&refit_event(2)).unwrap();
        m.on_checkpoint(&CheckpointEvent {
            step: 2,
            path: PathBuf::from("ckpts/ckpt-00000002.lgpckpt"),
            bytes: 1024,
            write_secs: 0.001,
        })
        .unwrap();
        m.on_end(&RunSummary {
            steps: 2,
            final_val_acc: 0.5,
            examples_seen: 64,
            cost_units: 10.0,
            wall_secs: 1.0,
        })
        .unwrap();
        for c in [a, b] {
            assert_eq!(*c.0.lock().unwrap(), (2, 1, 1, 1, 1));
        }
    }

    #[test]
    fn multicast_stops_at_first_error() {
        struct Failing;
        impl TrainObserver for Failing {
            fn on_step(&mut self, _row: &LogRow) -> anyhow::Result<()> {
                anyhow::bail!("sink broke")
            }
        }
        let after = Counter::default();
        let mut m = Multicast::new().with(Box::new(Failing)).with(Box::new(after.clone()));
        assert!(m.on_step(&row(1, f64::NAN)).is_err());
        assert_eq!(after.0.lock().unwrap().0, 0, "later sinks must not see the event");
    }

    #[test]
    fn csv_observer_writes_header_and_rows() {
        let dir = std::env::temp_dir().join("lgp_observer_test");
        let path = dir.join("steps.csv");
        let mut o = CsvObserver::create(&path).unwrap();
        o.on_step(&row(1, f64::NAN)).unwrap();
        o.on_step(&row(2, 0.75)).unwrap();
        drop(o);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], LogRow::HEADER.join(","));
        assert!(lines[2].starts_with("2,"), "{}", lines[2]);
    }

    #[test]
    fn jsonl_lines_parse_even_with_nan_fields() {
        let dir = std::env::temp_dir().join("lgp_observer_test");
        let path = dir.join("steps.jsonl");
        let mut o = JsonlObserver::create(&path).unwrap();
        o.on_step(&row(1, f64::NAN)).unwrap();
        o.on_refit(&refit_event(1)).unwrap();
        o.on_dist(&DistEvent {
            step: 1,
            rank: 0,
            procs: 2,
            kind: DistEventKind::Joined,
            detail: "1 follower".to_string(),
        })
        .unwrap();
        o.on_checkpoint(&CheckpointEvent {
            step: 1,
            path: PathBuf::from("ckpts/ckpt-00000001.lgpckpt"),
            bytes: 2048,
            write_secs: 0.002,
        })
        .unwrap();
        o.on_end(&RunSummary {
            steps: 1,
            final_val_acc: 0.5,
            examples_seen: 64,
            cost_units: 10.0,
            wall_secs: 1.0,
        })
        .unwrap();
        drop(o);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        let dist = Json::parse(lines[2]).unwrap();
        assert_eq!(dist.get("event").and_then(Json::as_str), Some("dist"));
        assert_eq!(dist.get("kind").and_then(Json::as_str), Some("joined"));
        assert_eq!(dist.get("rank").and_then(Json::as_usize), Some(0));
        let ckpt = Json::parse(lines[3]).unwrap();
        assert_eq!(ckpt.get("event").and_then(Json::as_str), Some("checkpoint"));
        assert_eq!(ckpt.get("bytes").and_then(Json::as_usize), Some(2048));
        for line in &lines {
            let j = Json::parse(line).unwrap_or_else(|e| panic!("bad jsonl line {line}: {e}"));
            assert!(j.get("event").and_then(Json::as_str).is_some());
        }
        // NaN val_acc must surface as null, not a bare NaN token.
        let step = Json::parse(lines[0]).unwrap();
        assert!(step.get("val_acc").map_or(false, |v| v.as_f64().is_none()));
        assert_eq!(step.get("step").and_then(Json::as_usize), Some(1));
    }
}
