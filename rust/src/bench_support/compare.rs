//! Perf-regression gate: compare two `lgp.bench.v1` documents cell by
//! cell and fail on slowdowns (EXPERIMENTS.md §Compare gate).
//!
//! A *cell* is one (kernel name, backend, shape, threads, estimator)
//! tuple; the compared quantity is `mean_ns`. Records without a `threads`
//! field (the pre-ADR-004 trajectory) key as `threads=1`, and records
//! without an `estimator` field (every bench but `estimator_sweep`) key
//! without the suffix, so old baselines stay comparable byte for byte.
//! The gate fails when any cell present in both documents
//! regresses by more than the threshold (default 10%), or when a baseline
//! cell disappears from the new document (silent coverage loss reads as a
//! pass otherwise) — the failure text names every missing cell, not just
//! a count, and when every missing cell's backend is absent from the new
//! document entirely it additionally names that backend dimension (a
//! whole column of e.g. `simd` rows vanishing usually means the host
//! lacks the baseline machine's CPU features, not a harness bug). Cells
//! that exist only in the new document are fine — shape grids may grow.
//!
//! Drivers: `bench_report --compare <baseline.json> <new.json>` at the
//! command line, and the cargo-test smoke check in
//! `tests/backend_equivalence.rs` that validates the repo-root
//! `BENCH_kernels.json` against the committed
//! `BENCH_kernels.baseline.json` whenever both exist.

use super::schema;
use super::Table;
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// Default regression threshold: fail on >10% mean ns/op slowdown.
pub const DEFAULT_THRESHOLD: f64 = 0.10;

/// One compared cell.
#[derive(Clone, Debug)]
pub struct CellDelta {
    /// "name backend m×k×n tN [estimator]" — stable, human-readable
    /// cell id; the estimator suffix appears only on estimator-sweep rows.
    pub key: String,
    pub base_ns: f64,
    pub new_ns: f64,
    /// new / base; > 1 means slower.
    pub ratio: f64,
}

/// Outcome of one comparison.
#[derive(Clone, Debug)]
pub struct CompareReport {
    /// Cells present in both documents, baseline order.
    pub cells: Vec<CellDelta>,
    /// Baseline cells missing from the new document.
    pub missing: Vec<String>,
    /// Backends that own at least one missing cell and have **zero**
    /// cells anywhere in the new document. A whole backend column
    /// vanishing is almost always an environment difference (the host
    /// lacks the CPU features the baseline machine had — e.g. `simd`
    /// rows from an AVX2+FMA box), not a bench-harness coverage bug, so
    /// the failure text names the dimension instead of leaving the user
    /// to reverse-engineer it from a wall of per-cell keys.
    pub missing_backends: Vec<String>,
    pub threshold: f64,
}

impl CompareReport {
    /// Cells slower than `1 + threshold`.
    pub fn regressions(&self) -> Vec<&CellDelta> {
        self.cells
            .iter()
            .filter(|c| c.ratio > 1.0 + self.threshold)
            .collect()
    }

    /// Cells at least `1 + threshold` faster (for the summary line).
    pub fn improvements(&self) -> Vec<&CellDelta> {
        self.cells
            .iter()
            .filter(|c| c.ratio < 1.0 / (1.0 + self.threshold))
            .collect()
    }

    pub fn passed(&self) -> bool {
        self.regressions().is_empty() && self.missing.is_empty()
    }

    /// Human-readable failure verdict naming every offending cell — the
    /// `(kernel, backend, shape, threads, estimator)` tuples, not just
    /// counts, so a gate failure in CI output is actionable without
    /// re-running locally. `None` when the gate passed.
    pub fn failure_message(&self) -> Option<String> {
        if self.passed() {
            return None;
        }
        let mut parts = Vec::new();
        let regs = self.regressions();
        if !regs.is_empty() {
            let list: Vec<String> = regs
                .iter()
                .map(|c| format!("{} ({:.0} -> {:.0} ns, x{:.2})", c.key, c.base_ns, c.new_ns, c.ratio))
                .collect();
            parts.push(format!(
                "{} cell(s) regressed past {:.0}%: {}",
                list.len(),
                self.threshold * 100.0,
                list.join(", ")
            ));
        }
        if !self.missing.is_empty() {
            parts.push(format!(
                "{} baseline cell(s) lost coverage (kernel backend shape threads estimator): {}",
                self.missing.len(),
                self.missing.join(", ")
            ));
        }
        if !self.missing_backends.is_empty() {
            parts.push(format!(
                "note: backend(s) [{}] contribute missing cells and appear nowhere in the new \
                 document — this host likely lacks the CPU features the baseline machine had \
                 (e.g. avx2+fma for 'simd'); re-run on matching hardware or regenerate the \
                 baseline without those rows",
                self.missing_backends.join(", ")
            ));
        }
        Some(parts.join("; "))
    }

    /// Fixed-width per-cell table for terminal output.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["cell", "base ns", "new ns", "ratio", "verdict"]);
        for c in &self.cells {
            let verdict = if c.ratio > 1.0 + self.threshold {
                "REGRESSED"
            } else if c.ratio < 1.0 / (1.0 + self.threshold) {
                "improved"
            } else {
                "ok"
            };
            t.row(vec![
                c.key.clone(),
                format!("{:.0}", c.base_ns),
                format!("{:.0}", c.new_ns),
                format!("{:.3}", c.ratio),
                verdict.into(),
            ]);
        }
        for m in &self.missing {
            t.row(vec![m.clone(), "-".into(), "-".into(), "-".into(), "MISSING".into()]);
        }
        t
    }
}

fn cell_key(rec: &Json) -> Option<String> {
    let name = rec.get("name")?.as_str()?;
    let backend = rec.get("backend")?.as_str()?;
    let shape = rec
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_f64().map(|v| format!("{}", v as u64)))
        .collect::<Option<Vec<_>>>()?
        .join("x");
    // Absent threads keys as 1: pre-dimension baselines compare cleanly
    // against refreshed documents that stamp `threads` everywhere.
    let threads = match rec.get("threads") {
        Some(t) => t.as_f64()? as u64,
        None => 1,
    };
    // The estimator dimension (ADR-006) suffixes the key only when
    // present, keeping every pre-dimension baseline key byte-identical.
    let mut key = format!("{name} {backend} {shape} t{threads}");
    if let Some(e) = rec.get("estimator") {
        key.push(' ');
        key.push_str(e.as_str()?);
    }
    Some(key)
}

fn index_cells(
    doc: &Json,
    what: &str,
) -> Result<(BTreeMap<String, f64>, BTreeSet<String>), String> {
    let mut cells = BTreeMap::new();
    let mut backends = BTreeSet::new();
    let records = doc
        .get("records")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{what}: missing records array"))?;
    for (i, rec) in records.iter().enumerate() {
        let key =
            cell_key(rec).ok_or_else(|| format!("{what}: records[{i}] has a malformed key"))?;
        let mean = rec
            .get("mean_ns")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("{what}: records[{i}] missing mean_ns"))?;
        // cell_key() already proved `backend` is a string.
        backends.insert(rec.get("backend").and_then(Json::as_str).unwrap().to_string());
        // Duplicate cells would make the comparison ambiguous.
        if cells.insert(key.clone(), mean).is_some() {
            return Err(format!("{what}: duplicate cell '{key}'"));
        }
    }
    Ok((cells, backends))
}

/// Compare two validated documents. Both must pass schema validation and
/// describe the same bench.
pub fn compare_docs(base: &Json, new: &Json, threshold: f64) -> Result<CompareReport, String> {
    let base_rep = schema::validate(base).map_err(|e| format!("baseline: {e}"))?;
    let new_rep = schema::validate(new).map_err(|e| format!("new: {e}"))?;
    if base_rep.bench != new_rep.bench {
        return Err(format!(
            "bench mismatch: baseline is '{}', new is '{}'",
            base_rep.bench, new_rep.bench
        ));
    }
    let (base_cells, _) = index_cells(base, "baseline")?;
    let (new_cells, new_backends) = index_cells(new, "new")?;
    let mut cells = Vec::new();
    let mut missing = Vec::new();
    let mut missing_backends = BTreeSet::new();
    for (key, &base_ns) in &base_cells {
        match new_cells.get(key) {
            Some(&new_ns) => {
                let ratio = if base_ns > 0.0 { new_ns / base_ns } else { 1.0 };
                cells.push(CellDelta { key: key.clone(), base_ns, new_ns, ratio });
            }
            None => {
                // Keys are "name backend shape tN [estimator]" and neither
                // name nor backend may contain whitespace, so the second
                // token is the backend dimension of the lost cell.
                if let Some(be) = key.split_whitespace().nth(1) {
                    if !new_backends.contains(be) {
                        missing_backends.insert(be.to_string());
                    }
                }
                missing.push(key.clone());
            }
        }
    }
    Ok(CompareReport {
        cells,
        missing,
        missing_backends: missing_backends.into_iter().collect(),
        threshold,
    })
}

/// Read, validate and compare two `BENCH_*.json` files.
pub fn compare_files(
    base: &Path,
    new: &Path,
    threshold: f64,
) -> Result<CompareReport, String> {
    let read = |p: &Path| -> Result<Json, String> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| format!("reading {}: {e}", p.display()))?;
        Json::parse(&text).map_err(|e| format!("parsing {}: {e}", p.display()))
    };
    compare_docs(&read(base)?, &read(new)?, threshold)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(cells: &[(&str, &str, &[usize], f64)]) -> Json {
        let records: Vec<String> = cells
            .iter()
            .map(|(name, be, shape, ns)| {
                let dims: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
                format!(
                    r#"{{"name":"{name}","backend":"{be}","shape":[{}],
                        "iters":3,"mean_ns":{ns},"p50_ns":{ns},"p90_ns":{ns}}}"#,
                    dims.join(",")
                )
            })
            .collect();
        Json::parse(&format!(
            r#"{{"schema":"lgp.bench.v1","bench":"custom","created_unix":1,
                "records":[{}]}}"#,
            records.join(",")
        ))
        .unwrap()
    }

    #[test]
    fn identical_documents_pass() {
        let d = doc(&[
            ("matmul", "naive", &[8, 8, 8], 100.0),
            ("gram_t", "micro", &[32, 16], 50.0),
        ]);
        let rep = compare_docs(&d, &d, DEFAULT_THRESHOLD).unwrap();
        assert!(rep.passed());
        assert_eq!(rep.cells.len(), 2);
        assert!(rep.regressions().is_empty());
        rep.table().print();
    }

    #[test]
    fn twenty_percent_slower_fails_ten_percent_gate() {
        let base = doc(&[("matmul", "micro", &[8, 8, 8], 100.0)]);
        let slow = doc(&[("matmul", "micro", &[8, 8, 8], 120.0)]);
        let rep = compare_docs(&base, &slow, DEFAULT_THRESHOLD).unwrap();
        assert!(!rep.passed());
        assert_eq!(rep.regressions().len(), 1);
        assert!((rep.regressions()[0].ratio - 1.2).abs() < 1e-9);
    }

    #[test]
    fn nine_percent_slower_passes_ten_percent_gate() {
        let base = doc(&[("matmul", "micro", &[8, 8, 8], 100.0)]);
        let ok = doc(&[("matmul", "micro", &[8, 8, 8], 109.0)]);
        let rep = compare_docs(&base, &ok, DEFAULT_THRESHOLD).unwrap();
        assert!(rep.passed());
    }

    #[test]
    fn missing_baseline_cell_fails() {
        let base = doc(&[
            ("matmul", "micro", &[8, 8, 8], 100.0),
            ("gram_t", "micro", &[32, 16], 50.0),
        ]);
        let new = doc(&[("matmul", "micro", &[8, 8, 8], 100.0)]);
        let rep = compare_docs(&base, &new, DEFAULT_THRESHOLD).unwrap();
        assert!(!rep.passed());
        assert_eq!(rep.missing, vec!["gram_t micro 32x16 t1".to_string()]);
    }

    #[test]
    fn failure_message_lists_every_missing_cell() {
        let base = doc(&[
            ("matmul", "micro", &[8, 8, 8], 100.0),
            ("gram_t", "micro", &[32, 16], 50.0),
            ("dot", "naive", &[4096], 10.0),
        ]);
        let new = doc(&[("matmul", "micro", &[8, 8, 8], 100.0)]);
        let rep = compare_docs(&base, &new, DEFAULT_THRESHOLD).unwrap();
        let msg = rep.failure_message().expect("lost coverage must fail");
        // Every lost (kernel, backend, shape, threads) cell is named.
        assert!(msg.contains("gram_t micro 32x16 t1"), "{msg}");
        assert!(msg.contains("dot naive 4096 t1"), "{msg}");
        assert!(msg.contains("2 baseline cell(s) lost coverage"), "{msg}");
        // A clean comparison has no failure message.
        let rep = compare_docs(&base, &base, DEFAULT_THRESHOLD).unwrap();
        assert!(rep.failure_message().is_none());
    }

    #[test]
    fn failure_message_names_regressed_cells_with_ratio() {
        let base = doc(&[("matmul", "micro", &[8, 8, 8], 100.0)]);
        let slow = doc(&[("matmul", "micro", &[8, 8, 8], 150.0)]);
        let rep = compare_docs(&base, &slow, DEFAULT_THRESHOLD).unwrap();
        let msg = rep.failure_message().unwrap();
        assert!(msg.contains("matmul micro 8x8x8 t1"), "{msg}");
        assert!(msg.contains("x1.50"), "{msg}");
    }

    #[test]
    fn threads_distinguishes_cells_and_defaults_to_one() {
        // Same (name, backend, shape) at two thread counts are distinct
        // cells; a record without `threads` keys identically to t1.
        let base = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"custom","created_unix":1,"records":[
                {"name":"sharded_update","backend":"micro","shape":[8,64,64],
                 "iters":3,"mean_ns":100.0,"p50_ns":100.0,"p90_ns":100.0},
                {"name":"sharded_update","backend":"micro","shape":[8,64,64],
                 "threads":4,"iters":3,"mean_ns":30.0,"p50_ns":30.0,"p90_ns":30.0}]}"#,
        )
        .unwrap();
        let new = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"custom","created_unix":2,"records":[
                {"name":"sharded_update","backend":"micro","shape":[8,64,64],
                 "threads":1,"iters":3,"mean_ns":100.0,"p50_ns":100.0,"p90_ns":100.0},
                {"name":"sharded_update","backend":"micro","shape":[8,64,64],
                 "threads":4,"iters":3,"mean_ns":30.0,"p50_ns":30.0,"p90_ns":30.0}]}"#,
        )
        .unwrap();
        let rep = compare_docs(&base, &new, DEFAULT_THRESHOLD).unwrap();
        assert!(rep.passed(), "{:?}", rep.failure_message());
        assert_eq!(rep.cells.len(), 2);
        assert!(rep.cells.iter().any(|c| c.key.ends_with("t1")));
        assert!(rep.cells.iter().any(|c| c.key.ends_with("t4")));
    }

    #[test]
    fn estimator_distinguishes_cells_and_missing_cells_name_it() {
        // Same (name, backend, shape, threads) under two estimators are
        // distinct cells; dropping one must be reported by its full key,
        // estimator included — and plain cells keep their suffix-free key.
        let base = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"custom","created_unix":1,"records":[
                {"name":"slot_estimate","backend":"micro","shape":[8],
                 "estimator":"control-variate",
                 "iters":3,"mean_ns":40.0,"p50_ns":40.0,"p90_ns":40.0},
                {"name":"slot_estimate","backend":"micro","shape":[8],
                 "estimator":"multi-tangent",
                 "iters":3,"mean_ns":25.0,"p50_ns":25.0,"p90_ns":25.0},
                {"name":"gram_t","backend":"micro","shape":[32,16],
                 "iters":3,"mean_ns":50.0,"p50_ns":50.0,"p90_ns":50.0}]}"#,
        )
        .unwrap();
        let new = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"custom","created_unix":2,"records":[
                {"name":"slot_estimate","backend":"micro","shape":[8],
                 "estimator":"control-variate",
                 "iters":3,"mean_ns":40.0,"p50_ns":40.0,"p90_ns":40.0},
                {"name":"gram_t","backend":"micro","shape":[32,16],
                 "iters":3,"mean_ns":50.0,"p50_ns":50.0,"p90_ns":50.0}]}"#,
        )
        .unwrap();
        let rep = compare_docs(&base, &new, DEFAULT_THRESHOLD).unwrap();
        assert!(!rep.passed());
        assert_eq!(
            rep.missing,
            vec!["slot_estimate micro 8 t1 multi-tangent".to_string()]
        );
        let msg = rep.failure_message().unwrap();
        assert!(msg.contains("(kernel backend shape threads estimator)"), "{msg}");
        assert!(msg.contains("slot_estimate micro 8 t1 multi-tangent"), "{msg}");
        // Estimator-free rows keep the historical key shape.
        assert!(rep.cells.iter().any(|c| c.key == "gram_t micro 32x16 t1"));
        assert!(rep
            .cells
            .iter()
            .any(|c| c.key == "slot_estimate micro 8 t1 control-variate"));
    }

    #[test]
    fn missing_whole_backend_names_the_backend_dimension() {
        // The baseline has simd rows (written on an AVX2+FMA machine);
        // the new document has none at all. The failure text must name
        // the backend dimension, not just list cells.
        let base = doc(&[
            ("matmul", "micro", &[192, 192, 192], 100.0),
            ("matmul", "simd", &[192, 192, 192], 40.0),
            ("gram_t", "simd", &[192, 96], 30.0),
        ]);
        let new = doc(&[("matmul", "micro", &[192, 192, 192], 100.0)]);
        let rep = compare_docs(&base, &new, DEFAULT_THRESHOLD).unwrap();
        assert!(!rep.passed());
        assert_eq!(rep.missing.len(), 2);
        assert_eq!(rep.missing_backends, vec!["simd".to_string()]);
        let msg = rep.failure_message().unwrap();
        assert!(msg.contains("backend(s) [simd]"), "{msg}");
        assert!(msg.contains("CPU features"), "{msg}");
    }

    #[test]
    fn missing_cell_of_a_still_present_backend_gets_no_backend_note() {
        // micro still has cells in the new document, so a lost micro cell
        // is a genuine coverage regression — no environment note.
        let base = doc(&[
            ("matmul", "micro", &[8, 8, 8], 100.0),
            ("gram_t", "micro", &[32, 16], 50.0),
        ]);
        let new = doc(&[("matmul", "micro", &[8, 8, 8], 100.0)]);
        let rep = compare_docs(&base, &new, DEFAULT_THRESHOLD).unwrap();
        assert!(!rep.passed());
        assert!(rep.missing_backends.is_empty());
        let msg = rep.failure_message().unwrap();
        assert!(!msg.contains("backend(s) ["), "{msg}");
    }

    #[test]
    fn extra_new_cells_are_fine_and_improvements_counted() {
        let base = doc(&[("matmul", "micro", &[8, 8, 8], 100.0)]);
        let new = doc(&[
            ("matmul", "micro", &[8, 8, 8], 60.0),
            ("matmul", "micro", &[16, 16, 16], 400.0),
        ]);
        let rep = compare_docs(&base, &new, DEFAULT_THRESHOLD).unwrap();
        assert!(rep.passed());
        assert_eq!(rep.improvements().len(), 1);
    }

    #[test]
    fn mismatched_bench_ids_and_duplicates_error() {
        let a = doc(&[("matmul", "micro", &[8, 8, 8], 100.0)]);
        let other = Json::parse(
            &a.to_string().replace("\"bench\":\"custom\"", "\"bench\":\"other\""),
        )
        .unwrap();
        assert!(compare_docs(&a, &other, DEFAULT_THRESHOLD).is_err());

        let dup = doc(&[
            ("matmul", "micro", &[8, 8, 8], 100.0),
            ("matmul", "micro", &[8, 8, 8], 90.0),
        ]);
        assert!(compare_docs(&dup, &a, DEFAULT_THRESHOLD).is_err());
    }

    #[test]
    fn compare_files_reports_io_errors() {
        let missing = Path::new("/nonexistent/BENCH_a.json");
        assert!(compare_files(missing, missing, DEFAULT_THRESHOLD).is_err());
    }
}
