//! Hand-rolled micro-benchmark harness (no criterion in the offline set).
//!
//! Provides warmup + timed iterations with mean/σ/percentile reporting and
//! fixed-width table printing shared by every `cargo bench` target. Each
//! bench binary regenerates one paper table or figure (DESIGN.md §4).
//!
//! Machine-readable output: `json_out` serializes timing records to the
//! repo-root `BENCH_*.json` trajectory files (schema `lgp.bench.v1`,
//! documented in EXPERIMENTS.md), `kernels` is the backend×shape kernel
//! suite shared by `cargo bench --bench hotpath` and the smoke tests,
//! `schema` validates emitted documents (also used by the `bench-report`
//! binary), and `compare` is the perf-regression gate behind
//! `bench_report --compare` and the tier-1 smoke check.

pub mod compare;
pub mod json_out;
pub mod kernels;
pub mod schema;

use std::time::Instant;

/// Timing summary over bench iterations, in seconds.
#[derive(Clone, Debug)]
pub struct Summary {
    pub iters: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
}

impl Summary {
    pub fn from_samples(mut samples: Vec<f64>) -> Summary {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let pick = |q: f64| samples[((q * (n - 1) as f64).round() as usize).min(n - 1)];
        Summary {
            iters: n,
            mean,
            std: var.sqrt(),
            min: samples[0],
            p50: pick(0.5),
            p90: pick(0.9),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean * 1e3
    }
}

/// Run `f` for `warmup` unmeasured iterations then `iters` measured ones.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::from_samples(samples)
}

/// Time a single invocation (for expensive end-to-end cases).
pub fn time_once<F: FnOnce() -> T, T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Fixed-width table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table arity mismatch");
        for (w, c) in self.widths.iter_mut().zip(&cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let line: Vec<String> = self
            .headers
            .iter()
            .zip(&self.widths)
            .map(|(h, w)| format!("{h:>w$}"))
            .collect();
        println!("{}", line.join("  "));
        let rule: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", rule.join("  "));
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .zip(&self.widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            println!("{}", line.join("  "));
        }
    }
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_statistics() {
        let s = Summary::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 3.0);
        assert!(s.std > 1.0 && s.std < 2.0);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0usize;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_formats_without_panic() {
        let mut t = Table::new(&["f", "rho*"]);
        t.row(vec!["0.1".into(), "0.876".into()]);
        t.row(vec!["0.5".into(), "0.689".into()]);
        t.print();
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-10).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }
}
