//! Machine-readable bench output: the `BENCH_*.json` trajectory files.
//!
//! Every bench binary that produces perf numbers serializes them through
//! [`BenchRecord`] into a `lgp.bench.v1` document (schema documented in
//! EXPERIMENTS.md) and drops it at the repository root, so future PRs can
//! regress against the recorded trajectory. The `bench-report` binary and
//! the smoke tests validate the same documents via `bench_support::schema`.

use super::Summary;
use crate::util::json::{num, obj, s, Json};
use std::path::PathBuf;

/// Schema identifier stamped into every emitted document.
pub const SCHEMA_ID: &str = "lgp.bench.v1";

/// One timed entry: a kernel/procedure on one backend at one shape.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Kernel or procedure name, e.g. `matmul`, `gram_t`, `train_grads`.
    pub name: String,
    /// Tensor backend (`naive`/`blocked`/`micro`/`simd`), or `device` for
    /// PJRT timings, or `-` where the notion does not apply.
    pub backend: String,
    /// Problem shape, kernel-specific (matmul: `[m, k, n]`).
    pub shape: Vec<usize>,
    /// Worker threads driving the measured region (ADR-004). Host kernels
    /// are single-threaded (`1`); the sharded-update rows sweep it.
    /// Documents written before the dimension existed read as `1`.
    pub threads: usize,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
    /// Throughput where a flop count is defined.
    pub gflops: Option<f64>,
    /// Gradient-estimator dimension (ADR-006): the zoo member that
    /// produced this row (`estimator_sweep` rows), absent for plain
    /// kernel benches. Like `threads`, documents written before the
    /// dimension existed simply omit it.
    pub estimator: Option<String>,
}

impl BenchRecord {
    /// Build a record from a timing [`Summary`] and an optional flop count
    /// per iteration.
    pub fn from_summary(
        name: &str,
        backend: &str,
        shape: &[usize],
        summary: &Summary,
        flops: Option<f64>,
    ) -> BenchRecord {
        BenchRecord {
            name: name.to_string(),
            backend: backend.to_string(),
            shape: shape.to_vec(),
            threads: 1,
            iters: summary.iters,
            mean_ns: summary.mean * 1e9,
            p50_ns: summary.p50 * 1e9,
            p90_ns: summary.p90 * 1e9,
            gflops: flops.and_then(|fl| {
                let g = fl / summary.mean / 1e9;
                g.is_finite().then_some(g)
            }),
            estimator: None,
        }
    }

    /// Builder: stamp the worker-thread dimension (sharded-update rows).
    pub fn with_threads(mut self, threads: usize) -> BenchRecord {
        assert!(threads >= 1, "threads dimension must be >= 1");
        self.threads = threads;
        self
    }

    /// Builder: stamp the estimator dimension (`estimator_sweep` rows).
    pub fn with_estimator(mut self, name: &str) -> BenchRecord {
        assert!(!name.is_empty(), "estimator dimension must be non-empty");
        self.estimator = Some(name.to_string());
        self
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", s(&self.name)),
            ("backend", s(&self.backend)),
            (
                "shape",
                Json::Arr(self.shape.iter().map(|&d| num(d as f64)).collect()),
            ),
            ("threads", num(self.threads as f64)),
            ("iters", num(self.iters as f64)),
            ("mean_ns", num(self.mean_ns)),
            ("p50_ns", num(self.p50_ns)),
            ("p90_ns", num(self.p90_ns)),
        ];
        if let Some(g) = self.gflops {
            pairs.push(("gflops", num(g)));
        }
        if let Some(est) = &self.estimator {
            pairs.push(("estimator", s(est)));
        }
        obj(pairs)
    }
}

/// Assemble a full `lgp.bench.v1` document. `derived` carries
/// bench-specific summary values (e.g. the cost-model γ table) that the
/// generic validator does not interpret.
pub fn bench_doc(bench: &str, records: &[BenchRecord], derived: Option<Json>) -> Json {
    let created = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let mut pairs = vec![
        ("schema", s(SCHEMA_ID)),
        ("bench", s(bench)),
        ("created_unix", num(created)),
        (
            "records",
            Json::Arr(records.iter().map(BenchRecord::to_json).collect()),
        ),
    ];
    if let Some(d) = derived {
        pairs.push(("derived", d));
    }
    obj(pairs)
}

/// Where `BENCH_*.json` files land: `$LGP_BENCH_DIR` if set, else the
/// repository root (first ancestor of the current directory holding
/// `.git` or `ROADMAP.md`), else the current directory.
pub fn bench_out_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("LGP_BENCH_DIR") {
        return PathBuf::from(dir);
    }
    let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    let mut dir = cwd.clone();
    loop {
        if dir.join(".git").exists() || dir.join("ROADMAP.md").exists() {
            return dir;
        }
        if !dir.pop() {
            return cwd;
        }
    }
}

/// Serialize `doc` to `<bench_out_dir>/<file_name>` and return the path.
pub fn write_bench_doc(file_name: &str, doc: &Json) -> anyhow::Result<PathBuf> {
    let path = bench_out_dir().join(file_name);
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write(&path, text)
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary() -> Summary {
        Summary::from_samples(vec![1e-6, 2e-6, 3e-6])
    }

    #[test]
    fn record_converts_units() {
        let r = BenchRecord::from_summary("matmul", "blocked", &[8, 8, 8], &summary(), Some(1024.0));
        assert_eq!(r.iters, 3);
        assert_eq!(r.threads, 1, "threads dimension defaults to single-threaded");
        assert!((r.mean_ns - 2000.0).abs() < 1e-6);
        let g = r.gflops.unwrap();
        assert!((g - 1024.0 / 2e-6 / 1e9).abs() < 1e-9);
        let r4 = r.with_threads(4);
        assert_eq!(r4.threads, 4);
        let j = r4.to_json();
        assert_eq!(j.at(&["threads"]).as_f64(), Some(4.0));
        // Estimator dimension: absent unless stamped.
        assert!(j.get("estimator").is_none());
        let re = r4.with_estimator("control-variate");
        let j = re.to_json();
        assert_eq!(j.at(&["estimator"]).as_str(), Some("control-variate"));
    }

    #[test]
    fn doc_is_valid_json_with_schema_header() {
        let r = BenchRecord::from_summary("dot", "naive", &[64], &summary(), None);
        let doc = bench_doc("kernels", &[r], None);
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.at(&["schema"]).as_str(), Some(SCHEMA_ID));
        assert_eq!(parsed.at(&["bench"]).as_str(), Some("kernels"));
        assert_eq!(parsed.at(&["records"]).as_arr().unwrap().len(), 1);
        // gflops omitted when no flop count was given
        assert!(parsed.at(&["records"]).as_arr().unwrap()[0]
            .get("gflops")
            .is_none());
    }

    #[test]
    fn out_dir_honors_env_override() {
        // Serialize access to the env var across test threads is not
        // needed: this test sets a unique value and restores immediately.
        let dir = std::env::temp_dir().join("lgp_json_out_test");
        std::env::set_var("LGP_BENCH_DIR", &dir);
        let got = bench_out_dir();
        std::env::remove_var("LGP_BENCH_DIR");
        assert_eq!(got, dir);
        // Without the override the walk-up finds a marker or falls back.
        let root = bench_out_dir();
        assert!(root.as_os_str().len() > 0);
    }
}
