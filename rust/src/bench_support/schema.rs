//! Validation of `lgp.bench.v1` documents (the `BENCH_*.json` trajectory
//! files). The rules here are the normative schema described in
//! EXPERIMENTS.md §Schema; the `bench-report` binary and the cargo-test
//! smoke tests both call into this module, so a malformed emitter fails
//! in CI and at the command line identically.

use super::json_out::SCHEMA_ID;
use crate::util::json::Json;
use std::path::Path;

/// Summary of one successfully validated document.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub bench: String,
    pub records: usize,
    /// Distinct backend names seen across records.
    pub backends: Vec<String>,
    /// Distinct estimator names seen across records (ADR-006); empty for
    /// documents without the dimension.
    pub estimators: Vec<String>,
}

fn field<'a>(j: &'a Json, key: &str, what: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("{what}: missing field '{key}'"))
}

fn req_str(j: &Json, key: &str, what: &str) -> Result<String, String> {
    let v = field(j, key, what)?
        .as_str()
        .ok_or_else(|| format!("{what}: field '{key}' must be a string"))?;
    if v.is_empty() {
        return Err(format!("{what}: field '{key}' must be non-empty"));
    }
    Ok(v.to_string())
}

fn req_num(j: &Json, key: &str, what: &str) -> Result<f64, String> {
    let v = field(j, key, what)?
        .as_f64()
        .ok_or_else(|| format!("{what}: field '{key}' must be a number"))?;
    if !v.is_finite() || v < 0.0 {
        return Err(format!("{what}: field '{key}' must be finite and >= 0, got {v}"));
    }
    Ok(v)
}

/// Validate one parsed document against the `lgp.bench.v1` schema.
pub fn validate(doc: &Json) -> Result<ValidationReport, String> {
    if doc.as_obj().is_none() {
        return Err("top level must be a JSON object".into());
    }
    let schema = req_str(doc, "schema", "document")?;
    if schema != SCHEMA_ID {
        return Err(format!("unknown schema '{schema}' (want '{SCHEMA_ID}')"));
    }
    let bench = req_str(doc, "bench", "document")?;
    req_num(doc, "created_unix", "document")?;

    let records = field(doc, "records", "document")?
        .as_arr()
        .ok_or_else(|| "document: 'records' must be an array".to_string())?;
    if records.is_empty() {
        return Err("document: 'records' must be non-empty".into());
    }

    let mut backends: Vec<String> = Vec::new();
    let mut estimators: Vec<String> = Vec::new();
    for (i, rec) in records.iter().enumerate() {
        let what = format!("records[{i}]");
        if rec.as_obj().is_none() {
            return Err(format!("{what}: must be an object"));
        }
        req_str(rec, "name", &what)?;
        let be = req_str(rec, "backend", &what)?;
        if !backends.contains(&be) {
            backends.push(be);
        }
        let shape = field(rec, "shape", &what)?
            .as_arr()
            .ok_or_else(|| format!("{what}: 'shape' must be an array"))?;
        for (d, dim) in shape.iter().enumerate() {
            let v = dim
                .as_f64()
                .ok_or_else(|| format!("{what}: shape[{d}] must be a number"))?;
            if v < 0.0 || v.fract() != 0.0 {
                return Err(format!("{what}: shape[{d}] must be a non-negative integer"));
            }
        }
        let iters = req_num(rec, "iters", &what)?;
        if iters < 1.0 || iters.fract() != 0.0 {
            return Err(format!("{what}: 'iters' must be a positive integer"));
        }
        // Optional worker-thread dimension (ADR-004); absent reads as 1,
        // so pre-dimension documents stay valid and comparable.
        if let Some(t) = rec.get("threads") {
            let v = t
                .as_f64()
                .ok_or_else(|| format!("{what}: 'threads' must be a number"))?;
            if v < 1.0 || v.fract() != 0.0 {
                return Err(format!("{what}: 'threads' must be a positive integer"));
            }
        }
        // Optional gradient-estimator dimension (ADR-006); absent means
        // the row is estimator-agnostic (plain kernel benches).
        if let Some(e) = rec.get("estimator") {
            let v = e
                .as_str()
                .ok_or_else(|| format!("{what}: 'estimator' must be a string"))?;
            if v.is_empty() {
                return Err(format!("{what}: 'estimator' must be non-empty"));
            }
            if !estimators.contains(&v.to_string()) {
                estimators.push(v.to_string());
            }
        }
        req_num(rec, "mean_ns", &what)?;
        req_num(rec, "p50_ns", &what)?;
        req_num(rec, "p90_ns", &what)?;
        if let Some(g) = rec.get("gflops") {
            let v = g
                .as_f64()
                .ok_or_else(|| format!("{what}: 'gflops' must be a number"))?;
            if !v.is_finite() || v < 0.0 {
                return Err(format!("{what}: 'gflops' must be finite and >= 0"));
            }
        }
    }

    // Bench-specific invariant: the kernel trajectory must cover every
    // *portable* tensor backend, or cross-PR comparisons silently lose a
    // column. `simd` is deliberately not required — it exists only on
    // AVX2+FMA hosts (ADR-007), and a kernels document emitted on a
    // scalar machine must still validate; the compare gate is what
    // notices when a baseline's simd column goes missing.
    if bench == "kernels" {
        for required in ["naive", "blocked", "micro"] {
            if !backends.iter().any(|b| b == required) {
                return Err(format!("kernels document missing backend '{required}'"));
            }
        }
    }

    // Same invariant for the estimator sweep: every zoo member must be
    // present, or the head-to-head table silently loses a row.
    if bench == "estimators" {
        for required in [
            "true-backprop",
            "control-variate",
            "predicted-lgp",
            "multi-tangent",
            "neural-cv",
        ] {
            if !estimators.iter().any(|e| e == required) {
                return Err(format!("estimators document missing estimator '{required}'"));
            }
        }
    }

    Ok(ValidationReport { bench, records: records.len(), backends, estimators })
}

/// Read, parse and validate a `BENCH_*.json` file.
pub fn validate_file(path: &Path) -> Result<ValidationReport, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))?;
    validate(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal(backend_list: &[&str]) -> String {
        let records: Vec<String> = backend_list
            .iter()
            .map(|b| {
                format!(
                    r#"{{"name":"matmul","backend":"{b}","shape":[4,4,4],
                        "iters":3,"mean_ns":10.0,"p50_ns":9.0,"p90_ns":12.0,"gflops":1.5}}"#
                )
            })
            .collect();
        format!(
            r#"{{"schema":"lgp.bench.v1","bench":"kernels","created_unix":1,
                "records":[{}]}}"#,
            records.join(",")
        )
    }

    #[test]
    fn accepts_well_formed_kernels_doc() {
        let doc = Json::parse(&minimal(&["naive", "blocked", "micro"])).unwrap();
        let rep = validate(&doc).unwrap();
        assert_eq!(rep.bench, "kernels");
        assert_eq!(rep.records, 3);
        assert_eq!(rep.backends.len(), 3);
    }

    #[test]
    fn rejects_missing_backend_coverage() {
        let doc = Json::parse(&minimal(&["naive", "blocked"])).unwrap();
        let err = validate(&doc).unwrap_err();
        assert!(err.contains("micro"), "{err}");
    }

    #[test]
    fn rejects_bad_schema_and_shapes() {
        let doc = Json::parse(r#"{"schema":"nope","bench":"x","created_unix":1,"records":[]}"#)
            .unwrap();
        assert!(validate(&doc).unwrap_err().contains("unknown schema"));

        let doc = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"x","created_unix":1,"records":[]}"#,
        )
        .unwrap();
        assert!(validate(&doc).unwrap_err().contains("non-empty"));

        let doc = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"x","created_unix":1,
                "records":[{"name":"m","backend":"naive","shape":[-1],
                            "iters":1,"mean_ns":1,"p50_ns":1,"p90_ns":1}]}"#,
        )
        .unwrap();
        assert!(validate(&doc).unwrap_err().contains("shape[0]"));
    }

    #[test]
    fn threads_dimension_optional_but_positive_integer() {
        let ok = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"x","created_unix":1,
                "records":[{"name":"sharded_update","backend":"micro","shape":[8,192,192],
                            "threads":4,"iters":3,"mean_ns":1,"p50_ns":1,"p90_ns":1}]}"#,
        )
        .unwrap();
        assert!(validate(&ok).is_ok());
        let zero = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"x","created_unix":1,
                "records":[{"name":"m","backend":"naive","shape":[2],
                            "threads":0,"iters":1,"mean_ns":1,"p50_ns":1,"p90_ns":1}]}"#,
        )
        .unwrap();
        assert!(validate(&zero).unwrap_err().contains("threads"));
    }

    #[test]
    fn estimator_dimension_optional_but_non_empty_string() {
        let ok = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"x","created_unix":1,
                "records":[{"name":"slot_estimate","backend":"micro","shape":[8],
                            "estimator":"control-variate",
                            "iters":3,"mean_ns":1,"p50_ns":1,"p90_ns":1}]}"#,
        )
        .unwrap();
        let rep = validate(&ok).unwrap();
        assert_eq!(rep.estimators, vec!["control-variate".to_string()]);
        let empty = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"x","created_unix":1,
                "records":[{"name":"m","backend":"naive","shape":[2],
                            "estimator":"",
                            "iters":1,"mean_ns":1,"p50_ns":1,"p90_ns":1}]}"#,
        )
        .unwrap();
        assert!(validate(&empty).unwrap_err().contains("estimator"));
        let non_str = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"x","created_unix":1,
                "records":[{"name":"m","backend":"naive","shape":[2],
                            "estimator":7,
                            "iters":1,"mean_ns":1,"p50_ns":1,"p90_ns":1}]}"#,
        )
        .unwrap();
        assert!(validate(&non_str).unwrap_err().contains("must be a string"));
    }

    #[test]
    fn estimators_bench_requires_full_zoo_coverage() {
        let zoo = [
            "true-backprop",
            "control-variate",
            "predicted-lgp",
            "multi-tangent",
            "neural-cv",
        ];
        let doc_for = |names: &[&str]| {
            let records: Vec<String> = names
                .iter()
                .map(|e| {
                    format!(
                        r#"{{"name":"slot_estimate","backend":"micro","shape":[8],
                            "estimator":"{e}","iters":1,"mean_ns":1,"p50_ns":1,"p90_ns":1}}"#
                    )
                })
                .collect();
            format!(
                r#"{{"schema":"lgp.bench.v1","bench":"estimators","created_unix":1,
                    "records":[{}]}}"#,
                records.join(",")
            )
        };
        let full = Json::parse(&doc_for(&zoo)).unwrap();
        let rep = validate(&full).unwrap();
        assert_eq!(rep.bench, "estimators");
        assert_eq!(rep.estimators.len(), 5);
        // Dropping any one zoo member invalidates the document.
        let partial = Json::parse(&doc_for(&zoo[..4])).unwrap();
        let err = validate(&partial).unwrap_err();
        assert!(err.contains("neural-cv"), "{err}");
    }

    #[test]
    fn rejects_non_numeric_timings() {
        let doc = Json::parse(
            r#"{"schema":"lgp.bench.v1","bench":"x","created_unix":1,
                "records":[{"name":"m","backend":"naive","shape":[2],
                            "iters":1,"mean_ns":"fast","p50_ns":1,"p90_ns":1}]}"#,
        )
        .unwrap();
        assert!(validate(&doc).unwrap_err().contains("mean_ns"));
    }

    #[test]
    fn validate_file_reports_io_and_parse_errors() {
        let missing = std::path::Path::new("/nonexistent/BENCH_x.json");
        assert!(validate_file(missing).is_err());
        let dir = std::env::temp_dir().join("lgp_schema_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("BENCH_bad.json");
        std::fs::write(&bad, "{not json").unwrap();
        assert!(validate_file(&bad).unwrap_err().contains("parsing"));
    }
}
