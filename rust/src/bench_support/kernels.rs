//! The backend × shape kernel benchmark suite.
//!
//! Library form of the kernel comparison so `cargo bench --bench hotpath`
//! and the `cargo test` smoke test (`tests/backend_equivalence.rs`) run
//! the exact same code: time `matmul`, `gram_t` and `dot` on every
//! backend available on the host (the portable concrete set plus `simd`
//! on AVX2+FMA machines), and serialize the results as `lgp.bench.v1`
//! records destined for `BENCH_kernels.json` (EXPERIMENTS.md §Benches).

use super::json_out::{bench_doc, BenchRecord};
use super::{bench, Table};
use crate::coordinator::{exec, pool::WorkerPool, reduce};
use crate::tensor::{simd, Backend, Tensor, Workspace};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Suite sizing. `full()` is the trajectory-recording run; `fast()` keeps
/// the whole sweep under ~1s for test-mode runs (`LGP_BENCH_FAST=1`).
#[derive(Clone, Debug)]
pub struct KernelBenchConfig {
    pub warmup: usize,
    pub iters: usize,
    /// (m, k, n) matmul shapes — deliberately including non-multiples of
    /// the register/L1 tile sizes.
    pub matmul_shapes: Vec<(usize, usize, usize)>,
    /// (n, d) gram_t shapes.
    pub gram_shapes: Vec<(usize, usize)>,
    pub dot_lens: Vec<usize>,
}

impl KernelBenchConfig {
    pub fn full() -> KernelBenchConfig {
        KernelBenchConfig {
            warmup: 2,
            iters: 12,
            matmul_shapes: vec![(64, 64, 64), (96, 128, 80), (192, 192, 192), (256, 256, 256)],
            gram_shapes: vec![(128, 64), (256, 96)],
            dot_lens: vec![4096, 65536],
        }
    }

    pub fn fast() -> KernelBenchConfig {
        KernelBenchConfig {
            warmup: 1,
            iters: 3,
            matmul_shapes: vec![(24, 32, 20), (48, 48, 48)],
            gram_shapes: vec![(32, 24)],
            dot_lens: vec![4096],
        }
    }

    /// Honor `LGP_BENCH_FAST` (any value) for test-mode runs.
    pub fn from_env() -> KernelBenchConfig {
        if std::env::var_os("LGP_BENCH_FAST").is_some() {
            KernelBenchConfig::fast()
        } else {
            KernelBenchConfig::full()
        }
    }
}

fn rand_t(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(&mut t.data, 1.0);
    t
}

/// Run the suite: every concrete backend over every configured shape,
/// through the steady-state (`*_into_ws`) entry points the hot paths use —
/// the timed region matches what the trainer actually runs: reused outputs,
/// reused workspace, zero allocation.
pub fn run(cfg: &KernelBenchConfig) -> Vec<BenchRecord> {
    let mut rng = Pcg64::seeded(0xBE7C);
    let mut records = Vec::new();
    let mut ws = Workspace::new();

    for &(m, k, n) in &cfg.matmul_shapes {
        let a = rand_t(&mut rng, &[m, k]);
        let b = rand_t(&mut rng, &[k, n]);
        let mut c = Tensor::zeros(&[m, n]);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        for be in Backend::all() {
            let s = bench(cfg.warmup, cfg.iters, || {
                be.matmul_into_ws(&a, &b, &mut c, &mut ws);
                std::hint::black_box(&c);
            });
            records.push(BenchRecord::from_summary(
                "matmul",
                be.name(),
                &[m, k, n],
                &s,
                Some(flops),
            ));
        }
    }

    for &(rows, d) in &cfg.gram_shapes {
        let a = rand_t(&mut rng, &[rows, d]);
        let mut c = Tensor::zeros(&[d, d]);
        // n rows × d(d+1)/2 upper entries × 2 flops each.
        let flops = rows as f64 * d as f64 * (d + 1) as f64;
        for be in Backend::all() {
            let s = bench(cfg.warmup, cfg.iters, || {
                be.gram_t_into_ws(&a, &mut c, &mut ws);
                std::hint::black_box(&c);
            });
            records.push(BenchRecord::from_summary(
                "gram_t",
                be.name(),
                &[rows, d],
                &s,
                Some(flops),
            ));
        }
    }

    for &len in &cfg.dot_lens {
        let mut a = vec![0.0f32; len];
        let mut b = vec![0.0f32; len];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        for be in Backend::all() {
            let s = bench(cfg.warmup, cfg.iters, || {
                std::hint::black_box(be.dot(&a, &b));
            });
            records.push(BenchRecord::from_summary(
                "dot",
                be.name(),
                &[len],
                &s,
                Some(2.0 * len as f64),
            ));
        }
    }

    records
}

/// Sizing of the sharded-update throughput sweep (ADR-004/ADR-007).
#[derive(Clone, Debug)]
pub struct ShardedBenchConfig {
    pub warmup: usize,
    pub iters: usize,
    /// Micro-batch slots per synthetic update (the paper's accum = 8).
    pub accum: usize,
    /// Square matmul side of the per-slot workload — the update is
    /// square-matmul-dominated, like the device micro-batch it stands for.
    pub n: usize,
    /// Second (accum, n) point with a deliberately *small* per-update
    /// workload, where per-update thread-spawn overhead is a visible
    /// fraction of the update — the cell that shows the persistent pool's
    /// win over `exec::scatter` (ADR-007).
    pub accum_dispatch: usize,
    pub n_dispatch: usize,
    pub shard_counts: Vec<usize>,
}

impl ShardedBenchConfig {
    pub fn full() -> ShardedBenchConfig {
        ShardedBenchConfig {
            warmup: 2,
            iters: 10,
            accum: 8,
            n: 192,
            accum_dispatch: 4,
            n_dispatch: 48,
            shard_counts: vec![1, 2, 4],
        }
    }

    pub fn fast() -> ShardedBenchConfig {
        ShardedBenchConfig {
            warmup: 1,
            iters: 3,
            accum: 4,
            n: 48,
            accum_dispatch: 2,
            n_dispatch: 24,
            shard_counts: vec![1, 2],
        }
    }

    pub fn from_env() -> ShardedBenchConfig {
        if std::env::var_os("LGP_BENCH_FAST").is_some() {
            ShardedBenchConfig::fast()
        } else {
            ShardedBenchConfig::full()
        }
    }

    /// The (accum, n) grid points the sweep times per shard count.
    fn shapes(&self) -> [(usize, usize); 2] {
        [(self.accum, self.n), (self.accum_dispatch, self.n_dispatch)]
    }
}

/// Per-worker state of the synthetic sharded update: a pinned operand,
/// an output slab and a private arena — the same ownership shape as the
/// trainer's `ShardWorker`.
struct ShardedBenchWorker {
    a: Tensor,
    c: Tensor,
    ws: Workspace,
}

/// Build the per-worker state for one synthetic update at side `n`.
fn sharded_workers(rng: &mut Pcg64, count: usize, n: usize) -> Vec<ShardedBenchWorker> {
    (0..count.max(1))
        .map(|_| {
            let mut a = Tensor::zeros(&[n, n]);
            rng.fill_normal(&mut a.data, 1.0);
            ShardedBenchWorker { a, c: Tensor::zeros(&[n, n]), ws: Workspace::new() }
        })
        .collect()
}

/// Sharded-update throughput sweep: one synthetic optimizer update =
/// `accum` square-matmul micro-tasks scattered over the persistent pool
/// (`coordinator::pool`, the session's ADR-007 path — `sharded_update`)
/// and, as the overhead comparison point, over the one-shot scoped-thread
/// executor (`coordinator::exec` — `sharded_update_spawn`), both plus the
/// fixed-topology reduction (`coordinator::reduce`) — timed per shard
/// count × (accum, n) grid point and emitted with the `threads`
/// dimension. At `shards >= 2` the sweep also times the pool's banded
/// single-kernel matmul/gram_t paths (micro and, when the host supports
/// it, simd). Runs the micro backend for the update rows regardless of
/// the calibration probe so the (kernel, backend, shape, threads) cell
/// keys stay stable for the compare gate.
pub fn run_sharded(cfg: &ShardedBenchConfig) -> Vec<BenchRecord> {
    let be = Backend::micro();
    let mut rng = Pcg64::seeded(0x5AAD);
    let mut records = Vec::new();
    for &shards in &cfg.shard_counts {
        // Spawned once per shard count, reused by every timed update —
        // amortization is exactly what the pool rows measure.
        let pool = WorkerPool::new(shards.max(1));
        for (accum, n) in cfg.shapes() {
            let flops = accum as f64 * 2.0 * (n as f64).powi(3);
            let mut workers = sharded_workers(&mut rng, shards.max(1), n);
            let mut acc = vec![0.0f32; n * n];
            let s = bench(cfg.warmup, cfg.iters, || {
                let leaves = pool
                    .scatter(&mut workers, accum, |w, _slot| {
                        be.matmul_into_ws(&w.a, &w.a, &mut w.c, &mut w.ws);
                        Ok(w.c.data.clone())
                    })
                    .expect("synthetic tasks cannot fail");
                let refs: Vec<&[f32]> = leaves.iter().map(|l| l.as_slice()).collect();
                reduce::tree_reduce_into(&mut acc, &refs);
                std::hint::black_box(&acc);
            });
            records.push(
                BenchRecord::from_summary("sharded_update", be.name(), &[accum, n, n], &s, Some(flops))
                    .with_threads(shards),
            );
            let s = bench(cfg.warmup, cfg.iters, || {
                let leaves = exec::scatter(&mut workers, accum, |w, _slot| {
                    be.matmul_into_ws(&w.a, &w.a, &mut w.c, &mut w.ws);
                    Ok(w.c.data.clone())
                })
                .expect("synthetic tasks cannot fail");
                let refs: Vec<&[f32]> = leaves.iter().map(|l| l.as_slice()).collect();
                reduce::tree_reduce_into(&mut acc, &refs);
                std::hint::black_box(&acc);
            });
            records.push(
                BenchRecord::from_summary(
                    "sharded_update_spawn",
                    be.name(),
                    &[accum, n, n],
                    &s,
                    Some(flops),
                )
                .with_threads(shards),
            );
        }
        // Banded single-kernel rows (ADR-007 intra-shard parallelism).
        // Only at shards >= 2: at one thread the pooled entry points
        // delegate to the plain serial kernels, whose cells the kernel
        // suite already emits (duplicate cell keys would fail the index).
        if shards >= 2 {
            let n = cfg.n;
            let a = rand_t(&mut rng, &[n, n]);
            let b = rand_t(&mut rng, &[n, n]);
            let mut c = Tensor::zeros(&[n, n]);
            let mut ws = Workspace::new();
            let mut banded = vec![Backend::micro()];
            if simd::simd_available() {
                banded.push(Backend::simd());
            }
            for kb in banded {
                let s = bench(cfg.warmup, cfg.iters, || {
                    pool.matmul_into_ws(kb, &a, &b, &mut c, &mut ws);
                    std::hint::black_box(&c);
                });
                records.push(
                    BenchRecord::from_summary(
                        "matmul",
                        kb.name(),
                        &[n, n, n],
                        &s,
                        Some(2.0 * (n as f64).powi(3)),
                    )
                    .with_threads(shards),
                );
                let s = bench(cfg.warmup, cfg.iters, || {
                    pool.gram_t_into_ws(kb, &a, &mut c, &mut ws);
                    std::hint::black_box(&c);
                });
                records.push(
                    BenchRecord::from_summary(
                        "gram_t",
                        kb.name(),
                        &[n, n],
                        &s,
                        Some(n as f64 * n as f64 * (n + 1) as f64),
                    )
                    .with_threads(shards),
                );
            }
        }
    }
    records
}

/// Wrap the records in the `lgp.bench.v1` document for
/// `BENCH_kernels.json`.
pub fn doc(records: &[BenchRecord]) -> Json {
    bench_doc("kernels", records, None)
}

/// Fixed-width comparison table for terminal output.
pub fn table(records: &[BenchRecord]) -> Table {
    let mut t = Table::new(&["kernel", "shape", "backend", "thr", "mean", "p90", "GFLOP/s"]);
    for r in records {
        let shape = r
            .shape
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x");
        t.row(vec![
            r.name.clone(),
            shape,
            r.backend.clone(),
            r.threads.to_string(),
            super::fmt_time(r.mean_ns / 1e9),
            super::fmt_time(r.p90_ns / 1e9),
            r.gflops.map_or("-".into(), |g| format!("{g:.2}")),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_suite_covers_all_backends_and_kernels() {
        let records = run(&KernelBenchConfig::fast());
        let mut required = vec!["naive", "blocked", "micro"];
        if simd::simd_available() {
            // The simd rows ride along automatically wherever the host
            // supports AVX2+FMA (Backend::all()).
            required.push("simd");
        }
        for be in required {
            for kernel in ["matmul", "gram_t", "dot"] {
                assert!(
                    records.iter().any(|r| r.backend == be && r.name == kernel),
                    "missing {kernel} on {be}"
                );
            }
        }
        assert!(records.iter().all(|r| r.mean_ns >= 0.0 && r.mean_ns.is_finite()));
        assert!(records.iter().all(|r| r.threads == 1), "kernel rows are single-threaded");
        // doc round-trips through the parser
        let d = doc(&records);
        let reparsed = Json::parse(&d.to_string()).unwrap();
        assert_eq!(
            reparsed.at(&["records"]).as_arr().unwrap().len(),
            records.len()
        );
        table(&records).print();
    }

    #[test]
    fn sharded_suite_sweeps_thread_counts() {
        let cfg = ShardedBenchConfig::fast();
        let records = run_sharded(&cfg);
        // Per shard count: pool + spawn rows at both (accum, n) grid
        // points; banded kernel rows ride along at shards >= 2.
        for name in ["sharded_update", "sharded_update_spawn"] {
            let rows: Vec<_> = records.iter().filter(|r| r.name == name).collect();
            assert_eq!(rows.len(), 2 * cfg.shard_counts.len(), "{name}");
            for &shards in &cfg.shard_counts {
                for (accum, n) in cfg.shapes() {
                    assert!(
                        rows.iter().any(|r| r.threads == shards
                            && r.shape == vec![accum, n, n]
                            && r.mean_ns.is_finite()
                            && r.mean_ns > 0.0),
                        "{name} missing t{shards} {accum}x{n}"
                    );
                }
            }
        }
        // Banded kernel rows: micro always, simd with the host's support,
        // and never at one thread (those cells belong to the kernel suite).
        for kernel in ["matmul", "gram_t"] {
            let rows: Vec<_> = records.iter().filter(|r| r.name == kernel).collect();
            assert!(rows.iter().all(|r| r.threads >= 2), "{kernel} t1 row leaked");
            assert!(
                rows.iter().any(|r| r.backend == "micro"),
                "missing banded {kernel} on micro"
            );
            assert_eq!(
                rows.iter().any(|r| r.backend == "simd"),
                simd::simd_available(),
                "banded {kernel} simd rows must track host support"
            );
        }
        // Mixed with the kernel rows, the combined document still passes
        // schema validation (threads is a first-class dimension).
        let mut all = run(&KernelBenchConfig::fast());
        all.extend(records);
        let d = doc(&all);
        let rep = super::super::schema::validate(&d).unwrap();
        assert_eq!(rep.records, all.len());
    }
}
