//! Optimizers: SGD, SGD+momentum, AdamW, and Muon (the paper's optimizer).
//!
//! All operate on the host-side `ParamStore` given a `FlatGrad` in the
//! same layout. Muon (Jordan et al., 2024) applies momentum + Newton–
//! Schulz orthogonalization to each 2-D hidden-layer matrix (the manifest
//! marks which trunk slots qualify) and falls back to AdamW for
//! everything else (embeddings, LN, biases, head) — the reference Muon
//! setup. Default lr 0.02 follows the paper's Sec. 7.1.

use crate::model::manifest::Manifest;
use crate::model::params::{FlatGrad, ParamStore};
use crate::tensor::{backend, backend::Backend, linalg, Workspace};

/// Hyperparameters shared across optimizers.
#[derive(Clone, Debug)]
pub struct OptimConfig {
    pub lr: f32,
    pub weight_decay: f32,
    pub momentum: f32,
    /// AdamW betas and epsilon.
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Muon: Newton–Schulz iterations and auxiliary AdamW lr for
    /// non-matrix parameters.
    pub ns_steps: usize,
    pub aux_lr: f32,
    /// Tensor backend for Muon's Newton–Schulz matmuls (the coordinator
    /// threads its startup-selected backend through here).
    pub backend: Backend,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig {
            lr: 0.02,
            weight_decay: 0.0,
            momentum: 0.95,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            ns_steps: 5,
            aux_lr: 3e-3,
            backend: backend::active(),
        }
    }
}

/// Optimizer state + step logic.
pub enum Optimizer {
    Sgd {
        cfg: OptimConfig,
    },
    Momentum {
        cfg: OptimConfig,
        velocity: FlatGrad,
    },
    AdamW {
        cfg: OptimConfig,
        m: FlatGrad,
        v: FlatGrad,
        t: u64,
    },
    Muon {
        cfg: OptimConfig,
        /// Momentum buffers for muon-eligible trunk matrices (by layout
        /// index), plus AdamW state for everything else.
        matrix_momentum: Vec<Option<Vec<f32>>>,
        adam_m: FlatGrad,
        adam_v: FlatGrad,
        t: u64,
        /// Scratch arena for the per-matrix Newton–Schulz iteration; after
        /// the first step every update runs allocation-free (ADR-003).
        ws: Workspace,
    },
}

impl Optimizer {
    pub fn new(kind: crate::config::OptimKind, cfg: OptimConfig, params: &ParamStore,
               manifest: &Manifest) -> Optimizer {
        use crate::config::OptimKind::*;
        match kind {
            Sgd => Optimizer::Sgd { cfg },
            Momentum => Optimizer::Momentum { cfg, velocity: FlatGrad::zeros_like(params) },
            AdamW => Optimizer::AdamW {
                cfg,
                m: FlatGrad::zeros_like(params),
                v: FlatGrad::zeros_like(params),
                t: 0,
            },
            Muon => Optimizer::Muon {
                cfg,
                matrix_momentum: manifest
                    .trunk_layout
                    .iter()
                    .map(|p| p.muon.then(|| vec![0.0f32; p.len]))
                    .collect(),
                adam_m: FlatGrad::zeros_like(params),
                adam_v: FlatGrad::zeros_like(params),
                t: 0,
                ws: Workspace::new(),
            },
        }
    }

    /// Apply one update in place.
    pub fn step(&mut self, params: &mut ParamStore, grad: &FlatGrad, manifest: &Manifest) {
        self.step_pooled(params, grad, manifest, None);
    }

    /// [`step`](Optimizer::step) with Muon's Newton–Schulz matmuls
    /// optionally banded across a persistent worker pool (ADR-007). The
    /// pooled path is bit-identical to the serial one (backend banding
    /// contract), so estimator/shard determinism is unaffected; every
    /// other optimizer ignores the pool.
    pub fn step_pooled(
        &mut self,
        params: &mut ParamStore,
        grad: &FlatGrad,
        manifest: &Manifest,
        pool: Option<&crate::coordinator::pool::WorkerPool>,
    ) {
        match self {
            Optimizer::Sgd { cfg } => {
                sgd_update(&mut params.trunk, &grad.trunk, cfg);
                sgd_update(&mut params.head_w, &grad.head_w, cfg);
                sgd_update(&mut params.head_b, &grad.head_b, cfg);
            }
            Optimizer::Momentum { cfg, velocity } => {
                momentum_update(&mut params.trunk, &grad.trunk, &mut velocity.trunk, cfg);
                momentum_update(&mut params.head_w, &grad.head_w, &mut velocity.head_w, cfg);
                momentum_update(&mut params.head_b, &grad.head_b, &mut velocity.head_b, cfg);
            }
            Optimizer::AdamW { cfg, m, v, t } => {
                *t += 1;
                adamw_update(&mut params.trunk, &grad.trunk, &mut m.trunk, &mut v.trunk, *t, cfg, cfg.lr);
                adamw_update(&mut params.head_w, &grad.head_w, &mut m.head_w, &mut v.head_w, *t, cfg, cfg.lr);
                adamw_update(&mut params.head_b, &grad.head_b, &mut m.head_b, &mut v.head_b, *t, cfg, cfg.lr);
            }
            Optimizer::Muon { cfg, matrix_momentum, adam_m, adam_v, t, ws } => {
                *t += 1;
                // Matrix params: momentum -> Newton-Schulz -> scaled step.
                // All per-matrix temporaries come from the optimizer's own
                // workspace arena, so a warmed step never allocates.
                for (i, p) in manifest.trunk_layout.iter().enumerate() {
                    if let Some(buf) = &mut matrix_momentum[i] {
                        let g = &grad.trunk[p.offset..p.offset + p.len];
                        for (b, gv) in buf.iter_mut().zip(g) {
                            *b = cfg.momentum * *b + gv;
                        }
                        let (rows, cols) = (p.shape[0], p.shape[1]);
                        // Nesterov-style blend as in the Muon reference.
                        let mut gm = ws.take_tensor(&[rows, cols]);
                        for ((o, b), gv) in gm.data.iter_mut().zip(buf.iter()).zip(g) {
                            *o = cfg.momentum * *b + gv;
                        }
                        let mut o = ws.take_tensor(&[rows, cols]);
                        match pool {
                            Some(p) => linalg::newton_schulz_into_with(
                                cfg.backend,
                                |a, b, c, ws| p.matmul_into_ws(cfg.backend, a, b, c, ws),
                                &gm,
                                cfg.ns_steps,
                                &mut o,
                                ws,
                            ),
                            None => linalg::newton_schulz_into(
                                cfg.backend,
                                &gm,
                                cfg.ns_steps,
                                &mut o,
                                ws,
                            ),
                        }
                        // Muon's shape-aware scale: sqrt(max(1, rows/cols)).
                        let scale = (rows as f32 / cols as f32).max(1.0).sqrt();
                        let slice = &mut params.trunk[p.offset..p.offset + p.len];
                        for (w, u) in slice.iter_mut().zip(&o.data) {
                            *w -= cfg.lr * scale * u + cfg.lr * cfg.weight_decay * *w;
                        }
                        ws.give_tensor(gm);
                        ws.give_tensor(o);
                    }
                }
                // Non-matrix trunk params: AdamW at the auxiliary lr.
                for (i, p) in manifest.trunk_layout.iter().enumerate() {
                    if matrix_momentum[i].is_none() {
                        let range = p.offset..p.offset + p.len;
                        adamw_update(
                            &mut params.trunk[range.clone()],
                            &grad.trunk[range.clone()],
                            &mut adam_m.trunk[range.clone()],
                            &mut adam_v.trunk[range],
                            *t,
                            cfg,
                            cfg.aux_lr,
                        );
                    }
                }
                // Head: AdamW (Muon reference excludes the classifier head).
                adamw_update(&mut params.head_w, &grad.head_w, &mut adam_m.head_w,
                             &mut adam_v.head_w, *t, cfg, cfg.aux_lr);
                adamw_update(&mut params.head_b, &grad.head_b, &mut adam_m.head_b,
                             &mut adam_v.head_b, *t, cfg, cfg.aux_lr);
            }
        }
    }
}

fn sgd_update(w: &mut [f32], g: &[f32], cfg: &OptimConfig) {
    for (wi, gi) in w.iter_mut().zip(g) {
        *wi -= cfg.lr * (gi + cfg.weight_decay * *wi);
    }
}

fn momentum_update(w: &mut [f32], g: &[f32], v: &mut [f32], cfg: &OptimConfig) {
    for ((wi, gi), vi) in w.iter_mut().zip(g).zip(v.iter_mut()) {
        *vi = cfg.momentum * *vi + gi;
        *wi -= cfg.lr * (*vi + cfg.weight_decay * *wi);
    }
}

fn adamw_update(w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], t: u64,
                cfg: &OptimConfig, lr: f32) {
    let bc1 = 1.0 - cfg.beta1.powi(t as i32);
    let bc2 = 1.0 - cfg.beta2.powi(t as i32);
    for (((wi, gi), mi), vi) in w.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut()) {
        *mi = cfg.beta1 * *mi + (1.0 - cfg.beta1) * gi;
        *vi = cfg.beta2 * *vi + (1.0 - cfg.beta2) * gi * gi;
        let mhat = *mi / bc1;
        let vhat = *vi / bc2;
        *wi -= lr * (mhat / (vhat.sqrt() + cfg.eps) + cfg.weight_decay * *wi);
    }
}

/// Learning-rate schedules for the budget loop.
#[derive(Clone, Copy, Debug)]
pub enum Schedule {
    Constant,
    /// Linear warmup for `warmup` steps then cosine decay to `floor` x lr
    /// over `total` steps.
    WarmupCosine { warmup: usize, total: usize, floor: f32 },
}

impl Schedule {
    pub fn factor(&self, step: usize) -> f32 {
        match *self {
            Schedule::Constant => 1.0,
            Schedule::WarmupCosine { warmup, total, floor } => {
                if step < warmup {
                    (step + 1) as f32 / warmup.max(1) as f32
                } else {
                    let p = ((step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32)
                        .min(1.0);
                    floor + (1.0 - floor) * 0.5 * (1.0 + (std::f32::consts::PI * p).cos())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::TrunkParam;

    /// Minimal manifest stand-in: two trunk params, one muon matrix.
    fn tiny_setup() -> (ParamStore, Manifest) {
        let layout = vec![
            TrunkParam { name: "w".into(), shape: vec![4, 3], offset: 0, len: 12, muon: true },
            TrunkParam { name: "b".into(), shape: vec![3], offset: 12, len: 3, muon: false },
        ];
        let manifest = Manifest {
            dir: ".".into(),
            preset: "test".into(),
            image: 8,
            classes: 2,
            width: 3,
            label_smoothing: 0.05,
            rank: 2,
            n_chunk: 4,
            n_fit: 8,
            feat_dim: 12,
            trunk_params: 15,
            total_params: 15 + 6 + 2,
            micro_batch: 8,
            fs: vec![0.25],
            val_batch: 8,
            trunk_layout: layout,
            artifacts: {
                let mut m = std::collections::BTreeMap::new();
                m.insert(
                    "x".into(),
                    crate::model::manifest::ArtifactMeta {
                        name: "x".into(),
                        file: "x".into(),
                        args: vec![],
                        outs: vec![],
                    },
                );
                m
            },
            init_trunk: ".".into(),
            init_head_w: ".".into(),
            init_head_b: ".".into(),
        };
        let params = ParamStore {
            trunk: (0..15).map(|i| 0.1 * i as f32).collect(),
            head_w: vec![0.05; 6],
            head_b: vec![0.0; 2],
            width: 3,
            classes: 2,
        };
        (params, manifest)
    }

    fn const_grad(p: &ParamStore, v: f32) -> FlatGrad {
        let mut g = FlatGrad::zeros_like(p);
        g.trunk.fill(v);
        g.head_w.fill(v);
        g.head_b.fill(v);
        g
    }

    #[test]
    fn sgd_moves_against_gradient() {
        let (mut p, m) = tiny_setup();
        let before = p.trunk.clone();
        let mut opt = Optimizer::new(crate::config::OptimKind::Sgd,
                                     OptimConfig { lr: 0.1, ..Default::default() }, &p, &m);
        let g = const_grad(&p, 1.0);
        opt.step(&mut p, &g, &m);
        for (a, b) in p.trunk.iter().zip(&before) {
            assert!((a - (b - 0.1)).abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_accumulates() {
        let (mut p, m) = tiny_setup();
        let w0 = p.trunk[0];
        let mut opt = Optimizer::new(crate::config::OptimKind::Momentum,
                                     OptimConfig { lr: 0.1, momentum: 0.9, ..Default::default() },
                                     &p, &m);
        let g = const_grad(&p, 1.0);
        opt.step(&mut p, &g, &m);
        let step1 = w0 - p.trunk[0];
        opt.step(&mut p, &g, &m);
        let step2 = w0 - step1 - p.trunk[0];
        assert!(step2 > step1, "momentum should accelerate: {step1} vs {step2}");
    }

    #[test]
    fn adamw_step_is_scale_invariant_at_start() {
        // With bias correction, the first AdamW step is ~lr regardless of
        // gradient magnitude.
        let (p0, m) = tiny_setup();
        for &scale in &[1e-3f32, 1.0, 1e3] {
            let mut p = p0.clone();
            let w0 = p.trunk[0];
            let mut opt = Optimizer::new(crate::config::OptimKind::AdamW,
                                         OptimConfig { lr: 0.01, ..Default::default() }, &p, &m);
            let g = const_grad(&p, scale);
            opt.step(&mut p, &g, &m);
            let step = (w0 - p.trunk[0]).abs();
            assert!((step - 0.01).abs() < 1e-3, "scale {scale}: step {step}");
        }
    }

    #[test]
    fn adamw_weight_decay_shrinks_weights() {
        let (mut p, m) = tiny_setup();
        p.trunk.fill(1.0);
        let mut opt = Optimizer::new(
            crate::config::OptimKind::AdamW,
            OptimConfig { lr: 0.01, weight_decay: 0.1, ..Default::default() }, &p, &m);
        let g = const_grad(&p, 0.0);
        opt.step(&mut p, &g, &m);
        assert!(p.trunk.iter().all(|&w| w < 1.0 && w > 0.99 - 0.01));
    }

    #[test]
    fn muon_updates_matrix_with_unit_scale_step() {
        let (mut p, m) = tiny_setup();
        let before = p.trunk.clone();
        let mut opt = Optimizer::new(crate::config::OptimKind::Muon,
                                     OptimConfig { lr: 0.02, ..Default::default() }, &p, &m);
        let mut g = const_grad(&p, 0.0);
        // gradient only on the muon matrix
        for v in g.trunk[..12].iter_mut() {
            *v = 0.5;
        }
        opt.step(&mut p, &g, &m);
        // Matrix entries moved...
        assert!(p.trunk[..12].iter().zip(&before[..12]).any(|(a, b)| a != b));
        // ...by an orthogonalized (rank-1 here -> normalized) update whose
        // per-entry magnitude is bounded by lr * sqrt(rows/cols).
        for (a, b) in p.trunk[..12].iter().zip(&before[..12]) {
            assert!((a - b).abs() <= 0.02 * (4.0f32 / 3.0).sqrt() * 1.3 + 1e-6);
        }
        // Non-matrix slot got (tiny) AdamW update only where grad nonzero: zero grad -> no move.
        for (a, b) in p.trunk[12..].iter().zip(&before[12..]) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn schedule_shapes() {
        let s = Schedule::WarmupCosine { warmup: 10, total: 110, floor: 0.1 };
        assert!(s.factor(0) < s.factor(5));
        assert!((s.factor(9) - 1.0).abs() < 0.11);
        assert!(s.factor(10) >= s.factor(60));
        assert!(s.factor(60) > s.factor(109));
        assert!((s.factor(1000) - 0.1).abs() < 1e-4);
        assert_eq!(Schedule::Constant.factor(12345), 1.0);
    }
}
