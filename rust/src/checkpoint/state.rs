//! Component codecs for the session checkpoint sections (ADR-008).
//!
//! One encode/decode pair per stateful training component. Decoders write
//! *into* an existing object built by the normal construction path
//! (`SessionBuilder::build`, `Optimizer::new`, …) and verify shapes
//! against it — a checkpoint can never resize a component, only refill
//! it. All functions are also the contract surface for the host-level
//! kill-and-resume tests (`tests/checkpoint_resume.rs`), which round-trip
//! every estimator in the zoo through them without artifacts.

use super::{Dec, Enc};
use crate::estimator::GradientEstimator;
use crate::model::params::{FlatGrad, ParamStore};
use crate::optim::Optimizer;
use crate::predictor::fit::FitBuffer;
use crate::predictor::Predictor;
use anyhow::{bail, ensure, Result};

/// Section names of the session checkpoint artifact.
pub const META: &str = "meta";
pub const PARAMS: &str = "params";
pub const OPTIM: &str = "optim";
pub const PREDICTOR: &str = "predictor";
pub const FITBUF: &str = "fitbuf";
pub const ESTIMATOR: &str = "estimator";
pub const DATA: &str = "data";

// -- params -----------------------------------------------------------------

pub fn encode_params(p: &ParamStore) -> Vec<u8> {
    let mut e = Enc::new();
    e.put_f32s(&p.trunk);
    e.put_f32s(&p.head_w);
    e.put_f32s(&p.head_b);
    e.into_bytes()
}

pub fn decode_params(p: &mut ParamStore, bytes: &[u8]) -> Result<()> {
    let mut d = Dec::new(bytes, PARAMS);
    let trunk = d.take_f32s()?;
    let head_w = d.take_f32s()?;
    let head_b = d.take_f32s()?;
    ensure!(
        trunk.len() == p.trunk.len()
            && head_w.len() == p.head_w.len()
            && head_b.len() == p.head_b.len(),
        "checkpoint params sized ({}, {}, {}) but the model has ({}, {}, {})",
        trunk.len(),
        head_w.len(),
        head_b.len(),
        p.trunk.len(),
        p.head_w.len(),
        p.head_b.len()
    );
    p.trunk = trunk;
    p.head_w = head_w;
    p.head_b = head_b;
    d.finish()
}

// -- optimizer --------------------------------------------------------------

fn put_flat(e: &mut Enc, g: &FlatGrad) {
    e.put_f32s(&g.trunk);
    e.put_f32s(&g.head_w);
    e.put_f32s(&g.head_b);
}

fn take_flat_into(d: &mut Dec, g: &mut FlatGrad, what: &str) -> Result<()> {
    let trunk = d.take_f32s()?;
    let head_w = d.take_f32s()?;
    let head_b = d.take_f32s()?;
    ensure!(
        trunk.len() == g.trunk.len()
            && head_w.len() == g.head_w.len()
            && head_b.len() == g.head_b.len(),
        "checkpoint {what} buffer shape mismatch"
    );
    g.trunk = trunk;
    g.head_w = head_w;
    g.head_b = head_b;
    Ok(())
}

fn optim_tag(o: &Optimizer) -> u8 {
    match o {
        Optimizer::Sgd { .. } => 0,
        Optimizer::Momentum { .. } => 1,
        Optimizer::AdamW { .. } => 2,
        Optimizer::Muon { .. } => 3,
    }
}

/// Serialize the optimizer *state* (moments, step counters). Hyper-
/// parameters and scratch workspaces are rebuilt from config, not stored.
pub fn encode_optimizer(o: &Optimizer) -> Vec<u8> {
    let mut e = Enc::new();
    e.put_u8(optim_tag(o));
    match o {
        Optimizer::Sgd { .. } => {}
        Optimizer::Momentum { velocity, .. } => put_flat(&mut e, velocity),
        Optimizer::AdamW { m, v, t, .. } => {
            e.put_u64(*t);
            put_flat(&mut e, m);
            put_flat(&mut e, v);
        }
        Optimizer::Muon { matrix_momentum, adam_m, adam_v, t, .. } => {
            e.put_u64(*t);
            e.put_u64(matrix_momentum.len() as u64);
            for slot in matrix_momentum {
                match slot {
                    None => e.put_bool(false),
                    Some(buf) => {
                        e.put_bool(true);
                        e.put_f32s(buf);
                    }
                }
            }
            put_flat(&mut e, adam_m);
            put_flat(&mut e, adam_v);
        }
    }
    e.into_bytes()
}

pub fn decode_optimizer(o: &mut Optimizer, bytes: &[u8]) -> Result<()> {
    let mut d = Dec::new(bytes, OPTIM);
    let tag = d.take_u8()?;
    ensure!(
        tag == optim_tag(o),
        "checkpoint optimizer kind (tag {tag}) differs from the configured one (tag {})",
        optim_tag(o)
    );
    match o {
        Optimizer::Sgd { .. } => {}
        Optimizer::Momentum { velocity, .. } => take_flat_into(&mut d, velocity, "velocity")?,
        Optimizer::AdamW { m, v, t, .. } => {
            *t = d.take_u64()?;
            take_flat_into(&mut d, m, "adam m")?;
            take_flat_into(&mut d, v, "adam v")?;
        }
        Optimizer::Muon { matrix_momentum, adam_m, adam_v, t, .. } => {
            *t = d.take_u64()?;
            let n = d.take_u64()? as usize;
            ensure!(
                n == matrix_momentum.len(),
                "checkpoint muon layout has {n} trunk slots, manifest has {}",
                matrix_momentum.len()
            );
            for (i, slot) in matrix_momentum.iter_mut().enumerate() {
                let present = d.take_bool()?;
                match (present, slot.as_mut()) {
                    (false, None) => {}
                    (true, Some(buf)) => {
                        let vals = d.take_f32s()?;
                        ensure!(
                            vals.len() == buf.len(),
                            "checkpoint muon momentum {i} has {} values, expected {}",
                            vals.len(),
                            buf.len()
                        );
                        *buf = vals;
                    }
                    _ => bail!("checkpoint muon-eligibility of trunk slot {i} changed"),
                }
            }
            take_flat_into(&mut d, adam_m, "muon adam m")?;
            take_flat_into(&mut d, adam_v, "muon adam v")?;
        }
    }
    d.finish()
}

// -- predictor --------------------------------------------------------------

pub fn encode_predictor(p: &Predictor) -> Vec<u8> {
    let mut e = Enc::new();
    e.put_u64(p.fits as u64);
    e.put_f32s(&p.u.data);
    e.put_f32s(&p.b.data);
    e.into_bytes()
}

/// Restore (U, B, fits). Bumps `version` so device-resident copies are
/// invalidated and re-uploaded on the next use.
pub fn decode_predictor(p: &mut Predictor, bytes: &[u8]) -> Result<()> {
    let mut d = Dec::new(bytes, PREDICTOR);
    let fits = d.take_u64()? as usize;
    let u = d.take_f32s()?;
    let b = d.take_f32s()?;
    ensure!(
        u.len() == p.u.data.len() && b.len() == p.b.data.len(),
        "checkpoint predictor sized (U {}, B {}) but session has (U {}, B {})",
        u.len(),
        b.len(),
        p.u.data.len(),
        p.b.data.len()
    );
    p.u.data = u;
    p.b.data = b;
    p.fits = fits;
    p.version += 1;
    d.finish()
}

// -- fit buffer -------------------------------------------------------------

/// Serialize the ring in *logical* order (0 = oldest): the physical
/// head/slot layout is an implementation detail, and a restore via
/// `clear` + `push` is bit-equivalent because all reads go through the
/// logical accessors.
pub fn encode_fitbuf(buf: &FitBuffer) -> Vec<u8> {
    let mut e = Enc::new();
    e.put_u64(buf.capacity as u64);
    e.put_u64(buf.len() as u64);
    if !buf.is_empty() {
        let d = buf.h(0).len();
        e.put_u64(d as u64);
        for i in 0..buf.len() {
            e.put_f32s(buf.grad(i));
            e.put_f32s(&buf.a1(i)[..d]);
            e.put_f32s(buf.h(i));
        }
    }
    e.into_bytes()
}

pub fn decode_fitbuf(buf: &mut FitBuffer, bytes: &[u8]) -> Result<()> {
    let mut dec = Dec::new(bytes, FITBUF);
    let capacity = dec.take_u64()? as usize;
    ensure!(
        capacity == buf.capacity,
        "checkpoint fit buffer capacity {capacity} differs from session's {}",
        buf.capacity
    );
    let len = dec.take_u64()? as usize;
    buf.clear();
    if len > 0 {
        let d = dec.take_u64()? as usize;
        for i in 0..len {
            let grad = dec.take_f32s()?;
            let a = dec.take_f32s()?;
            let h = dec.take_f32s()?;
            ensure!(
                a.len() == d && h.len() == d,
                "checkpoint fit buffer row {i} has widths (a {}, h {}), expected {d}",
                a.len(),
                h.len()
            );
            buf.push(&grad, &a, &h);
        }
    }
    dec.finish()
}

// -- estimator --------------------------------------------------------------

/// Wrap an estimator's own [`GradientEstimator::save_state`] payload with
/// its name, so resuming under a different estimator kind fails with a
/// clear diagnostic instead of a garbled decode.
pub fn encode_estimator(est: &dyn GradientEstimator) -> Vec<u8> {
    let mut e = Enc::new();
    e.put_str(est.name());
    e.put_vec(&est.save_state());
    e.into_bytes()
}

pub fn decode_estimator(est: &mut dyn GradientEstimator, bytes: &[u8]) -> Result<()> {
    let mut d = Dec::new(bytes, ESTIMATOR);
    let name = d.take_str()?;
    ensure!(
        name == est.name(),
        "checkpoint was written by estimator '{name}', session runs '{}'",
        est.name()
    );
    let payload = d.take_vec()?;
    d.finish()?;
    est.load_state(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::{ControlVariate, MultiTangentForward, TrueBackprop};
    use crate::util::rng::Pcg64;

    fn dummy_params(rng: &mut Pcg64) -> ParamStore {
        let mut p = ParamStore {
            trunk: vec![0.0; 24],
            head_w: vec![0.0; 12],
            head_b: vec![0.0; 3],
            width: 4,
            classes: 3,
        };
        rng.fill_normal(&mut p.trunk, 1.0);
        rng.fill_normal(&mut p.head_w, 1.0);
        rng.fill_normal(&mut p.head_b, 1.0);
        p
    }

    #[test]
    fn params_round_trip_bitwise() {
        let mut rng = Pcg64::seeded(1);
        let p = dummy_params(&mut rng);
        let mut q = dummy_params(&mut rng);
        decode_params(&mut q, &encode_params(&p)).unwrap();
        assert_eq!(p.trunk, q.trunk);
        assert_eq!(p.head_w, q.head_w);
        assert_eq!(p.head_b, q.head_b);
    }

    #[test]
    fn params_shape_mismatch_rejected() {
        let mut rng = Pcg64::seeded(2);
        let p = dummy_params(&mut rng);
        let mut small = p.clone();
        small.trunk.truncate(10);
        let err = decode_params(&mut small, &encode_params(&p)).unwrap_err();
        assert!(format!("{err:#}").contains("sized"), "{err:#}");
    }

    #[test]
    fn fitbuf_round_trip_preserves_logical_rows_through_ring_wrap() {
        let mut rng = Pcg64::seeded(3);
        let mut buf = FitBuffer::new(4);
        // Push 6 rows into capacity 4 so the ring wraps.
        for _ in 0..6 {
            let mut g = vec![0.0f32; 10];
            let mut a = vec![0.0f32; 3];
            let mut h = vec![0.0f32; 3];
            rng.fill_normal(&mut g, 1.0);
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut h, 1.0);
            buf.push(&g, &a, &h);
        }
        let bytes = encode_fitbuf(&buf);
        let mut back = FitBuffer::new(4);
        decode_fitbuf(&mut back, &bytes).unwrap();
        assert_eq!(back.len(), buf.len());
        for i in 0..buf.len() {
            assert_eq!(back.grad(i), buf.grad(i), "row {i}");
            assert_eq!(back.a1(i), buf.a1(i), "row {i}");
            assert_eq!(back.h(i), buf.h(i), "row {i}");
        }
        // Re-encode from the restored buffer: byte-identical.
        assert_eq!(encode_fitbuf(&back), bytes);
        // Capacity mismatch is rejected.
        let mut wrong = FitBuffer::new(8);
        assert!(decode_fitbuf(&mut wrong, &bytes).is_err());
    }

    #[test]
    fn empty_fitbuf_round_trips() {
        let buf = FitBuffer::new(5);
        let mut back = FitBuffer::new(5);
        // Pre-fill then confirm restore empties it.
        back.push(&[1.0], &[2.0], &[3.0]);
        decode_fitbuf(&mut back, &encode_fitbuf(&buf)).unwrap();
        assert!(back.is_empty());
    }

    #[test]
    fn estimator_wrapper_names_must_match() {
        let cv = ControlVariate::new(0.25);
        let bytes = encode_estimator(&cv);
        let mut mtf = MultiTangentForward::new(4, 0);
        let err = decode_estimator(&mut mtf, &bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("control-variate") && msg.contains("multi-tangent"), "{msg}");
    }

    #[test]
    fn stateless_estimator_rejects_unexpected_payload() {
        let mut tb = TrueBackprop;
        let mut e = Enc::new();
        e.put_str("true-backprop");
        e.put_vec(&[1, 2, 3]);
        let err = decode_estimator(&mut tb, &e.into_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("no checkpoint state"), "{err:#}");
    }

    #[test]
    fn predictor_restore_bumps_version() {
        let mut rng = Pcg64::seeded(4);
        let mut p = Predictor::new(20, 4, 2);
        rng.fill_normal(&mut p.u.data, 1.0);
        rng.fill_normal(&mut p.b.data, 1.0);
        p.fits = 3;
        let bytes = encode_predictor(&p);
        let mut q = Predictor::new(20, 4, 2);
        let v0 = q.version;
        decode_predictor(&mut q, &bytes).unwrap();
        assert_eq!(q.fits, 3);
        assert_eq!(q.u.data, p.u.data);
        assert_eq!(q.b.data, p.b.data);
        assert!(q.version > v0, "device copies must be invalidated");
        // Wrong rank -> size mismatch.
        let mut wrong = Predictor::new(20, 4, 3);
        assert!(decode_predictor(&mut wrong, &bytes).is_err());
    }
}
