//! Checkpoint resharding (`lgp reshard`, DESIGN.md ADR-010).
//!
//! Rewrites a `.lgpckpt` artifact for a run whose worker/process geometry
//! is changing (N → M shards, or a different `--procs` split of the same
//! slots). ADR-004 makes training state *shard-neutral* by construction —
//! the fit ring is stored in logical row order, the data stream as a bare
//! cursor, params/optimizer/estimator as flat tensors, and the ADR-008
//! fingerprint deliberately excludes `shards` — so the rewrite is a
//! *validating identity*: every section is decoded through its codec
//! (every CRC checked), the geometry-touching sections (FITBUF, DATA) are
//! re-derived through a full decode/encode cycle, and the output must
//! come out byte-identical to the input. Any divergence means the format
//! has drifted into shard-dependence, and the tool hard-errors instead of
//! writing a subtly wrong artifact. The value of the operation is the
//! *proof*: after `lgp reshard`, resuming the artifact under the new
//! geometry is known-safe, not assumed-safe.

use super::{state as ckstate, Checkpoint, Dec};
use crate::predictor::fit::FitBuffer;
use anyhow::{ensure, Context as _, Result};
use std::path::{Path, PathBuf};

/// What [`reshard_file`] validated and wrote.
#[derive(Debug)]
pub struct ReshardReport {
    /// Optimizer updates captured by the artifact (stamps the filename).
    pub step: u64,
    /// Logical rows carried by the fit ring.
    pub fitbuf_rows: usize,
    /// Data-stream cursor (examples consumed so far).
    pub cursor: u64,
    /// Sections decoded and validated.
    pub sections: usize,
    /// Output artifact path.
    pub path: PathBuf,
    /// Output artifact size.
    pub bytes: usize,
}

/// Validate `input` end-to-end and write the re-derived artifact into
/// `out_dir` (atomically, ADR-008 tmp+fsync+rename), asserting the
/// rewrite is byte-stable. `from`/`to` are the old and new shard counts —
/// recorded for the operator; the artifact itself carries no shard count,
/// which is exactly the invariant this tool verifies.
pub fn reshard_file(
    input: &Path,
    out_dir: &Path,
    from: usize,
    to: usize,
) -> Result<ReshardReport> {
    ensure!(from >= 1 && to >= 1, "shard counts must be >= 1 (got {from} -> {to})");
    let bytes = std::fs::read(input)
        .with_context(|| format!("reading checkpoint {}", input.display()))?;
    let ck = Checkpoint::decode(&bytes)
        .with_context(|| format!("decoding checkpoint {}", input.display()))?;

    // META leads with the step counter; the rest belongs to the session.
    let step = Dec::new(ck.section(ckstate::META)?, ckstate::META).take_u64()?;

    // DATA: positional stream state (ADR-004). A cursor is valid under
    // any shard count because slot -> stream position is a pure function
    // of (cursor, slot index), independent of which worker computes it.
    let data_in = ck.section(ckstate::DATA)?;
    let mut data = Dec::new(data_in, ckstate::DATA);
    let _seed = data.take_u64()?;
    let cursor = data.take_u64()?;
    data.finish()?;

    // FITBUF: run the ring through a full decode/encode cycle at the
    // capacity the section records. Logical row order is the on-disk
    // order, so repartitioning rows across M shard segments changes
    // nothing — and if that ever stops being true, byte-stability here
    // is the tripwire.
    let fb_in = ck.section(ckstate::FITBUF)?;
    let capacity = Dec::new(fb_in, ckstate::FITBUF).take_u64()? as usize;
    let mut ring = FitBuffer::new(capacity);
    ckstate::decode_fitbuf(&mut ring, fb_in)?;
    let fb_out = ckstate::encode_fitbuf(&ring);
    ensure!(
        fb_out.as_slice() == fb_in,
        "fit-ring re-encode diverged ({} -> {} bytes): the checkpoint \
         format has become shard-dependent — refusing to reshard",
        fb_in.len(),
        fb_out.len()
    );

    // Rebuild the container section-for-section (same order, same
    // fingerprint — `shards` is excluded from the fingerprint, so the
    // resharded artifact resumes under the new geometry) and require
    // byte-identity with the input.
    let mut out = Checkpoint::new(ck.fingerprint);
    let mut sections = 0usize;
    for name in ck.section_names().map(str::to_string).collect::<Vec<_>>() {
        out.add(&name, ck.section(&name)?.to_vec());
        sections += 1;
    }
    let out_bytes = out.encode();
    ensure!(
        out_bytes == bytes,
        "checkpoint re-encode diverged from the input artifact — refusing \
         to reshard"
    );

    let path = super::write_atomic(out_dir, &super::file_name(step), &out_bytes)?;
    crate::log_info!(
        "reshard: {} ({from} shards) -> {} ({to} shards): {sections} sections, \
         {} fit rows, cursor {cursor}, step {step}",
        input.display(),
        path.display(),
        ring.len(),
    );
    Ok(ReshardReport {
        step,
        fitbuf_rows: ring.len(),
        cursor,
        sections,
        path,
        bytes: out_bytes.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Enc;
    use crate::util::rng::Pcg64;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lgp_reshard_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// A synthetic but codec-faithful artifact: real META/DATA/FITBUF
    /// payloads, opaque bytes for the sections reshard copies verbatim.
    fn synth_artifact(step: u64, cursor: u64, rows: usize) -> Vec<u8> {
        let mut rng = Pcg64::seeded(step ^ cursor);
        let mut ring = FitBuffer::new(4);
        for _ in 0..rows {
            let mut g = vec![0.0f32; 10];
            let mut a = vec![0.0f32; 3];
            let mut h = vec![0.0f32; 3];
            rng.fill_normal(&mut g, 1.0);
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut h, 1.0);
            ring.push(&g, &a, &h);
        }
        let mut ck = Checkpoint::new(0xfeed);
        let mut meta = Enc::new();
        meta.put_u64(step);
        ck.add(ckstate::META, meta.into_bytes());
        ck.add(ckstate::PARAMS, vec![1, 2, 3, 4]);
        ck.add(ckstate::OPTIM, vec![5, 6]);
        ck.add(ckstate::FITBUF, ckstate::encode_fitbuf(&ring));
        let mut data = Enc::new();
        data.put_u64(7);
        data.put_u64(cursor);
        ck.add(ckstate::DATA, data.into_bytes());
        ck.encode()
    }

    #[test]
    fn reshard_is_a_validated_byte_identity() {
        let dir = temp_dir("identity");
        let input = dir.join("in.lgpckpt");
        let bytes = synth_artifact(12, 640, 6);
        std::fs::write(&input, &bytes).unwrap();
        let out_dir = dir.join("out");
        let report = reshard_file(&input, &out_dir, 2, 8).unwrap();
        assert_eq!(report.step, 12);
        assert_eq!(report.cursor, 640);
        assert_eq!(report.fitbuf_rows, 4, "ring capacity 4, 6 pushed");
        assert_eq!(report.sections, 5);
        assert_eq!(report.path, out_dir.join(crate::checkpoint::file_name(12)));
        let out = std::fs::read(&report.path).unwrap();
        assert_eq!(out, bytes, "reshard must be byte-stable");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reshard_rejects_corrupt_and_invalid_inputs() {
        let dir = temp_dir("corrupt");
        let out_dir = dir.join("out");
        // Bit flip in the body -> some section CRC fails.
        let mut bytes = synth_artifact(3, 64, 2);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let input = dir.join("bad.lgpckpt");
        std::fs::write(&input, &bytes).unwrap();
        assert!(reshard_file(&input, &out_dir, 1, 2).is_err());
        // Not a checkpoint at all.
        let junk = dir.join("junk.lgpckpt");
        std::fs::write(&junk, b"not a checkpoint").unwrap();
        let err = reshard_file(&junk, &out_dir, 1, 2).unwrap_err();
        assert!(format!("{err:#}").contains("decoding"), "{err:#}");
        // Degenerate shard counts.
        let good = dir.join("good.lgpckpt");
        std::fs::write(&good, synth_artifact(1, 8, 1)).unwrap();
        assert!(reshard_file(&good, &out_dir, 0, 2).is_err());
        assert!(reshard_file(&good, &out_dir, 2, 0).is_err());
        assert!(out_dir.join(crate::checkpoint::file_name(3)).try_exists().map_or(true, |e| !e));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
