//! Fault-injection shim for the atomic-write protocol (ADR-008).
//!
//! Compiled only under `cfg(test)` or the `fault-inject` feature; release
//! builds carry none of this. The armed plan is **thread-local** so
//! parallel test threads (cargo's default) cannot interfere with each
//! other: arm a fault, call [`super::write_atomic`] on the same thread,
//! then [`disarm`].
//!
//! Fault semantics:
//! - [`Fault::ShortWrite`] — the tmp file receives only a prefix, then the
//!   process "dies" (torn tmp file on disk; never retried).
//! - [`Fault::ENospc`] — the next `times` write attempts fail with a
//!   transient IO error; the bounded retry loop is expected to absorb a
//!   small number of these.
//! - [`Fault::Kill`] — simulated process death at a precise point in the
//!   write → fsync → rename sequence; surfaces as a non-retried error
//!   leaving the directory exactly as a real crash would.

use std::cell::RefCell;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KillPoint {
    AfterTmpWrite,
    AfterTmpSync,
    AfterRename,
}

#[derive(Clone, Copy, Debug)]
pub enum Fault {
    ShortWrite { bytes: usize },
    ENospc { times: usize },
    Kill(KillPoint),
}

pub(super) enum WriteAction {
    Proceed,
    Error(std::io::Error),
    ShortThenKill(usize),
}

thread_local! {
    static PLAN: RefCell<Option<Fault>> = const { RefCell::new(None) };
}

/// Arm one fault for subsequent writes on this thread.
pub fn arm(f: Fault) {
    PLAN.with(|p| *p.borrow_mut() = Some(f));
}

/// Clear any armed fault.
pub fn disarm() {
    PLAN.with(|p| *p.borrow_mut() = None);
}

/// Consulted once per write attempt, before the payload hits the tmp file.
pub(super) fn on_write(_len: usize) -> WriteAction {
    PLAN.with(|p| {
        let mut plan = p.borrow_mut();
        match *plan {
            Some(Fault::ShortWrite { bytes }) => {
                *plan = None;
                WriteAction::ShortThenKill(bytes)
            }
            Some(Fault::ENospc { times }) if times > 0 => {
                *plan = if times == 1 { None } else { Some(Fault::ENospc { times: times - 1 }) };
                WriteAction::Error(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "injected ENOSPC: no space left on device",
                ))
            }
            _ => WriteAction::Proceed,
        }
    })
}

/// True when an armed kill-point matches `point` (consumes the plan).
pub(super) fn kill_at(point: KillPoint) -> bool {
    PLAN.with(|p| {
        let mut plan = p.borrow_mut();
        if let Some(Fault::Kill(k)) = *plan {
            if k == point {
                *plan = None;
                return true;
            }
        }
        false
    })
}
