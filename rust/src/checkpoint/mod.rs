//! Crash-safe checkpoint artifacts (DESIGN.md ADR-008).
//!
//! One checkpoint is one file: a versioned binary container with a magic
//! header, a config/manifest fingerprint, and named sections each guarded
//! by a CRC32. Writes go through a tmp-file + fsync + atomic-rename
//! protocol so a crash at any instant leaves the directory either with the
//! previous valid artifact or with the new one — never with a torn file
//! under the final name. Loads scan the directory newest-first and fall
//! back past corrupt or truncated artifacts; a *valid* artifact whose
//! fingerprint disagrees with the running config is a hard error (resuming
//! it would silently be a different experiment).
//!
//! The resume-bit-identity contract this container serves: a run
//! checkpointed at step `k` and resumed must be bit-identical from step
//! `k+1` onward to the uninterrupted run (`tests/checkpoint_resume.rs`).
//! Everything positional (data stream, tangent seeds, NCV fit RNG) is a
//! pure function of `(seed, position)` per ADR-004, so the data section
//! stores only the cursor; the mutable state (params, optimizer moments,
//! FitBuffer ring, predictor factors, estimator internals, loss EMA) is
//! serialized exactly.

use anyhow::{bail, ensure, Context as _, Result};
use std::io::Write as _;
use std::path::{Path, PathBuf};

pub mod reshard;
pub mod state;

#[cfg(any(test, feature = "fault-inject"))]
pub mod fault;

/// File magic: identifies the container format before any parsing.
pub const MAGIC: [u8; 8] = *b"LGPCKPT\0";

/// Bumped on any incompatible layout change; readers reject unknown
/// versions instead of guessing.
pub const FORMAT_VERSION: u32 = 1;

/// Extension for checkpoint artifacts (`ckpt-<step:08>.lgpckpt`).
pub const FILE_EXT: &str = "lgpckpt";

/// Attempts for one atomic write before giving up on transient IO errors.
const WRITE_ATTEMPTS: u32 = 3;

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3 polynomial, hand-rolled — no external crates, ADR-002)
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC32 of `bytes` (the `cksum`/zlib polynomial, reflected).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

/// FNV-1a 64-bit over `key=value` pairs: the config/manifest fingerprint
/// stamped into every artifact. Covers only behavior-affecting knobs —
/// `shards` is deliberately absent (the stream is bit-identical across
/// shard counts, ADR-004), as are output/budget/checkpoint knobs.
pub fn fingerprint_of(parts: &[(&str, String)]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for (k, v) in parts {
        mix(k.as_bytes());
        mix(b"=");
        mix(v.as_bytes());
        mix(b"\n");
    }
    h
}

// ---------------------------------------------------------------------------
// Little-endian byte codec
// ---------------------------------------------------------------------------

/// Append-only little-endian encoder for section payloads.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed f32 slice (u64 count + raw LE words).
    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed UTF-8 string (u32 byte count).
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed raw byte blob (u64 count).
    pub fn put_vec(&mut self, bytes: &[u8]) {
        self.put_u64(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }
}

/// Decoder over one section payload; every error names the section so a
/// bad checkpoint diagnoses itself.
pub struct Dec<'a> {
    bytes: &'a [u8],
    pos: usize,
    section: &'a str,
}

impl<'a> Dec<'a> {
    pub fn new(bytes: &'a [u8], section: &'a str) -> Dec<'a> {
        Dec { bytes, pos: 0, section }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.bytes.len(),
            "checkpoint section '{}' truncated: need {} bytes at offset {}, have {}",
            self.section,
            n,
            self.pos,
            self.bytes.len() - self.pos
        );
        let out = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn take_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn take_bool(&mut self) -> Result<bool> {
        match self.take_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => bail!("checkpoint section '{}': bad bool byte {v}", self.section),
        }
    }

    pub fn take_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn take_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn take_u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    pub fn take_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn take_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn take_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.take_u64()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| {
            anyhow::anyhow!("checkpoint section '{}': f32 slice length overflow", self.section)
        })?)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn take_vec(&mut self) -> Result<Vec<u8>> {
        let n = self.take_u64()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn take_str(&mut self) -> Result<String> {
        let n = self.take_u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| anyhow::anyhow!("checkpoint section '{}': invalid UTF-8", self.section))
    }

    /// Assert the payload was fully consumed — trailing bytes mean the
    /// writer and reader disagree about the section layout.
    pub fn finish(self) -> Result<()> {
        ensure!(
            self.pos == self.bytes.len(),
            "checkpoint section '{}': {} trailing bytes after decode",
            self.section,
            self.bytes.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Container
// ---------------------------------------------------------------------------

/// A decoded (or to-be-encoded) checkpoint: a fingerprint plus named,
/// CRC-guarded sections.
///
/// Layout (all integers little-endian):
///
/// ```text
/// magic[8] | version u32 | fingerprint u64 | section_count u32 | header_crc u32
/// then per section:
///   name_len u32 | name bytes | payload_len u64 | section_crc u32 | payload
/// ```
///
/// `header_crc` covers everything before it, so a bit flip anywhere in the
/// header (including the fingerprint) reads as *corrupt* — recoverable by
/// falling back to an older artifact — rather than as a spurious
/// fingerprint mismatch, which is a hard error by design. `section_crc`
/// covers the name bytes and the payload.
pub struct Checkpoint {
    pub fingerprint: u64,
    sections: Vec<(String, Vec<u8>)>,
}

impl Checkpoint {
    pub fn new(fingerprint: u64) -> Checkpoint {
        Checkpoint { fingerprint, sections: Vec::new() }
    }

    pub fn add(&mut self, name: &str, payload: Vec<u8>) {
        self.sections.push((name.to_string(), payload));
    }

    pub fn section(&self, name: &str) -> Result<&[u8]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p.as_slice())
            .ok_or_else(|| anyhow::anyhow!("checkpoint has no '{name}' section"))
    }

    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.iter().map(|(n, _)| n.as_str())
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            24 + self.sections.iter().map(|(n, p)| 16 + n.len() + p.len()).sum::<usize>(),
        );
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&self.fingerprint.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        let header_crc = crc32(&out);
        out.extend_from_slice(&header_crc.to_le_bytes());
        for (name, payload) in &self.sections {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            let mut crc_input = Vec::with_capacity(name.len() + payload.len());
            crc_input.extend_from_slice(name.as_bytes());
            crc_input.extend_from_slice(payload);
            out.extend_from_slice(&crc32(&crc_input).to_le_bytes());
            out.extend_from_slice(payload);
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        ensure!(bytes.len() >= 28, "checkpoint truncated: {} bytes (header is 28)", bytes.len());
        ensure!(bytes[..8] == MAGIC, "not a checkpoint: bad magic");
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        ensure!(
            version == FORMAT_VERSION,
            "unsupported checkpoint format version {version} (this build reads {FORMAT_VERSION})"
        );
        let fingerprint = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        let count = u32::from_le_bytes(bytes[20..24].try_into().unwrap()) as usize;
        let header_crc = u32::from_le_bytes(bytes[24..28].try_into().unwrap());
        ensure!(crc32(&bytes[..24]) == header_crc, "checkpoint header corrupt (crc mismatch)");

        let mut d = Dec::new(&bytes[28..], "container");
        let mut sections = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = d.take_u32()? as usize;
            let name_raw = d.take(name_len)?;
            let name = std::str::from_utf8(name_raw)
                .map_err(|_| anyhow::anyhow!("checkpoint section name is not UTF-8"))?
                .to_string();
            let payload_len = d.take_u64()? as usize;
            let want_crc = d.take_u32()?;
            let payload = d
                .take(payload_len)
                .with_context(|| format!("checkpoint section '{name}'"))?;
            let mut crc_input = Vec::with_capacity(name.len() + payload.len());
            crc_input.extend_from_slice(name.as_bytes());
            crc_input.extend_from_slice(payload);
            ensure!(
                crc32(&crc_input) == want_crc,
                "checkpoint section '{name}' corrupt (crc mismatch)"
            );
            sections.push((name, payload.to_vec()));
        }
        d.finish().context("checkpoint container")?;
        Ok(Checkpoint { fingerprint, sections })
    }
}

// ---------------------------------------------------------------------------
// Atomic write protocol + recovery scan
// ---------------------------------------------------------------------------

/// Canonical artifact name for step `step`. Zero-padded so lexical order
/// equals numeric order in directory listings.
pub fn file_name(step: u64) -> String {
    format!("ckpt-{step:08}.{FILE_EXT}")
}

/// Inverse of [`file_name`]; `None` for anything else (tmp files, foreign
/// files) so the recovery scan skips them.
pub fn parse_step(name: &str) -> Option<u64> {
    let rest = name.strip_prefix("ckpt-")?;
    let digits = rest.strip_suffix(&format!(".{FILE_EXT}"))?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

enum ProtoErr {
    /// Transient IO failure — eligible for retry.
    Io(std::io::Error),
    /// Injected crash: the process is "dead"; never retried, and the
    /// directory is left exactly as a real kill at that instant would.
    #[cfg_attr(not(any(test, feature = "fault-inject")), allow(dead_code))]
    Kill(&'static str),
}

/// Write `bytes` to `dir/file_name` via tmp + fsync + rename + dir-fsync.
/// Transient IO errors get bounded retry with backoff; injected
/// kill-points abort immediately (simulating process death).
pub fn write_atomic(dir: &Path, file_name: &str, bytes: &[u8]) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let final_path = dir.join(file_name);
    let tmp_path = dir.join(format!(".{file_name}.tmp"));
    let mut last_err = None;
    for attempt in 0..WRITE_ATTEMPTS {
        if attempt > 0 {
            std::thread::sleep(std::time::Duration::from_millis(5 << attempt));
        }
        match write_once(&tmp_path, &final_path, dir, bytes) {
            Ok(()) => return Ok(final_path),
            Err(ProtoErr::Kill(point)) => {
                bail!("checkpoint write killed by injected fault ({point})")
            }
            Err(ProtoErr::Io(e)) => last_err = Some(e),
        }
    }
    let _ = std::fs::remove_file(&tmp_path);
    Err(anyhow::anyhow!(
        "writing checkpoint {} failed after {WRITE_ATTEMPTS} attempts: {}",
        final_path.display(),
        last_err.expect("retry loop ran")
    ))
}

fn write_once(tmp: &Path, dst: &Path, dir: &Path, bytes: &[u8]) -> Result<(), ProtoErr> {
    let mut f = std::fs::File::create(tmp).map_err(ProtoErr::Io)?;
    #[cfg(any(test, feature = "fault-inject"))]
    match fault::on_write(bytes.len()) {
        fault::WriteAction::Proceed => {}
        fault::WriteAction::Error(e) => return Err(ProtoErr::Io(e)),
        fault::WriteAction::ShortThenKill(n) => {
            let _ = f.write_all(&bytes[..n.min(bytes.len())]);
            let _ = f.sync_all();
            return Err(ProtoErr::Kill("short tmp write"));
        }
    }
    f.write_all(bytes).map_err(ProtoErr::Io)?;
    #[cfg(any(test, feature = "fault-inject"))]
    if fault::kill_at(fault::KillPoint::AfterTmpWrite) {
        return Err(ProtoErr::Kill("after tmp write"));
    }
    f.sync_all().map_err(ProtoErr::Io)?;
    #[cfg(any(test, feature = "fault-inject"))]
    if fault::kill_at(fault::KillPoint::AfterTmpSync) {
        return Err(ProtoErr::Kill("after tmp fsync"));
    }
    drop(f);
    std::fs::rename(tmp, dst).map_err(ProtoErr::Io)?;
    #[cfg(any(test, feature = "fault-inject"))]
    if fault::kill_at(fault::KillPoint::AfterRename) {
        return Err(ProtoErr::Kill("after rename"));
    }
    // Durability for the rename itself. Best-effort: a failed directory
    // fsync does not undo an already-visible rename.
    #[cfg(unix)]
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    #[cfg(not(unix))]
    let _ = dir;
    Ok(())
}

/// A checkpoint recovered from disk.
pub struct Loaded {
    pub step: u64,
    pub path: PathBuf,
    pub ckpt: Checkpoint,
}

/// Scan `dir` for the newest loadable checkpoint. Corrupt, truncated, or
/// unreadable artifacts are skipped with a warning (torn-write fallback);
/// a *valid* artifact with the wrong fingerprint is a hard error. Returns
/// `Ok(None)` when the directory has no artifacts at all.
pub fn load_latest(dir: &Path, expect_fingerprint: u64) -> Result<Option<Loaded>> {
    if !dir.exists() {
        return Ok(None);
    }
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("scanning checkpoint dir {}", dir.display()))?;
    let mut found: Vec<(u64, PathBuf)> = Vec::new();
    for entry in entries {
        let entry = entry.with_context(|| format!("scanning checkpoint dir {}", dir.display()))?;
        let name = entry.file_name();
        if let Some(step) = name.to_str().and_then(parse_step) {
            found.push((step, entry.path()));
        }
    }
    found.sort_by(|a, b| b.0.cmp(&a.0));
    for (step, path) in found {
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) => {
                crate::log_warn!("skipping unreadable checkpoint {}: {e}", path.display());
                continue;
            }
        };
        match Checkpoint::decode(&bytes) {
            Ok(ckpt) => {
                ensure!(
                    ckpt.fingerprint == expect_fingerprint,
                    "checkpoint {} was written by an incompatible run \
                     (fingerprint {:016x}, expected {:016x}) — refusing to resume",
                    path.display(),
                    ckpt.fingerprint,
                    expect_fingerprint
                );
                return Ok(Some(Loaded { step, path, ckpt }));
            }
            Err(e) => {
                crate::log_warn!("skipping corrupt checkpoint {}: {e:#}", path.display());
            }
        }
    }
    Ok(None)
}

/// Retention policy (`--checkpoint-keep K`): after a successful write,
/// delete artifacts beyond the newest `keep` *valid* ones. Invariants:
///
/// - the artifact at `just_wrote` is never deleted (it counts as valid
///   without re-reading it — it was just written through the atomic
///   protocol);
/// - torn/corrupt artifacts never count toward `keep` (they would pin
///   the window with files [`load_latest`] can only skip) and are pruned
///   *after* every excess valid artifact, oldest first — once a newer
///   valid artifact exists they serve no recovery purpose;
/// - deletion order is oldest-first, so an interruption mid-prune always
///   leaves the newest state intact.
///
/// `keep == 0` disables retention entirely. Returns the number of files
/// removed. Foreign files and `.tmp` droppings are left alone
/// ([`parse_step`] skips them).
pub fn prune_keep(dir: &Path, keep: usize, just_wrote: &Path) -> Result<usize> {
    if keep == 0 {
        return Ok(0);
    }
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("scanning checkpoint dir {}", dir.display()))?;
    let mut valid: Vec<(u64, PathBuf)> = Vec::new();
    let mut torn: Vec<(u64, PathBuf)> = Vec::new();
    for entry in entries {
        let entry = entry.with_context(|| format!("scanning checkpoint dir {}", dir.display()))?;
        let name = entry.file_name();
        let Some(step) = name.to_str().and_then(parse_step) else {
            continue;
        };
        let path = entry.path();
        if path == just_wrote {
            valid.push((step, path));
            continue;
        }
        let ok = std::fs::read(&path).map_or(false, |b| Checkpoint::decode(&b).is_ok());
        if ok {
            valid.push((step, path));
        } else {
            torn.push((step, path));
        }
    }
    valid.sort_by(|a, b| b.0.cmp(&a.0)); // newest first: [..keep] is the window
    let excess = if valid.len() > keep { valid.split_off(keep) } else { Vec::new() };
    torn.sort_by(|a, b| a.0.cmp(&b.0)); // oldest first
    let mut removed = 0usize;
    // Excess valid artifacts first (oldest first), torn last.
    for (_, path) in excess.iter().rev().chain(torn.iter()) {
        if path == just_wrote {
            continue;
        }
        std::fs::remove_file(path)
            .with_context(|| format!("pruning old checkpoint {}", path.display()))?;
        removed += 1;
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lgp_ckpt_{tag}_{:?}", std::thread::current().id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample(fp: u64) -> Checkpoint {
        let mut ck = Checkpoint::new(fp);
        let mut e = Enc::new();
        e.put_u64(42);
        e.put_f64(0.25);
        e.put_f32s(&[1.0, -2.5, 3.25]);
        e.put_str("hello");
        ck.add("alpha", e.into_bytes());
        ck.add("beta", vec![9, 8, 7, 6, 5]);
        ck
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard check value for "123456789" under CRC-32/ISO-HDLC.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn container_round_trips_and_reencodes_identically() {
        let ck = sample(0xdead_beef);
        let bytes = ck.encode();
        let back = Checkpoint::decode(&bytes).unwrap();
        assert_eq!(back.fingerprint, 0xdead_beef);
        assert_eq!(back.section("beta").unwrap(), &[9, 8, 7, 6, 5]);
        let mut d = Dec::new(back.section("alpha").unwrap(), "alpha");
        assert_eq!(d.take_u64().unwrap(), 42);
        assert_eq!(d.take_f64().unwrap(), 0.25);
        assert_eq!(d.take_f32s().unwrap(), vec![1.0, -2.5, 3.25]);
        assert_eq!(d.take_str().unwrap(), "hello");
        d.finish().unwrap();
        assert_eq!(back.encode(), bytes, "re-encode must be byte-identical");
    }

    #[test]
    fn every_corrupt_byte_is_rejected_or_detected() {
        // Flipping any single byte must never produce a silently-wrong
        // decode: either the decode errors, or (for a payload-length or
        // structural flip) it errors with truncation. Nothing decodes to
        // different section contents without complaint.
        let bytes = sample(7).encode();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(
                Checkpoint::decode(&bad).is_err(),
                "byte {i} flipped but decode succeeded"
            );
        }
    }

    #[test]
    fn corrupt_payload_names_the_section() {
        let ck = sample(7);
        let bytes = ck.encode();
        // Corrupt the last byte: inside the final ("beta") payload.
        let mut bad = bytes.clone();
        *bad.last_mut().unwrap() ^= 0xff;
        let err = format!("{:#}", Checkpoint::decode(&bad).unwrap_err());
        assert!(err.contains("'beta'"), "diagnostic should name the section: {err}");
        assert!(err.contains("crc mismatch"), "{err}");
    }

    #[test]
    fn truncation_is_detected() {
        let bytes = sample(7).encode();
        for cut in [0, 5, 27, 30, bytes.len() - 1] {
            assert!(Checkpoint::decode(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn file_names_sort_lexically_by_step() {
        assert_eq!(file_name(6), "ckpt-00000006.lgpckpt");
        assert_eq!(parse_step("ckpt-00000006.lgpckpt"), Some(6));
        assert_eq!(parse_step("ckpt-12345678.lgpckpt"), Some(12_345_678));
        assert_eq!(parse_step(".ckpt-00000006.lgpckpt.tmp"), None);
        assert_eq!(parse_step("params.lgpckpt"), None);
        assert!(file_name(6) < file_name(10));
    }

    #[test]
    fn write_then_load_latest_round_trips() {
        let dir = scratch("roundtrip");
        for step in [2u64, 6, 4] {
            let mut ck = sample(11);
            let mut e = Enc::new();
            e.put_u64(step);
            ck.add("step", e.into_bytes());
            write_atomic(&dir, &file_name(step), &ck.encode()).unwrap();
        }
        let loaded = load_latest(&dir, 11).unwrap().expect("artifacts present");
        assert_eq!(loaded.step, 6, "newest-by-step wins");
        let mut d = Dec::new(loaded.ckpt.section("step").unwrap(), "step");
        assert_eq!(d.take_u64().unwrap(), 6);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_latest_empty_and_missing_dir() {
        let dir = scratch("empty");
        assert!(load_latest(&dir, 0).unwrap().is_none(), "missing dir");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(load_latest(&dir, 0).unwrap().is_none(), "empty dir");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_latest_falls_back_past_corrupt_newest() {
        let dir = scratch("fallback");
        write_atomic(&dir, &file_name(4), &sample(11).encode()).unwrap();
        // Newest artifact is torn: truncate a valid encoding.
        let bytes = sample(11).encode();
        std::fs::write(dir.join(file_name(8)), &bytes[..bytes.len() / 2]).unwrap();
        let loaded = load_latest(&dir, 11).unwrap().expect("older artifact valid");
        assert_eq!(loaded.step, 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_mismatch_is_a_hard_error() {
        let dir = scratch("fpmismatch");
        write_atomic(&dir, &file_name(3), &sample(11).encode()).unwrap();
        let err = format!("{:#}", load_latest(&dir, 99).unwrap_err());
        assert!(err.contains("incompatible run"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_of_is_order_and_content_sensitive() {
        let a = fingerprint_of(&[("k", "1".into()), ("j", "2".into())]);
        let b = fingerprint_of(&[("j", "2".into()), ("k", "1".into())]);
        let c = fingerprint_of(&[("k", "1".into()), ("j", "3".into())]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, fingerprint_of(&[("k", "1".into()), ("j", "2".into())]));
    }

    // -- fault-injection suite: no kill-point between write and rename may
    //    leave the directory without a loadable valid artifact -------------

    /// After a simulated crash at any point, the directory must still
    /// resolve to `want_step` (or `None`) via the normal recovery scan.
    fn assert_recovers_to(dir: &Path, fp: u64, want_step: Option<u64>) {
        let got = load_latest(dir, fp).unwrap().map(|l| l.step);
        assert_eq!(got, want_step);
    }

    #[test]
    fn kill_points_never_lose_the_previous_artifact() {
        for kp in [
            fault::KillPoint::AfterTmpWrite,
            fault::KillPoint::AfterTmpSync,
            fault::KillPoint::AfterRename,
        ] {
            let dir = scratch(&format!("kill_{kp:?}"));
            // A previous good checkpoint at step 3.
            write_atomic(&dir, &file_name(3), &sample(11).encode()).unwrap();
            fault::arm(fault::Fault::Kill(kp));
            let err = write_atomic(&dir, &file_name(6), &sample(11).encode()).unwrap_err();
            fault::disarm();
            assert!(format!("{err:#}").contains("killed"), "{err:#}");
            // AfterRename: the new artifact is already visible; earlier
            // kills must fall back to step 3. Either way the dir has a
            // loadable valid artifact.
            let want = if kp == fault::KillPoint::AfterRename { Some(6) } else { Some(3) };
            assert_recovers_to(&dir, 11, want);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn kill_on_first_ever_write_leaves_dir_recoverably_empty() {
        for kp in [fault::KillPoint::AfterTmpWrite, fault::KillPoint::AfterTmpSync] {
            let dir = scratch(&format!("killfirst_{kp:?}"));
            fault::arm(fault::Fault::Kill(kp));
            let _ = write_atomic(&dir, &file_name(1), &sample(11).encode());
            fault::disarm();
            // Only a tmp file may exist; the scan sees no artifacts.
            assert_recovers_to(&dir, 11, None);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn short_write_leaves_only_a_torn_tmp_file() {
        let dir = scratch("short");
        write_atomic(&dir, &file_name(2), &sample(11).encode()).unwrap();
        fault::arm(fault::Fault::ShortWrite { bytes: 10 });
        let err = write_atomic(&dir, &file_name(5), &sample(11).encode()).unwrap_err();
        fault::disarm();
        assert!(format!("{err:#}").contains("short tmp write"), "{err:#}");
        assert_recovers_to(&dir, 11, Some(2));
        // The torn bytes live under the tmp name, never the final name.
        assert!(dir.join(format!(".{}.tmp", file_name(5))).exists());
        assert!(!dir.join(file_name(5)).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn transient_enospc_is_absorbed_by_retry() {
        let dir = scratch("enospc");
        fault::arm(fault::Fault::ENospc { times: 2 });
        let path = write_atomic(&dir, &file_name(9), &sample(11).encode()).unwrap();
        fault::disarm();
        assert!(path.exists());
        assert_recovers_to(&dir, 11, Some(9));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistent_enospc_exhausts_retries_with_a_path_naming_error() {
        let dir = scratch("enospc_hard");
        fault::arm(fault::Fault::ENospc { times: 1000 });
        let err = write_atomic(&dir, &file_name(9), &sample(11).encode()).unwrap_err();
        fault::disarm();
        let msg = format!("{err:#}");
        assert!(msg.contains("after 3 attempts"), "{msg}");
        assert!(msg.contains(&file_name(9)), "{msg}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_keep_retains_newest_valid_and_drops_torn_last() {
        let dir = scratch("prune");
        let bytes = sample(11).encode();
        let mut last = PathBuf::new();
        for step in [1u64, 2, 3, 4, 5] {
            last = write_atomic(&dir, &file_name(step), &bytes).unwrap();
        }
        // A torn artifact *newer* than everything valid: it must neither
        // count toward the window nor survive the prune.
        std::fs::write(dir.join(file_name(6)), &bytes[..10]).unwrap();

        // keep = 0 disables retention entirely.
        assert_eq!(prune_keep(&dir, 0, &last).unwrap(), 0);
        assert!(dir.join(file_name(1)).exists());

        // keep = 2: valid steps 1-3 and the torn 6 go; 4 and 5 stay.
        assert_eq!(prune_keep(&dir, 2, &last).unwrap(), 4);
        for gone in [1u64, 2, 3, 6] {
            assert!(!dir.join(file_name(gone)).exists(), "step {gone} must be pruned");
        }
        assert!(dir.join(file_name(4)).exists());
        assert!(dir.join(file_name(5)).exists());
        // The survivor set is exactly what recovery sees.
        assert_recovers_to(&dir, 11, Some(5));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_keep_never_deletes_the_artifact_just_written() {
        let dir = scratch("prune_self");
        let bytes = sample(11).encode();
        let p4 = write_atomic(&dir, &file_name(4), &bytes).unwrap();
        write_atomic(&dir, &file_name(5), &bytes).unwrap();
        // Pathological call: the just-written artifact is *outside* the
        // newest-1 window (a clock-skewed or replayed step number). The
        // excess scan must still skip it.
        assert_eq!(prune_keep(&dir, 1, &p4).unwrap(), 0);
        assert!(dir.join(file_name(4)).exists(), "just-written artifact is untouchable");
        assert!(dir.join(file_name(5)).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_keep_ignores_foreign_files_and_tmp_droppings() {
        let dir = scratch("prune_foreign");
        let bytes = sample(11).encode();
        let mut last = PathBuf::new();
        for step in [1u64, 2, 3] {
            last = write_atomic(&dir, &file_name(step), &bytes).unwrap();
        }
        std::fs::write(dir.join("notes.txt"), b"keep me").unwrap();
        std::fs::write(dir.join(format!(".{}.tmp", file_name(9))), b"torn tmp").unwrap();
        assert_eq!(prune_keep(&dir, 1, &last).unwrap(), 2);
        assert!(dir.join("notes.txt").exists());
        assert!(dir.join(format!(".{}.tmp", file_name(9))).exists());
        assert!(dir.join(file_name(3)).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
