//! Runtime-selectable tensor backends (DESIGN.md §2, ADR-001).
//!
//! Every dense hot path in the reproduction — the predictor-fit Gram
//! matrices, the U materialization dots, Muon's Newton–Schulz matmuls —
//! funnels through the [`TensorBackend`] trait so the kernel strategy is
//! an extension point instead of a hardcoded loop nest:
//!
//! - [`NaiveBackend`] — the textbook ijk kernels, moved here verbatim from
//!   the old `matmul.rs` test oracle. Slow, obviously correct; every other
//!   backend is property-tested against it (`tests/backend_equivalence.rs`).
//! - [`BlockedBackend`] — the cache-aware ikj / j-tiled kernels that were
//!   previously the only implementation.
//! - [`MicroBackend`] — register-tiled 4-row kernels: the inner loop keeps
//!   four output-row accumulators live so each B row loaded from L1 is
//!   reused four times, and the unrolled multiply–add chains are
//!   FMA/auto-vectorization friendly.
//!
//! Selection is by [`BackendKind`] (`--backend` CLI flag / `backend` config
//! key); `Auto` runs a one-shot [`calibrate`] probe at startup and pins the
//! fastest backend for the process. The chosen backend is held in a global
//! the free functions in `tensor::matmul` dispatch through, and is also
//! threaded explicitly (as a [`Backend`] handle) through the predictor fit,
//! the Muon optimizer and the coordinator so call sites can pin a backend
//! independently of the global (the equivalence tests and benches do).

use super::Tensor;
use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// The dense kernels the reproduction's hot paths need. Implementations
/// may assume shape-checked inputs: the [`Backend`] handle validates before
/// dispatching.
pub trait TensorBackend: Sync {
    /// Stable lowercase identifier (appears in bench JSON and logs).
    fn name(&self) -> &'static str;

    /// Dot product of equal-length slices (the stats reduction feeding the
    /// Gram matrices and `matvec`).
    fn dot(&self, a: &[f32], b: &[f32]) -> f32;

    /// C = A @ B into a pre-allocated, zeroed-by-the-kernel output.
    fn matmul_into(&self, a: &Tensor, b: &Tensor, c: &mut Tensor);

    /// C = A^T @ A for A: (n, d) -> (d, d).
    fn gram_t(&self, a: &Tensor) -> Tensor;

    /// K = A @ A^T for A: (n, d) -> (n, n). Default: symmetric row-dot
    /// fill using this backend's `dot`.
    fn gram(&self, a: &Tensor) -> Tensor {
        let (n, d) = (a.rows(), a.cols());
        let mut k = Tensor::zeros(&[n, n]);
        for i in 0..n {
            let ri = &a.data[i * d..(i + 1) * d];
            for j in i..n {
                let rj = &a.data[j * d..(j + 1) * d];
                let dot = self.dot(ri, rj);
                k.data[i * n + j] = dot;
                k.data[j * n + i] = dot;
            }
        }
        k
    }
}

// ---------------------------------------------------------------------------
// Reference kernels (the correctness oracle)
// ---------------------------------------------------------------------------

/// Textbook ijk kernels. The equivalence proptests and the other backends'
/// unit tests all compare against this implementation.
pub struct NaiveBackend;

impl TensorBackend for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut s = 0.0f32;
        for (x, y) in a.iter().zip(b) {
            s += x * y;
        }
        s
    }

    fn matmul_into(&self, a: &Tensor, b: &Tensor, c: &mut Tensor) {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += a.at(i, kk) * b.at(kk, j);
                }
                c.set(i, j, s);
            }
        }
    }

    fn gram_t(&self, a: &Tensor) -> Tensor {
        let (n, d) = (a.rows(), a.cols());
        let mut c = Tensor::zeros(&[d, d]);
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0f32;
                for row in 0..n {
                    s += a.at(row, i) * a.at(row, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }
}

// ---------------------------------------------------------------------------
// Blocked kernels (the previous hardcoded implementation, moved here)
// ---------------------------------------------------------------------------

/// Cache-aware ikj loop order with an L1-sized j-tile. The inner j-loop is
/// a contiguous axpy over B's row and C's row, which auto-vectorizes.
pub struct BlockedBackend;

const BLOCKED_JT: usize = 256;

/// One ikj/j-tiled output row: c_row += a_row @ B. Shared by the blocked
/// kernel and the micro kernel's remainder rows.
fn blocked_row(a_row: &[f32], b: &Tensor, c_row: &mut [f32], n: usize) {
    for j0 in (0..n).step_by(BLOCKED_JT) {
        let j1 = (j0 + BLOCKED_JT).min(n);
        for (kk, &aik) in a_row.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let b_row = &b.data[kk * n + j0..kk * n + j1];
            let c_seg = &mut c_row[j0..j1];
            for (cv, bv) in c_seg.iter_mut().zip(b_row) {
                *cv += aik * bv;
            }
        }
    }
}

impl TensorBackend for BlockedBackend {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        super::stats::dot(a, b)
    }

    fn matmul_into(&self, a: &Tensor, b: &Tensor, c: &mut Tensor) {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        c.data.fill(0.0);
        for i in 0..m {
            let a_row = &a.data[i * k..(i + 1) * k];
            let c_row = &mut c.data[i * n..(i + 1) * n];
            blocked_row(a_row, b, c_row, n);
        }
    }

    fn gram_t(&self, a: &Tensor) -> Tensor {
        let (n, d) = (a.rows(), a.cols());
        let mut c = Tensor::zeros(&[d, d]);
        for row in 0..n {
            let r = &a.data[row * d..(row + 1) * d];
            for i in 0..d {
                let ri = r[i];
                if ri == 0.0 {
                    continue;
                }
                let c_row = &mut c.data[i * d..(i + 1) * d];
                for j in i..d {
                    c_row[j] += ri * r[j];
                }
            }
        }
        mirror_upper(&mut c, d);
        c
    }
}

fn mirror_upper(c: &mut Tensor, d: usize) {
    for i in 0..d {
        for j in 0..i {
            c.data[i * d + j] = c.data[j * d + i];
        }
    }
}

// ---------------------------------------------------------------------------
// Register-tiled micro kernels (new)
// ---------------------------------------------------------------------------

/// Register-tiled kernels: 4 output rows per pass with 4-wide accumulator
/// chains. Each B row fetched from cache feeds four C rows, quartering B
/// traffic versus the blocked kernel; the dense (no zero-skip) inner loop
/// keeps the multiply–add chains straight-line for the vectorizer.
pub struct MicroBackend;

const MICRO_JT: usize = 512;
const MICRO_MR: usize = 4;

/// The 4-row register-tiled block: c[0..4] += a_rows[0..4] @ B over one
/// j-tile at a time.
#[allow(clippy::too_many_arguments)]
fn micro_block4(
    ar0: &[f32],
    ar1: &[f32],
    ar2: &[f32],
    ar3: &[f32],
    b: &Tensor,
    c_block: &mut [f32],
    k: usize,
    n: usize,
) {
    let (c0, rest) = c_block.split_at_mut(n);
    let (c1, rest) = rest.split_at_mut(n);
    let (c2, c3) = rest.split_at_mut(n);
    for j0 in (0..n).step_by(MICRO_JT) {
        let j1 = (j0 + MICRO_JT).min(n);
        let w = j1 - j0;
        let s0 = &mut c0[j0..j1];
        let s1 = &mut c1[j0..j1];
        let s2 = &mut c2[j0..j1];
        let s3 = &mut c3[j0..j1];
        for kk in 0..k {
            let (a0, a1, a2, a3) = (ar0[kk], ar1[kk], ar2[kk], ar3[kk]);
            let b_row = &b.data[kk * n + j0..kk * n + j1];
            for idx in 0..w {
                let bv = b_row[idx];
                s0[idx] += a0 * bv;
                s1[idx] += a1 * bv;
                s2[idx] += a2 * bv;
                s3[idx] += a3 * bv;
            }
        }
    }
}

impl TensorBackend for MicroBackend {
    fn name(&self) -> &'static str {
        "micro"
    }

    /// 8-accumulator unrolled dot (wider than the blocked 4-way; the extra
    /// chains hide FMA latency on longer reductions).
    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 8;
        let mut acc = [0.0f32; 8];
        for i in 0..chunks {
            let j = i * 8;
            for (lane, s) in acc.iter_mut().enumerate() {
                *s += a[j + lane] * b[j + lane];
            }
        }
        let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
            + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for j in chunks * 8..n {
            s += a[j] * b[j];
        }
        s
    }

    fn matmul_into(&self, a: &Tensor, b: &Tensor, c: &mut Tensor) {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        c.data.fill(0.0);
        let full_blocks = m / MICRO_MR;
        for blk in 0..full_blocks {
            let i0 = blk * MICRO_MR;
            let ar0 = &a.data[i0 * k..(i0 + 1) * k];
            let ar1 = &a.data[(i0 + 1) * k..(i0 + 2) * k];
            let ar2 = &a.data[(i0 + 2) * k..(i0 + 3) * k];
            let ar3 = &a.data[(i0 + 3) * k..(i0 + 4) * k];
            let c_block = &mut c.data[i0 * n..(i0 + MICRO_MR) * n];
            micro_block4(ar0, ar1, ar2, ar3, b, c_block, k, n);
        }
        // Remainder rows (m % 4) fall back to the single-row axpy kernel.
        for i in full_blocks * MICRO_MR..m {
            let a_row = &a.data[i * k..(i + 1) * k];
            let c_row = &mut c.data[i * n..(i + 1) * n];
            blocked_row(a_row, b, c_row, n);
        }
    }

    fn gram_t(&self, a: &Tensor) -> Tensor {
        let (n, d) = (a.rows(), a.cols());
        let mut c = Tensor::zeros(&[d, d]);
        // Two samples per pass: each upper-triangle row update pulls two
        // A rows, halving passes over C relative to the blocked kernel.
        let pairs = n / 2;
        for p in 0..pairs {
            let r0 = &a.data[2 * p * d..(2 * p + 1) * d];
            let r1 = &a.data[(2 * p + 1) * d..(2 * p + 2) * d];
            for i in 0..d {
                let (x0, x1) = (r0[i], r1[i]);
                let c_row = &mut c.data[i * d..(i + 1) * d];
                for j in i..d {
                    c_row[j] += x0 * r0[j] + x1 * r1[j];
                }
            }
        }
        if n % 2 == 1 {
            let r = &a.data[(n - 1) * d..n * d];
            for i in 0..d {
                let ri = r[i];
                let c_row = &mut c.data[i * d..(i + 1) * d];
                for j in i..d {
                    c_row[j] += ri * r[j];
                }
            }
        }
        mirror_upper(&mut c, d);
        c
    }
}

// ---------------------------------------------------------------------------
// Selection
// ---------------------------------------------------------------------------

/// Which backend to use (config key `backend`, CLI `--backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Naive,
    Blocked,
    Micro,
    /// One-shot calibration probe at startup picks among the concrete
    /// kinds; resolves once per process.
    Auto,
}

impl BackendKind {
    /// The concrete (selectable-by-probe) kinds.
    pub const CONCRETE: [BackendKind; 3] =
        [BackendKind::Naive, BackendKind::Blocked, BackendKind::Micro];

    pub fn parse(s: &str) -> anyhow::Result<BackendKind> {
        match s {
            "naive" | "reference" => Ok(BackendKind::Naive),
            "blocked" => Ok(BackendKind::Blocked),
            "micro" | "microkernel" => Ok(BackendKind::Micro),
            "auto" => Ok(BackendKind::Auto),
            other => anyhow::bail!("unknown backend '{other}' (want naive|blocked|micro|auto)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Naive => "naive",
            BackendKind::Blocked => "blocked",
            BackendKind::Micro => "micro",
            BackendKind::Auto => "auto",
        }
    }
}

static NAIVE: NaiveBackend = NaiveBackend;
static BLOCKED: BlockedBackend = BlockedBackend;
static MICRO: MicroBackend = MicroBackend;

/// Copyable handle to a backend implementation — the thing threaded through
/// `fit_with`, `newton_schulz_with`, `OptimConfig` and the bench suites.
/// Validates shapes once, then dispatches.
#[derive(Clone, Copy)]
pub struct Backend {
    imp: &'static dyn TensorBackend,
    kind: BackendKind,
}

impl Backend {
    pub fn naive() -> Backend {
        Backend { imp: &NAIVE, kind: BackendKind::Naive }
    }

    pub fn blocked() -> Backend {
        Backend { imp: &BLOCKED, kind: BackendKind::Blocked }
    }

    pub fn micro() -> Backend {
        Backend { imp: &MICRO, kind: BackendKind::Micro }
    }

    /// Resolve a kind to a handle; `Auto` runs (or reuses) the calibration
    /// probe.
    pub fn of(kind: BackendKind) -> Backend {
        match kind {
            BackendKind::Naive => Backend::naive(),
            BackendKind::Blocked => Backend::blocked(),
            BackendKind::Micro => Backend::micro(),
            BackendKind::Auto => auto_select(),
        }
    }

    /// All concrete backends, for equivalence tests and bench sweeps.
    pub fn all() -> [Backend; 3] {
        [Backend::naive(), Backend::blocked(), Backend::micro()]
    }

    pub fn name(&self) -> &'static str {
        self.imp.name()
    }

    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    // ---- dispatching kernel API (shape-checked once, here) --------------

    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len(), "dot length mismatch: {} vs {}", a.len(), b.len());
        self.imp.dot(a, b)
    }

    /// C = A @ B. A: (m, k), B: (k, n) -> (m, n).
    pub fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let mut c = Tensor::zeros(&[a.rows(), b.cols()]);
        self.matmul_into(a, b, &mut c);
        c
    }

    /// C = A @ B into a pre-allocated output (hot path avoids allocation).
    pub fn matmul_into(&self, a: &Tensor, b: &Tensor, c: &mut Tensor) {
        let (m, k) = (a.rows(), a.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul inner-dim mismatch: {k} vs {k2}");
        assert_eq!(c.shape, vec![m, n], "matmul output shape mismatch");
        self.imp.matmul_into(a, b, c);
    }

    /// C = A^T @ A for A: (n, d) -> (d, d).
    pub fn gram_t(&self, a: &Tensor) -> Tensor {
        assert_eq!(a.shape.len(), 2, "gram_t needs a matrix");
        self.imp.gram_t(a)
    }

    /// K = A @ A^T for A: (n, d) -> (n, n).
    pub fn gram(&self, a: &Tensor) -> Tensor {
        assert_eq!(a.shape.len(), 2, "gram needs a matrix");
        self.imp.gram(a)
    }
}

impl fmt::Debug for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Backend({})", self.name())
    }
}

impl PartialEq for Backend {
    fn eq(&self, other: &Backend) -> bool {
        self.name() == other.name()
    }
}

// ---------------------------------------------------------------------------
// Global active backend + calibration probe
// ---------------------------------------------------------------------------

// Codes for the atomic: 0 = naive, 1 = blocked (default), 2 = micro.
static ACTIVE: AtomicU8 = AtomicU8::new(1);

fn code_of(kind: BackendKind) -> u8 {
    match kind {
        BackendKind::Naive => 0,
        BackendKind::Blocked => 1,
        BackendKind::Micro => 2,
        BackendKind::Auto => 1,
    }
}

/// The process-wide backend the `tensor::matmul` free functions dispatch
/// through. Defaults to blocked until someone calls [`set_active`].
pub fn active() -> Backend {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => Backend::naive(),
        2 => Backend::micro(),
        _ => Backend::blocked(),
    }
}

/// Install the process-wide backend (Auto resolves through the calibration
/// probe first) and return the resolved handle.
pub fn set_active(kind: BackendKind) -> Backend {
    let be = Backend::of(kind);
    ACTIVE.store(code_of(be.kind()), Ordering::Relaxed);
    be
}

/// Per-backend probe timings, for logs and bench JSON.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    pub chosen: BackendKind,
    /// (kind, best-of-three seconds) per concrete backend.
    pub timings: Vec<(BackendKind, f64)>,
}

/// One-shot startup probe: time a representative matmul + Gram pair on
/// each concrete backend and pick the fastest. Shapes are sized so the
/// whole probe stays in the low milliseconds (it runs before training and
/// before bench suites; DESIGN.md §2).
pub fn calibrate() -> CalibrationReport {
    use crate::util::rng::Pcg64;
    let mut rng = Pcg64::new(0xC0FF_EE, 17);
    let mut a = Tensor::zeros(&[64, 96]);
    let mut b = Tensor::zeros(&[96, 80]);
    let mut g = Tensor::zeros(&[96, 48]);
    rng.fill_normal(&mut a.data, 1.0);
    rng.fill_normal(&mut b.data, 1.0);
    rng.fill_normal(&mut g.data, 1.0);
    let mut c = Tensor::zeros(&[64, 80]);

    let mut timings = Vec::new();
    for kind in BackendKind::CONCRETE {
        let be = Backend::of(kind);
        // one unmeasured warmup, then best of three
        be.matmul_into(&a, &b, &mut c);
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            be.matmul_into(&a, &b, &mut c);
            std::hint::black_box(be.gram_t(&g));
            best = best.min(t0.elapsed().as_secs_f64());
        }
        timings.push((kind, best));
    }
    let chosen = timings
        .iter()
        .min_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
        .map(|&(k, _)| k)
        .unwrap_or(BackendKind::Blocked);
    CalibrationReport { chosen, timings }
}

static AUTO_CHOICE: OnceLock<BackendKind> = OnceLock::new();

/// The calibrated backend, probing at most once per process.
pub fn auto_select() -> Backend {
    let kind = *AUTO_CHOICE.get_or_init(|| {
        let report = calibrate();
        crate::log_debug!(
            "backend calibration: chose {} ({:?})",
            report.chosen.as_str(),
            report
                .timings
                .iter()
                .map(|(k, s)| format!("{}={:.1}µs", k.as_str(), s * 1e6))
                .collect::<Vec<_>>()
        );
        report.chosen
    });
    Backend::of(kind)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_t(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    fn assert_close(got: &Tensor, want: &Tensor, what: &str) {
        assert_eq!(got.shape, want.shape, "{what} shape");
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!(
                (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                "{what}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn all_backends_match_naive_matmul() {
        let mut rng = Pcg64::seeded(77);
        let oracle = Backend::naive();
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 3), (17, 33, 9), (20, 8, 12)] {
            let a = rand_t(&mut rng, &[m, k]);
            let b = rand_t(&mut rng, &[k, n]);
            let want = oracle.matmul(&a, &b);
            for be in Backend::all() {
                assert_close(&be.matmul(&a, &b), &want, be.name());
            }
        }
    }

    #[test]
    fn all_backends_match_naive_gram() {
        let mut rng = Pcg64::seeded(78);
        let oracle = Backend::naive();
        for &(n, d) in &[(1usize, 4usize), (9, 5), (16, 16), (7, 1)] {
            let a = rand_t(&mut rng, &[n, d]);
            let want_t = oracle.gram_t(&a);
            let want = oracle.gram(&a);
            for be in Backend::all() {
                assert_close(&be.gram_t(&a), &want_t, be.name());
                assert_close(&be.gram(&a), &want, be.name());
            }
        }
    }

    #[test]
    fn dot_matches_across_backends() {
        let mut rng = Pcg64::seeded(79);
        for len in [0usize, 1, 3, 8, 9, 31, 1024] {
            let mut a = vec![0.0f32; len];
            let mut b = vec![0.0f32; len];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let want: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            for be in Backend::all() {
                let got = be.dot(&a, &b) as f64;
                assert!(
                    (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                    "{}: {got} vs {want}",
                    be.name()
                );
            }
        }
    }

    #[test]
    fn kind_round_trips_through_parse_and_handle() {
        for kind in BackendKind::CONCRETE {
            assert_eq!(BackendKind::parse(kind.as_str()).unwrap(), kind);
            assert_eq!(Backend::of(kind).kind(), kind);
        }
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert!(BackendKind::parse("gpu").is_err());
    }

    #[test]
    fn calibration_picks_a_concrete_backend() {
        let report = calibrate();
        assert_ne!(report.chosen, BackendKind::Auto);
        assert_eq!(report.timings.len(), 3);
        assert!(report.timings.iter().all(|&(_, s)| s > 0.0 && s.is_finite()));
        assert_ne!(auto_select().kind(), BackendKind::Auto);
    }

    #[test]
    fn set_active_round_trips() {
        let prev = active();
        let be = set_active(BackendKind::Micro);
        assert_eq!(be.name(), "micro");
        assert_eq!(active().name(), "micro");
        set_active(prev.kind());
    }
}
