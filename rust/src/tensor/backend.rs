//! Runtime-selectable tensor backends (DESIGN.md §2, ADR-001, ADR-003).
//!
//! Every dense hot path in the reproduction — the predictor-fit Gram
//! matrices, the U materialization dots, Muon's Newton–Schulz matmuls —
//! funnels through the [`TensorBackend`] trait so the kernel strategy is
//! an extension point instead of a hardcoded loop nest:
//!
//! - [`NaiveBackend`] — the textbook ijk kernels, moved here verbatim from
//!   the old `matmul.rs` test oracle. Slow, obviously correct; every other
//!   backend is property-tested against it (`tests/backend_equivalence.rs`).
//! - [`BlockedBackend`] — the cache-aware ikj / j-tiled kernels that were
//!   previously the only implementation.
//! - [`MicroBackend`] — register-tiled 4-row kernels with B-panel packing:
//!   the shared operand is transpose-packed once per j-tile into a
//!   contiguous workspace panel, so the 4-row micro-kernel streams
//!   contiguous memory instead of striding across B, and each panel row
//!   loaded from L1 is reused four times.
//! - [`SimdBackend`](super::simd::SimdBackend) — explicit AVX2+FMA f32x8
//!   kernels (ADR-007) behind runtime feature detection; `Backend::simd()`
//!   falls back to `micro` on hosts without the features.
//!
//! The trait's primitive entry points are the *row-band* forms
//! (`matmul_rows`, `gram_t_rows`): the persistent worker pool
//! (`coordinator::pool`, ADR-007) splits large outputs into contiguous
//! row bands across workers, and the banding contract — a band result is
//! **bitwise identical** to the same rows of a full-kernel call — is what
//! lets intra-shard parallel kernels coexist with the ADR-004 guarantee
//! that `--shards N` matches serial bit-for-bit. Kernels uphold it by
//! making each output row's arithmetic a pure function of (row, A, B),
//! never of which rows share its block.
//!
//! All kernels are **workspace-aware** (ADR-003): the trait entry points
//! are `*_into` forms writing into caller-owned outputs, with a
//! [`Workspace`] arena providing packing scratch, so steady-state hot
//! loops run allocation-free. The allocating `matmul`/`gram_t`/`gram`
//! conveniences remain on the [`Backend`] handle for cold paths and tests.
//!
//! Selection is by [`BackendKind`] (`--backend` CLI flag / `backend` config
//! key); `Auto` runs a one-shot [`calibrate`] probe at startup and pins the
//! fastest backend for the process. The probe winner is also persisted to a
//! small cache file (keyed by backend set + probe shape grid) so repeat
//! process startups skip the warm-up probe; an explicit `--backend` never
//! consults the cache. The chosen backend is held in a global the free
//! functions in `tensor::matmul` dispatch through, and is also threaded
//! explicitly (as a [`Backend`] handle) through the predictor fit, the Muon
//! optimizer and the coordinator so call sites can pin a backend
//! independently of the global (the equivalence tests and benches do).

use super::{Tensor, Workspace};
use crate::util::json::{obj, s, Json};
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// The dense kernels the reproduction's hot paths need. Implementations
/// may assume shape-checked inputs: the [`Backend`] handle validates before
/// dispatching. All entry points write into caller-owned outputs and draw
/// any packing scratch from the caller's [`Workspace`], so a warmed hot
/// loop never allocates.
pub trait TensorBackend: Sync {
    /// Stable lowercase identifier (appears in bench JSON and logs).
    fn name(&self) -> &'static str;

    /// Dot product of equal-length slices (the stats reduction feeding the
    /// Gram matrices and `matvec`).
    fn dot(&self, a: &[f32], b: &[f32]) -> f32;

    /// Row band `[r0, r1)` of C = A @ B, written into `c_rows` (the
    /// corresponding `(r1 - r0) * n` floats of C). This is the kernel
    /// primitive: `matmul_into` is the full-range call, and the pooled
    /// executor dispatches disjoint bands of one output concurrently.
    ///
    /// **Banding contract:** the band must be bitwise identical to the
    /// same rows of a full-range call, for any partition — each output
    /// row's arithmetic may depend only on (row, A, B), never on band
    /// geometry (e.g. no zero-skip in one row path but not another).
    fn matmul_rows(
        &self,
        a: &Tensor,
        b: &Tensor,
        r0: usize,
        r1: usize,
        c_rows: &mut [f32],
        ws: &mut Workspace,
    );

    /// C = A @ B into a pre-allocated output (zeroed by the kernel).
    /// `ws` supplies operand-packing scratch.
    fn matmul_into(&self, a: &Tensor, b: &Tensor, c: &mut Tensor, ws: &mut Workspace) {
        let m = a.rows();
        self.matmul_rows(a, b, 0, m, &mut c.data, ws);
    }

    /// Output-row band `[i0, i1)` of C = A^T @ A for A: (n, d), written
    /// into `c_rows` ((i1 - i0) full d-wide rows). Only the
    /// upper-triangle cells `j >= i` are computed (band rows are zeroed
    /// first); the caller mirrors after all bands land — `mirror_upper`
    /// only reads the upper triangle, so it commutes with banding. Same
    /// banding contract as [`matmul_rows`](TensorBackend::matmul_rows).
    fn gram_t_rows(
        &self,
        a: &Tensor,
        i0: usize,
        i1: usize,
        c_rows: &mut [f32],
        ws: &mut Workspace,
    );

    /// C = A^T @ A for A: (n, d) into a pre-allocated (d, d) output.
    fn gram_t_into(&self, a: &Tensor, c: &mut Tensor, ws: &mut Workspace) {
        let d = a.cols();
        self.gram_t_rows(a, 0, d, &mut c.data, ws);
        mirror_upper(c, d);
    }

    /// K = A @ A^T for A: (n, d) into a pre-allocated (n, n) output.
    /// Default: symmetric row-dot fill using this backend's `dot`, with
    /// both row borrows hoisted out of the inner loop (one `chunks_exact`
    /// pass per row pair instead of re-slicing from the start of A for
    /// every (i, j)).
    fn gram_into(&self, a: &Tensor, c: &mut Tensor, _ws: &mut Workspace) {
        let (n, d) = (a.rows(), a.cols());
        if d == 0 {
            c.data.fill(0.0);
            return;
        }
        for (i, ri) in a.data.chunks_exact(d).enumerate() {
            for (off, rj) in a.data[i * d..].chunks_exact(d).enumerate() {
                let j = i + off;
                let dot = self.dot(ri, rj);
                c.data[i * n + j] = dot;
                c.data[j * n + i] = dot;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Reference kernels (the correctness oracle)
// ---------------------------------------------------------------------------

/// Textbook ijk kernels. The equivalence proptests and the other backends'
/// unit tests all compare against this implementation.
pub struct NaiveBackend;

impl TensorBackend for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut s = 0.0f32;
        for (x, y) in a.iter().zip(b) {
            s += x * y;
        }
        s
    }

    fn matmul_rows(
        &self,
        a: &Tensor,
        b: &Tensor,
        r0: usize,
        r1: usize,
        c_rows: &mut [f32],
        _ws: &mut Workspace,
    ) {
        let k = a.cols();
        let n = b.cols();
        for i in r0..r1 {
            let c_row = &mut c_rows[(i - r0) * n..(i - r0 + 1) * n];
            for (j, cv) in c_row.iter_mut().enumerate() {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += a.at(i, kk) * b.at(kk, j);
                }
                *cv = s;
            }
        }
    }

    fn gram_t_rows(
        &self,
        a: &Tensor,
        i0: usize,
        i1: usize,
        c_rows: &mut [f32],
        _ws: &mut Workspace,
    ) {
        let (n, d) = (a.rows(), a.cols());
        c_rows.fill(0.0);
        for i in i0..i1 {
            let c_row = &mut c_rows[(i - i0) * d..(i - i0 + 1) * d];
            for j in i..d {
                let mut s = 0.0f32;
                for row in 0..n {
                    s += a.at(row, i) * a.at(row, j);
                }
                c_row[j] = s;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Blocked kernels (the previous hardcoded implementation, moved here)
// ---------------------------------------------------------------------------

/// Cache-aware ikj loop order with an L1-sized j-tile. The inner j-loop is
/// a contiguous axpy over B's row and C's row, which auto-vectorizes.
pub struct BlockedBackend;

const BLOCKED_JT: usize = 256;

/// One ikj/j-tiled output row: c_row += a_row @ B (B unpacked, strided by
/// its full row width). Used by the blocked kernel.
fn blocked_row(a_row: &[f32], b: &Tensor, c_row: &mut [f32], n: usize) {
    for j0 in (0..n).step_by(BLOCKED_JT) {
        let j1 = (j0 + BLOCKED_JT).min(n);
        for (kk, &aik) in a_row.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let b_row = &b.data[kk * n + j0..kk * n + j1];
            let c_seg = &mut c_row[j0..j1];
            for (cv, bv) in c_seg.iter_mut().zip(b_row) {
                *cv += aik * bv;
            }
        }
    }
}

impl TensorBackend for BlockedBackend {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        super::stats::dot(a, b)
    }

    fn matmul_rows(
        &self,
        a: &Tensor,
        b: &Tensor,
        r0: usize,
        r1: usize,
        c_rows: &mut [f32],
        _ws: &mut Workspace,
    ) {
        let k = a.cols();
        let n = b.cols();
        c_rows.fill(0.0);
        for i in r0..r1 {
            let a_row = &a.data[i * k..(i + 1) * k];
            let c_row = &mut c_rows[(i - r0) * n..(i - r0 + 1) * n];
            blocked_row(a_row, b, c_row, n);
        }
    }

    fn gram_t_rows(
        &self,
        a: &Tensor,
        i0: usize,
        i1: usize,
        c_rows: &mut [f32],
        _ws: &mut Workspace,
    ) {
        let (n, d) = (a.rows(), a.cols());
        c_rows.fill(0.0);
        for row in 0..n {
            let r = &a.data[row * d..(row + 1) * d];
            for i in i0..i1 {
                let ri = r[i];
                if ri == 0.0 {
                    continue;
                }
                let c_row = &mut c_rows[(i - i0) * d..(i - i0 + 1) * d];
                for j in i..d {
                    c_row[j] += ri * r[j];
                }
            }
        }
    }
}

/// Copy the upper triangle into the lower one (used by the default
/// `gram_t_into` and the pooled gram_t after its bands land; reads only
/// cells `j >= i`, so it is safe to run once after any band partition).
pub(crate) fn mirror_upper(c: &mut Tensor, d: usize) {
    for i in 0..d {
        for j in 0..i {
            c.data[i * d + j] = c.data[j * d + i];
        }
    }
}

// ---------------------------------------------------------------------------
// Register-tiled micro kernels with B-panel packing
// ---------------------------------------------------------------------------

/// Register-tiled kernels: 4 output rows per pass with 4-wide accumulator
/// chains over a B panel packed once per j-tile into workspace scratch.
/// Packing turns the kk-walk over B from an n-strided gather into a
/// contiguous stream, and each packed row feeds four C rows (¼ the B
/// traffic of the blocked kernel); the dense (no zero-skip) inner loop
/// keeps the multiply–add chains straight-line for the vectorizer.
pub struct MicroBackend;

const MICRO_JT: usize = 512;
const MICRO_MR: usize = 4;

/// The 4-row register-tiled block over one packed (k, w) panel:
/// c[0..4][j0..j0+w] += a_rows[0..4] @ panel.
#[allow(clippy::too_many_arguments)]
fn micro_block4(
    ar0: &[f32],
    ar1: &[f32],
    ar2: &[f32],
    ar3: &[f32],
    panel: &[f32],
    c_block: &mut [f32],
    k: usize,
    n: usize,
    j0: usize,
    w: usize,
) {
    let (c0, rest) = c_block.split_at_mut(n);
    let (c1, rest) = rest.split_at_mut(n);
    let (c2, c3) = rest.split_at_mut(n);
    let s0 = &mut c0[j0..j0 + w];
    let s1 = &mut c1[j0..j0 + w];
    let s2 = &mut c2[j0..j0 + w];
    let s3 = &mut c3[j0..j0 + w];
    for kk in 0..k {
        let (a0, a1, a2, a3) = (ar0[kk], ar1[kk], ar2[kk], ar3[kk]);
        let b_row = &panel[kk * w..(kk + 1) * w];
        for (idx, &bv) in b_row.iter().enumerate() {
            s0[idx] += a0 * bv;
            s1[idx] += a1 * bv;
            s2[idx] += a2 * bv;
            s3[idx] += a3 * bv;
        }
    }
}

/// Remainder rows (m % 4): one output-row axpy over the packed panel.
/// Deliberately no zero-skip: a skipped `+= 0.0 * b` can flip a -0.0 to
/// +0.0 relative to the dense 4-row block, and the banding contract
/// (ADR-007) requires a row's bits to be identical whichever path
/// computes it.
fn micro_row(a_row: &[f32], panel: &[f32], c_seg: &mut [f32], w: usize) {
    for (kk, &aik) in a_row.iter().enumerate() {
        let b_row = &panel[kk * w..(kk + 1) * w];
        for (cv, &bv) in c_seg.iter_mut().zip(b_row) {
            *cv += aik * bv;
        }
    }
}

impl TensorBackend for MicroBackend {
    fn name(&self) -> &'static str {
        "micro"
    }

    /// 8-accumulator unrolled dot (wider than the blocked 4-way; the extra
    /// chains hide FMA latency on longer reductions).
    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 8;
        let mut acc = [0.0f32; 8];
        for i in 0..chunks {
            let j = i * 8;
            for (lane, s) in acc.iter_mut().enumerate() {
                *s += a[j + lane] * b[j + lane];
            }
        }
        let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
            + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for j in chunks * 8..n {
            s += a[j] * b[j];
        }
        s
    }

    fn matmul_rows(
        &self,
        a: &Tensor,
        b: &Tensor,
        r0: usize,
        r1: usize,
        c_rows: &mut [f32],
        ws: &mut Workspace,
    ) {
        let k = a.cols();
        let n = b.cols();
        let m = r1 - r0;
        c_rows.fill(0.0);
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let full_blocks = m / MICRO_MR;
        // One panel buffer serves every j-tile; the last (narrower) tile
        // just uses a shorter prefix.
        let mut panel = ws.take(k * MICRO_JT.min(n));
        for j0 in (0..n).step_by(MICRO_JT) {
            let j1 = (j0 + MICRO_JT).min(n);
            let w = j1 - j0;
            // Pack B[:, j0..j1] once into a contiguous (k, w) panel; it is
            // then reused by every 4-row block below, so the pack cost
            // amortizes over m/4 passes.
            for kk in 0..k {
                panel[kk * w..(kk + 1) * w]
                    .copy_from_slice(&b.data[kk * n + j0..kk * n + j1]);
            }
            let panel = &panel[..k * w];
            for blk in 0..full_blocks {
                let i0 = r0 + blk * MICRO_MR;
                let o0 = blk * MICRO_MR;
                micro_block4(
                    &a.data[i0 * k..(i0 + 1) * k],
                    &a.data[(i0 + 1) * k..(i0 + 2) * k],
                    &a.data[(i0 + 2) * k..(i0 + 3) * k],
                    &a.data[(i0 + 3) * k..(i0 + 4) * k],
                    panel,
                    &mut c_rows[o0 * n..(o0 + MICRO_MR) * n],
                    k,
                    n,
                    j0,
                    w,
                );
            }
            for i in full_blocks * MICRO_MR..m {
                let a_row = &a.data[(r0 + i) * k..(r0 + i + 1) * k];
                let c_seg = &mut c_rows[i * n + j0..i * n + j1];
                micro_row(a_row, panel, c_seg, w);
            }
        }
        ws.give(panel);
    }

    /// Fused symmetric rank-k update: four samples per pass over the upper
    /// triangle only (skipping the redundant lower-triangle work); the
    /// trait's `gram_t_into` mirrors once after the full range lands.
    /// Quarters the passes over C relative to the blocked kernel.
    fn gram_t_rows(
        &self,
        a: &Tensor,
        i0: usize,
        i1: usize,
        c_rows: &mut [f32],
        _ws: &mut Workspace,
    ) {
        let (n, d) = (a.rows(), a.cols());
        c_rows.fill(0.0);
        let quads = n / 4;
        for q in 0..quads {
            let base = 4 * q * d;
            let r0 = &a.data[base..base + d];
            let r1 = &a.data[base + d..base + 2 * d];
            let r2 = &a.data[base + 2 * d..base + 3 * d];
            let r3 = &a.data[base + 3 * d..base + 4 * d];
            for i in i0..i1 {
                let (x0, x1, x2, x3) = (r0[i], r1[i], r2[i], r3[i]);
                let c_row = &mut c_rows[(i - i0) * d..(i - i0 + 1) * d];
                for j in i..d {
                    c_row[j] += x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
                }
            }
        }
        for row in 4 * quads..n {
            let r = &a.data[row * d..(row + 1) * d];
            for i in i0..i1 {
                let ri = r[i];
                let c_row = &mut c_rows[(i - i0) * d..(i - i0 + 1) * d];
                for j in i..d {
                    c_row[j] += ri * r[j];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Selection
// ---------------------------------------------------------------------------

/// Which backend to use (config key `backend`, CLI `--backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Naive,
    Blocked,
    Micro,
    /// Explicit AVX2+FMA f32x8 kernels (ADR-007). Requires runtime CPU
    /// feature support; resolves to `micro` (warn-once) on hosts without
    /// it, so configs ship portably.
    Simd,
    /// One-shot calibration probe at startup picks among the concrete
    /// kinds; resolves once per process (cache file skips repeat probes).
    Auto,
}

impl BackendKind {
    /// The portable concrete kinds — runnable on every host. `Simd` is
    /// deliberately not here: its handle depends on runtime CPU features
    /// (see [`BackendKind::available`]).
    pub const CONCRETE: [BackendKind; 3] =
        [BackendKind::Naive, BackendKind::Blocked, BackendKind::Micro];

    /// The concrete kinds actually runnable on *this* host: the portable
    /// set plus `simd` when the CPU has AVX2+FMA. The calibration probe
    /// and `Backend::all()` sweep exactly this set, so bench rows and
    /// equivalence coverage never contain a silently-falling-back
    /// duplicate of `micro`.
    pub fn available() -> Vec<BackendKind> {
        let mut kinds = BackendKind::CONCRETE.to_vec();
        if super::simd::simd_available() {
            kinds.push(BackendKind::Simd);
        }
        kinds
    }

    /// Single source of truth for the parser and the `--help` option
    /// list (`util::cli::options(BackendKind::SPECS)`).
    pub const SPECS: &'static [crate::util::cli::EnumSpec<BackendKind>] = &[
        crate::util::cli::EnumSpec {
            name: "naive",
            aliases: &["reference"],
            value: BackendKind::Naive,
        },
        crate::util::cli::EnumSpec { name: "blocked", aliases: &[], value: BackendKind::Blocked },
        crate::util::cli::EnumSpec {
            name: "micro",
            aliases: &["microkernel"],
            value: BackendKind::Micro,
        },
        crate::util::cli::EnumSpec {
            name: "simd",
            aliases: &["avx2"],
            value: BackendKind::Simd,
        },
        crate::util::cli::EnumSpec { name: "auto", aliases: &[], value: BackendKind::Auto },
    ];

    pub fn parse(s: &str) -> anyhow::Result<BackendKind> {
        s.parse()
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Naive => "naive",
            BackendKind::Blocked => "blocked",
            BackendKind::Micro => "micro",
            BackendKind::Simd => "simd",
            BackendKind::Auto => "auto",
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<BackendKind> {
        crate::util::cli::parse_enum(BackendKind::SPECS, "backend", s)
    }
}

static NAIVE: NaiveBackend = NaiveBackend;
static BLOCKED: BlockedBackend = BlockedBackend;
static MICRO: MicroBackend = MicroBackend;
static SIMD: super::simd::SimdBackend = super::simd::SimdBackend;

/// Copyable handle to a backend implementation — the thing threaded through
/// `fit_with`, `newton_schulz_with`, `OptimConfig` and the bench suites.
/// Validates shapes once, then dispatches. Hot paths use the `*_into_ws`
/// entry points with a caller-owned [`Workspace`]; the allocating forms
/// remain for cold paths and tests.
#[derive(Clone, Copy)]
pub struct Backend {
    imp: &'static dyn TensorBackend,
    kind: BackendKind,
}

impl Backend {
    pub fn naive() -> Backend {
        Backend { imp: &NAIVE, kind: BackendKind::Naive }
    }

    pub fn blocked() -> Backend {
        Backend { imp: &BLOCKED, kind: BackendKind::Blocked }
    }

    pub fn micro() -> Backend {
        Backend { imp: &MICRO, kind: BackendKind::Micro }
    }

    /// The AVX2+FMA backend (ADR-007) when the host CPU supports it;
    /// otherwise falls back to `micro` with a warn-once log, so a config
    /// or calibration cache naming `simd` degrades instead of failing.
    /// Note the fallback handle reports `kind() == Micro` — callers (and
    /// bench cell keys) see what actually runs.
    pub fn simd() -> Backend {
        if super::simd::simd_available() {
            Backend { imp: &SIMD, kind: BackendKind::Simd }
        } else {
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                crate::log_warn!(
                    "backend 'simd' requested but host lacks AVX2+FMA; falling back to 'micro'"
                );
            });
            Backend::micro()
        }
    }

    /// Resolve a kind to a handle; `Auto` runs (or reuses) the calibration
    /// probe.
    pub fn of(kind: BackendKind) -> Backend {
        match kind {
            BackendKind::Naive => Backend::naive(),
            BackendKind::Blocked => Backend::blocked(),
            BackendKind::Micro => Backend::micro(),
            BackendKind::Simd => Backend::simd(),
            BackendKind::Auto => auto_select(),
        }
    }

    /// All concrete backends runnable on this host (`simd` included only
    /// when the CPU supports it — [`BackendKind::available`]), for
    /// equivalence tests and bench sweeps.
    pub fn all() -> Vec<Backend> {
        BackendKind::available().into_iter().map(Backend::of).collect()
    }

    pub fn name(&self) -> &'static str {
        self.imp.name()
    }

    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    // ---- dispatching kernel API (shape-checked once, here) --------------

    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len(), "dot length mismatch: {} vs {}", a.len(), b.len());
        self.imp.dot(a, b)
    }

    /// C = A @ B. A: (m, k), B: (k, n) -> (m, n). Allocating convenience.
    pub fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let mut c = Tensor::zeros(&[a.rows(), b.cols()]);
        self.matmul_into(a, b, &mut c);
        c
    }

    /// C = A @ B into a pre-allocated output; packing scratch comes from a
    /// fresh throwaway workspace (cold-path convenience).
    pub fn matmul_into(&self, a: &Tensor, b: &Tensor, c: &mut Tensor) {
        let mut ws = Workspace::new();
        self.matmul_into_ws(a, b, c, &mut ws);
    }

    /// C = A @ B into a pre-allocated output, drawing scratch from the
    /// caller's workspace — the zero-allocation hot-path entry point.
    pub fn matmul_into_ws(&self, a: &Tensor, b: &Tensor, c: &mut Tensor, ws: &mut Workspace) {
        let (m, k) = (a.rows(), a.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul inner-dim mismatch: {k} vs {k2}");
        // compared against a stack array: shape checks must not allocate
        assert_eq!(c.shape, [m, n], "matmul output shape mismatch");
        self.imp.matmul_into(a, b, c, ws);
    }

    /// C = A^T @ A for A: (n, d) -> (d, d). Allocating convenience.
    pub fn gram_t(&self, a: &Tensor) -> Tensor {
        let d = a.cols();
        let mut c = Tensor::zeros(&[d, d]);
        self.gram_t_into(a, &mut c);
        c
    }

    /// C = A^T @ A into a pre-allocated (d, d) output.
    pub fn gram_t_into(&self, a: &Tensor, c: &mut Tensor) {
        let mut ws = Workspace::new();
        self.gram_t_into_ws(a, c, &mut ws);
    }

    /// C = A^T @ A into a pre-allocated output with caller scratch — the
    /// zero-allocation hot-path entry point.
    pub fn gram_t_into_ws(&self, a: &Tensor, c: &mut Tensor, ws: &mut Workspace) {
        assert_eq!(a.shape.len(), 2, "gram_t needs a matrix");
        let d = a.cols();
        assert_eq!(c.shape, [d, d], "gram_t output shape mismatch");
        self.imp.gram_t_into(a, c, ws);
    }

    /// K = A @ A^T for A: (n, d) -> (n, n). Allocating convenience.
    pub fn gram(&self, a: &Tensor) -> Tensor {
        let n = a.rows();
        let mut c = Tensor::zeros(&[n, n]);
        self.gram_into(a, &mut c);
        c
    }

    /// K = A @ A^T into a pre-allocated (n, n) output.
    pub fn gram_into(&self, a: &Tensor, c: &mut Tensor) {
        let mut ws = Workspace::new();
        self.gram_into_ws(a, c, &mut ws);
    }

    /// K = A @ A^T into a pre-allocated output with caller scratch — the
    /// zero-allocation hot-path entry point.
    pub fn gram_into_ws(&self, a: &Tensor, c: &mut Tensor, ws: &mut Workspace) {
        assert_eq!(a.shape.len(), 2, "gram needs a matrix");
        let n = a.rows();
        assert_eq!(c.shape, [n, n], "gram output shape mismatch");
        self.imp.gram_into(a, c, ws);
    }

    /// Row band `[r0, r1)` of C = A @ B into `c_rows` — the entry the
    /// pooled executor (ADR-007) dispatches concurrent bands through.
    /// Bitwise identical to the same rows of `matmul_into_ws` for any
    /// partition (the trait's banding contract).
    pub fn matmul_rows(
        &self,
        a: &Tensor,
        b: &Tensor,
        r0: usize,
        r1: usize,
        c_rows: &mut [f32],
        ws: &mut Workspace,
    ) {
        let (m, k) = (a.rows(), a.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul inner-dim mismatch: {k} vs {k2}");
        assert!(r0 <= r1 && r1 <= m, "matmul row band [{r0}, {r1}) out of range (m = {m})");
        assert_eq!((r1 - r0) * n, c_rows.len(), "matmul band output length mismatch");
        self.imp.matmul_rows(a, b, r0, r1, c_rows, ws);
    }

    /// Output-row band `[i0, i1)` of C = A^T @ A into `c_rows` (upper
    /// triangle only; mirror with `mirror_upper` after every band lands).
    pub fn gram_t_rows(
        &self,
        a: &Tensor,
        i0: usize,
        i1: usize,
        c_rows: &mut [f32],
        ws: &mut Workspace,
    ) {
        assert_eq!(a.shape.len(), 2, "gram_t needs a matrix");
        let d = a.cols();
        assert!(i0 <= i1 && i1 <= d, "gram_t row band [{i0}, {i1}) out of range (d = {d})");
        assert_eq!((i1 - i0) * d, c_rows.len(), "gram_t band output length mismatch");
        self.imp.gram_t_rows(a, i0, i1, c_rows, ws);
    }
}

impl fmt::Debug for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Backend({})", self.name())
    }
}

impl PartialEq for Backend {
    fn eq(&self, other: &Backend) -> bool {
        self.name() == other.name()
    }
}

// ---------------------------------------------------------------------------
// Global active backend + calibration probe
// ---------------------------------------------------------------------------

// Codes for the atomic: 0 = naive, 1 = blocked (default), 2 = micro,
// 3 = simd (resolves through the runtime-detected fallback on load).
static ACTIVE: AtomicU8 = AtomicU8::new(1);

fn code_of(kind: BackendKind) -> u8 {
    match kind {
        BackendKind::Naive => 0,
        BackendKind::Blocked => 1,
        BackendKind::Micro => 2,
        BackendKind::Simd => 3,
        BackendKind::Auto => 1,
    }
}

/// The process-wide backend the `tensor::matmul` free functions dispatch
/// through. Defaults to blocked until someone calls [`set_active`].
pub fn active() -> Backend {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => Backend::naive(),
        2 => Backend::micro(),
        3 => Backend::simd(),
        _ => Backend::blocked(),
    }
}

/// Install the process-wide backend (Auto resolves through the calibration
/// probe first) and return the resolved handle.
pub fn set_active(kind: BackendKind) -> Backend {
    let be = Backend::of(kind);
    ACTIVE.store(code_of(be.kind()), Ordering::Relaxed);
    be
}

/// Per-backend probe timings, for logs and bench JSON.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    pub chosen: BackendKind,
    /// (kind, best-of-three seconds) per available backend.
    pub timings: Vec<(BackendKind, f64)>,
}

/// One-shot startup probe: time a representative matmul + Gram pair on
/// each backend available on this host ([`BackendKind::available`],
/// i.e. the portable concrete set plus `simd` when the CPU supports
/// AVX2+FMA) and pick the fastest. Shapes are sized so the
/// whole probe stays in the low milliseconds (it runs before training and
/// before bench suites; DESIGN.md §2).
pub fn calibrate() -> CalibrationReport {
    use crate::util::rng::Pcg64;
    let mut rng = Pcg64::new(0xC0FF_EE, 17);
    let mut a = Tensor::zeros(&[64, 96]);
    let mut b = Tensor::zeros(&[96, 80]);
    let mut g = Tensor::zeros(&[96, 48]);
    rng.fill_normal(&mut a.data, 1.0);
    rng.fill_normal(&mut b.data, 1.0);
    rng.fill_normal(&mut g.data, 1.0);
    let mut c = Tensor::zeros(&[64, 80]);
    let mut gt = Tensor::zeros(&[48, 48]);
    let mut ws = Workspace::new();

    let mut timings = Vec::new();
    for kind in BackendKind::available() {
        let be = Backend::of(kind);
        // one unmeasured warmup, then best of three
        be.matmul_into_ws(&a, &b, &mut c, &mut ws);
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            be.matmul_into_ws(&a, &b, &mut c, &mut ws);
            be.gram_t_into_ws(&g, &mut gt, &mut ws);
            std::hint::black_box(&gt);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        timings.push((kind, best));
    }
    let chosen = timings
        .iter()
        .min_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
        .map(|&(k, _)| k)
        .unwrap_or(BackendKind::Blocked);
    CalibrationReport { chosen, timings }
}

// ---------------------------------------------------------------------------
// Calibration cache (skip the warm-up probe on repeat startups)
// ---------------------------------------------------------------------------

/// Schema id stamped into the calibration cache file.
pub const CALIB_CACHE_SCHEMA: &str = "lgp.calib.v1";

/// Cache key: crate version + the backend set available on this host +
/// the detected CPU feature string + the probe's shape grid. A new
/// release (which may change kernel implementations and therefore the
/// ranking), a new backend, a host with different SIMD support, or new
/// probe shapes all invalidate stale cache files instead of pinning an
/// outdated winner.
pub fn calib_cache_key() -> String {
    let avail = BackendKind::available();
    let names: Vec<&str> = avail.iter().map(|k| k.as_str()).collect();
    format!(
        "v{}|{}|feat:{}|matmul:64x96x80|gram_t:96x48",
        env!("CARGO_PKG_VERSION"),
        names.join(","),
        super::simd::cpu_features()
    )
}

/// Cache location: `LGP_CALIB_CACHE` overrides the path,
/// `LGP_NO_CALIB_CACHE` disables caching entirely.
fn calib_cache_path() -> Option<PathBuf> {
    if std::env::var_os("LGP_NO_CALIB_CACHE").is_some() {
        return None;
    }
    if let Some(p) = std::env::var_os("LGP_CALIB_CACHE") {
        return Some(PathBuf::from(p));
    }
    Some(std::env::temp_dir().join("lgp_calib_cache_v1.json"))
}

/// Read a cached probe winner. Returns `None` (probe as usual) on a
/// missing file, parse failure, schema/key mismatch, a CPU-feature
/// mismatch, or a cached kind this host can't run — the cache can only
/// ever skip work, never break startup or pin an unsupported backend.
///
/// A feature mismatch (cache written on a host with a different SIMD
/// feature set, e.g. copied from an AVX2 box to one without) warns once
/// per process and re-probes, per ISSUE 7 satellite 1.
pub fn read_calib_cache(path: &Path, key: &str) -> Option<BackendKind> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    if j.at(&["schema"]).as_str() != Some(CALIB_CACHE_SCHEMA) {
        return None;
    }
    let here = super::simd::cpu_features();
    if let Some(feat) = j.at(&["features"]).as_str() {
        if feat != here {
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                crate::log_warn!(
                    "calibration cache {} was written for cpu features '{}' but this \
                     host has '{}'; re-probing",
                    path.display(),
                    feat,
                    here
                );
            });
            return None;
        }
    }
    if j.at(&["key"]).as_str() != Some(key) {
        return None;
    }
    let kind = BackendKind::parse(j.at(&["chosen"]).as_str()?).ok()?;
    (kind != BackendKind::Auto && BackendKind::available().contains(&kind)).then_some(kind)
}

/// Best-effort cache write; an IO failure never aborts startup (the probe
/// result is advisory and will simply be re-measured next time), but it is
/// logged with the offending path instead of being swallowed silently —
/// a read-only or full temp dir otherwise re-probes every run with no
/// visible reason. The detected CPU feature string is stamped in so
/// [`read_calib_cache`] can reject the file on a host with different SIMD
/// support.
pub fn write_calib_cache(path: &Path, key: &str, chosen: BackendKind) {
    let doc = obj(vec![
        ("schema", s(CALIB_CACHE_SCHEMA)),
        ("key", s(key)),
        ("features", s(super::simd::cpu_features())),
        ("chosen", s(chosen.as_str())),
    ]);
    let mut text = doc.to_string();
    text.push('\n');
    if let Err(e) = std::fs::write(path, text) {
        crate::log_warn!(
            "writing calibration cache {}: {e} (probe will re-run next startup)",
            path.display()
        );
    }
}

static AUTO_CHOICE: OnceLock<BackendKind> = OnceLock::new();

/// The calibrated backend, probing at most once per process. Consults the
/// calibration cache file first; an explicit `--backend` (any concrete
/// `BackendKind`) never reaches this path, so it always overrides.
pub fn auto_select() -> Backend {
    let kind = *AUTO_CHOICE.get_or_init(|| {
        let key = calib_cache_key();
        if let Some(path) = calib_cache_path() {
            if let Some(kind) = read_calib_cache(&path, &key) {
                crate::log_debug!(
                    "backend calibration: cache hit -> {} ({})",
                    kind.as_str(),
                    path.display()
                );
                return kind;
            }
        }
        let report = calibrate();
        crate::log_debug!(
            "backend calibration: chose {} ({:?})",
            report.chosen.as_str(),
            report
                .timings
                .iter()
                .map(|(k, s)| format!("{}={:.1}µs", k.as_str(), s * 1e6))
                .collect::<Vec<_>>()
        );
        if let Some(path) = calib_cache_path() {
            write_calib_cache(&path, &key, report.chosen);
        }
        report.chosen
    });
    Backend::of(kind)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_t(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    fn assert_close(got: &Tensor, want: &Tensor, what: &str) {
        assert_eq!(got.shape, want.shape, "{what} shape");
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!(
                (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                "{what}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn all_backends_match_naive_matmul() {
        let mut rng = Pcg64::seeded(77);
        let oracle = Backend::naive();
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 3), (17, 33, 9), (20, 8, 12)] {
            let a = rand_t(&mut rng, &[m, k]);
            let b = rand_t(&mut rng, &[k, n]);
            let want = oracle.matmul(&a, &b);
            for be in Backend::all() {
                assert_close(&be.matmul(&a, &b), &want, be.name());
            }
        }
    }

    #[test]
    fn all_backends_match_naive_gram() {
        let mut rng = Pcg64::seeded(78);
        let oracle = Backend::naive();
        for &(n, d) in &[(1usize, 4usize), (9, 5), (16, 16), (7, 1)] {
            let a = rand_t(&mut rng, &[n, d]);
            let want_t = oracle.gram_t(&a);
            let want = oracle.gram(&a);
            for be in Backend::all() {
                assert_close(&be.gram_t(&a), &want_t, be.name());
                assert_close(&be.gram(&a), &want, be.name());
            }
        }
    }

    #[test]
    fn workspace_entry_points_match_and_reuse_scratch() {
        // Dirty outputs + one shared workspace across shapes and backends:
        // the _into_ws kernels must overwrite every stale cell and, after
        // warm-up, stop allocating scratch.
        let mut rng = Pcg64::seeded(90);
        let oracle = Backend::naive();
        let mut ws = Workspace::new();
        let mut warm_misses = 0;
        for round in 0..3 {
            for &(m, k, n) in &[(5usize, 7usize, 3usize), (16, 16, 16), (9, 33, 5)] {
                let a = rand_t(&mut rng, &[m, k]);
                let b = rand_t(&mut rng, &[k, n]);
                let want = oracle.matmul(&a, &b);
                for be in Backend::all() {
                    let mut c = Tensor::filled(&[m, n], f32::NAN);
                    be.matmul_into_ws(&a, &b, &mut c, &mut ws);
                    assert_close(&c, &want, be.name());
                }
                let want_gt = oracle.gram_t(&a);
                let want_g = oracle.gram(&a);
                for be in Backend::all() {
                    let mut gt = Tensor::filled(&[k, k], f32::NAN);
                    be.gram_t_into_ws(&a, &mut gt, &mut ws);
                    assert_close(&gt, &want_gt, be.name());
                    let mut g = Tensor::filled(&[m, m], f32::NAN);
                    be.gram_into_ws(&a, &mut g, &mut ws);
                    assert_close(&g, &want_g, be.name());
                }
            }
            if round == 0 {
                // Record the warm-up miss count; later rounds must be
                // served entirely from the pool.
                warm_misses = ws.misses();
            }
        }
        assert_eq!(
            ws.misses(),
            warm_misses,
            "steady-state rounds must not allocate"
        );
    }

    #[test]
    fn dot_matches_across_backends() {
        let mut rng = Pcg64::seeded(79);
        for len in [0usize, 1, 3, 8, 9, 31, 1024] {
            let mut a = vec![0.0f32; len];
            let mut b = vec![0.0f32; len];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let want: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            for be in Backend::all() {
                let got = be.dot(&a, &b) as f64;
                assert!(
                    (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                    "{}: {got} vs {want}",
                    be.name()
                );
            }
        }
    }

    #[test]
    fn kind_round_trips_through_parse_and_handle() {
        for kind in BackendKind::CONCRETE {
            assert_eq!(BackendKind::parse(kind.as_str()).unwrap(), kind);
            assert_eq!(Backend::of(kind).kind(), kind);
        }
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert!(BackendKind::parse("gpu").is_err());
    }

    #[test]
    fn calibration_picks_a_concrete_backend() {
        let report = calibrate();
        assert_ne!(report.chosen, BackendKind::Auto);
        assert_eq!(report.timings.len(), BackendKind::available().len());
        assert!(report.timings.iter().all(|&(_, s)| s > 0.0 && s.is_finite()));
        assert_ne!(auto_select().kind(), BackendKind::Auto);
    }

    #[test]
    fn calib_cache_round_trips() {
        let dir = std::env::temp_dir().join("lgp_calib_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        let key = calib_cache_key();
        write_calib_cache(&path, &key, BackendKind::Micro);
        assert_eq!(read_calib_cache(&path, &key), Some(BackendKind::Micro));
        // A different key (new backend set / probe grid) misses.
        assert_eq!(read_calib_cache(&path, "other-key"), None);
        // Corrupt files miss instead of erroring.
        std::fs::write(&path, "{not json").unwrap();
        assert_eq!(read_calib_cache(&path, &key), None);
        // Missing files miss.
        assert_eq!(read_calib_cache(&dir.join("nope.json"), &key), None);
        // A tampered "auto" entry is rejected (must be concrete).
        std::fs::write(
            &path,
            format!(
                r#"{{"schema":"{CALIB_CACHE_SCHEMA}","key":"{key}","chosen":"auto"}}"#
            ),
        )
        .unwrap();
        assert_eq!(read_calib_cache(&path, &key), None);
        // A cache stamped with another host's CPU feature set is rejected
        // (re-probe) even when the key would otherwise match.
        std::fs::write(
            &path,
            format!(
                r#"{{"schema":"{CALIB_CACHE_SCHEMA}","key":"{key}","features":"some-other-isa","chosen":"micro"}}"#
            ),
        )
        .unwrap();
        assert_eq!(read_calib_cache(&path, &key), None);
        // A cached kind this host can't run is rejected; a supported one
        // round-trips. (Which branch fires depends on the host's SIMD
        // support — both hold the same invariant.)
        write_calib_cache(&path, &key, BackendKind::Simd);
        let expect = crate::tensor::simd::simd_available().then_some(BackendKind::Simd);
        assert_eq!(read_calib_cache(&path, &key), expect);
    }

    #[test]
    fn set_active_round_trips() {
        let prev = active();
        let be = set_active(BackendKind::Micro);
        assert_eq!(be.name(), "micro");
        assert_eq!(active().name(), "micro");
        set_active(prev.kind());
    }
}
