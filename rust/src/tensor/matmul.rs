//! Dense matrix products, dispatched through the active tensor backend.
//!
//! The kernel implementations live in `tensor::backend` (naive reference,
//! blocked ikj/j-tiled, register-tiled micro-kernel); these free functions
//! route through [`backend::active`] so existing call sites pick up
//! whatever the startup selection (config flag or calibration probe)
//! installed. Single-threaded here — intra-shard parallelism lives in the
//! coordinator's persistent worker pool (ADR-007); the perf pass
//! (EXPERIMENTS.md §Perf) measures the backends against each other and
//! `BENCH_kernels.json` records the trajectory. These feed the predictor
//! fit (Gram matrices, U materialization) and Muon's Newton–Schulz
//! iteration.

use super::{backend, Tensor, Workspace};

/// C = A @ B. A: (m, k), B: (k, n) -> (m, n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    backend::active().matmul(a, b)
}

/// C = A @ B into a pre-allocated output (hot path avoids allocation).
pub fn matmul_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    backend::active().matmul_into(a, b, c);
}

/// C = A @ B into a pre-allocated output with caller-owned scratch — the
/// zero-allocation form (ADR-003).
pub fn matmul_into_ws(a: &Tensor, b: &Tensor, c: &mut Tensor, ws: &mut Workspace) {
    backend::active().matmul_into_ws(a, b, c, ws);
}

/// C = A^T @ A for A: (n, d) -> (d, d).
pub fn gram_t(a: &Tensor) -> Tensor {
    backend::active().gram_t(a)
}

/// C = A^T @ A into a pre-allocated (d, d) output with caller scratch.
pub fn gram_t_into_ws(a: &Tensor, c: &mut Tensor, ws: &mut Workspace) {
    backend::active().gram_t_into_ws(a, c, ws);
}

/// K = A @ A^T for A: (n, d) -> (n, n). The predictor's example-Gram.
pub fn gram(a: &Tensor) -> Tensor {
    backend::active().gram(a)
}

/// K = A @ A^T into a pre-allocated (n, n) output with caller scratch.
pub fn gram_into_ws(a: &Tensor, c: &mut Tensor, ws: &mut Workspace) {
    backend::active().gram_into_ws(a, c, ws);
}

/// y = A @ x (matrix-vector).
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (m, k) = (a.rows(), a.cols());
    assert_eq!(k, x.len());
    let mut y = vec![0.0; m];
    matvec_into(a, x, &mut y);
    y
}

/// y = A @ x into pre-allocated output.
pub fn matvec_into(a: &Tensor, x: &[f32], y: &mut [f32]) {
    let (m, k) = (a.rows(), a.cols());
    assert_eq!(k, x.len());
    assert_eq!(m, y.len());
    let be = backend::active();
    for i in 0..m {
        y[i] = be.dot(&a.data[i * k..(i + 1) * k], x);
    }
}

/// y = A^T @ x for A: (n, d), x: (n,) -> (d,). Row-major friendly: walks
/// A's rows, accumulating x[i] * row_i.
pub fn matvec_t(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (n, d) = (a.rows(), a.cols());
    assert_eq!(n, x.len());
    let mut y = vec![0.0; d];
    for i in 0..n {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = &a.data[i * d..(i + 1) * d];
        for (yv, rv) in y.iter_mut().zip(row) {
            *yv += xi * rv;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Backend;
    use crate::util::rng::Pcg64;

    fn rand_t(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    #[test]
    fn matches_naive_various_shapes() {
        let mut rng = Pcg64::seeded(10);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 64, 64), (10, 300, 7)] {
            let a = rand_t(&mut rng, &[m, k]);
            let b = rand_t(&mut rng, &[k, n]);
            let c = matmul(&a, &b);
            let want = Backend::naive().matmul(&a, &b);
            for (x, y) in c.data.iter().zip(&want.data) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seeded(11);
        let a = rand_t(&mut rng, &[9, 9]);
        assert_eq!(matmul(&a, &Tensor::eye(9)).data, a.data);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Pcg64::seeded(12);
        let a = rand_t(&mut rng, &[13, 7]);
        let g1 = gram(&a);
        let g2 = matmul(&a, &a.t());
        for (x, y) in g1.data.iter().zip(&g2.data) {
            assert!((x - y).abs() < 1e-4);
        }
        let gt1 = gram_t(&a);
        let gt2 = matmul(&a.t(), &a);
        for (x, y) in gt1.data.iter().zip(&gt2.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matvec_consistency() {
        let mut rng = Pcg64::seeded(13);
        let a = rand_t(&mut rng, &[6, 11]);
        let x: Vec<f32> = (0..11).map(|i| i as f32 * 0.3 - 1.0).collect();
        let y = matvec(&a, &x);
        let xt = Tensor::from_vec(x.clone(), &[11, 1]);
        let want = matmul(&a, &xt);
        for (u, v) in y.iter().zip(&want.data) {
            assert!((u - v).abs() < 1e-4);
        }
        // A^T x via matvec_t equals matvec on transposed copy
        let z: Vec<f32> = (0..6).map(|i| 0.1 * i as f32 + 0.5).collect();
        let t1 = matvec_t(&a, &z);
        let t2 = matvec(&a.t(), &z);
        for (u, v) in t1.iter().zip(&t2) {
            assert!((u - v).abs() < 1e-4);
        }
    }
}
