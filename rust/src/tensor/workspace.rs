//! Workspace arena: caller-owned scratch buffers for the dense hot path
//! (DESIGN.md ADR-003).
//!
//! Every workspace-aware kernel entry point (`matmul_into_ws`,
//! `gram_t_into_ws`, `gram_into_ws`, `newton_schulz_into`, `fit_with_ws`)
//! takes a `&mut Workspace` instead of allocating its own scratch. The
//! arena is a best-fit free list of `Vec<f32>` buffers: `take(len)` hands
//! out a zeroed buffer, reusing the smallest pooled allocation whose
//! capacity suffices; `give` returns it for the next call. After one
//! warm-up pass through a steady-state loop the pool holds every buffer
//! the loop needs concurrently and `take` never touches the heap again —
//! the property the `alloc-counter` feature's test asserts.
//!
//! Buffers are *owned* `Vec<f32>`s moved out of and back into the pool,
//! so checked-out buffers carry no lifetime tie to the workspace and the
//! workspace itself stays available for nested kernel calls (e.g. the
//! micro backend's B-panel pack inside `newton_schulz_into`).

use super::Tensor;

/// Reusable scratch-buffer arena. Cheap to construct (`new` allocates
/// nothing); hold one per long-lived hot loop and thread it down.
#[derive(Default)]
pub struct Workspace {
    pool: Vec<Vec<f32>>,
    /// Recycled shape vectors for [`take_tensor`](Workspace::take_tensor),
    /// so tensor checkout allocates nothing once warm (the shape `Vec` of
    /// a `Tensor` is itself heap storage).
    shapes: Vec<Vec<usize>>,
    takes: usize,
    misses: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Check out a zeroed buffer of exactly `len` elements. Reuses the
    /// smallest pooled buffer with sufficient capacity (best fit keeps a
    /// warm pool matched to a repeating take sequence); allocates only on
    /// a pool miss.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        self.takes += 1;
        let mut best: Option<usize> = None;
        for (i, b) in self.pool.iter().enumerate() {
            if b.capacity() < len {
                continue;
            }
            if best.map_or(true, |j| self.pool[j].capacity() > b.capacity()) {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                let mut v = self.pool.swap_remove(i);
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => {
                self.misses += 1;
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer to the pool for reuse. Zero-capacity buffers are
    /// dropped (nothing to reuse).
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.pool.push(buf);
        }
    }

    /// [`take`](Workspace::take) with slack for SIMD panel packing
    /// (ADR-007): returns a zeroed buffer of `len + 7` elements plus the
    /// element offset at which a 32-byte (f32x8) boundary falls, so the
    /// caller can re-base `&buf[off..off + len]` onto an aligned panel
    /// and use aligned vector loads. Return the buffer with plain
    /// [`give`](Workspace::give); the offset is recomputed per checkout
    /// because the best-fit pool may hand back differently based storage.
    pub fn take_aligned32(&mut self, len: usize) -> (Vec<f32>, usize) {
        let buf = self.take(len + 7);
        let off = buf.as_ptr().align_offset(32);
        debug_assert!(off <= 7, "f32 storage must reach a 32B boundary within 7 elements");
        (buf, off)
    }

    /// [`take`] wrapped in a shaped [`Tensor`] (zeroed). The shape vector
    /// is recycled from returned tensors, so a warmed take/give cycle does
    /// not touch the heap at all.
    pub fn take_tensor(&mut self, shape: &[usize]) -> Tensor {
        let len = shape.iter().product();
        let mut sh = self.shapes.pop().unwrap_or_default();
        sh.clear();
        sh.extend_from_slice(shape);
        Tensor { data: self.take(len), shape: sh }
    }

    /// Return a tensor's storage (data and shape vector) to the pool.
    pub fn give_tensor(&mut self, t: Tensor) {
        self.give(t.data);
        if t.shape.capacity() > 0 {
            self.shapes.push(t.shape);
        }
    }

    /// Total `take` calls since construction.
    pub fn takes(&self) -> usize {
        self.takes
    }

    /// `take` calls that had to allocate (pool miss). In a warmed
    /// steady-state loop this stops growing.
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_returns_zeroed_buffers_even_after_reuse() {
        let mut ws = Workspace::new();
        let mut a = ws.take(8);
        a.iter_mut().for_each(|v| *v = 7.0);
        ws.give(a);
        let b = ws.take(8);
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|&v| v == 0.0), "reused buffer must be re-zeroed");
        ws.give(b);
    }

    #[test]
    fn steady_state_take_sequence_stops_missing() {
        let mut ws = Workspace::new();
        for round in 0..4 {
            let x = ws.take(100);
            let y = ws.take(200);
            let z = ws.take(50);
            ws.give(x);
            ws.give(y);
            ws.give(z);
            if round == 0 {
                assert_eq!(ws.misses(), 3);
            }
        }
        // After warm-up every repeat of the same sequence is served from
        // the pool.
        assert_eq!(ws.misses(), 3, "steady-state takes must not allocate");
        assert_eq!(ws.takes(), 12);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_buffer() {
        let mut ws = Workspace::new();
        let big = ws.take(1000);
        let small = ws.take(10);
        ws.give(big);
        ws.give(small);
        let got = ws.take(10);
        assert!(got.capacity() < 1000, "should reuse the small buffer");
        ws.give(got);
        assert_eq!(ws.pooled(), 2);
    }

    #[test]
    fn tensor_round_trip() {
        let mut ws = Workspace::new();
        let t = ws.take_tensor(&[3, 4]);
        assert_eq!(t.shape, vec![3, 4]);
        assert_eq!(t.data.len(), 12);
        ws.give_tensor(t);
        let t2 = ws.take_tensor(&[2, 6]);
        assert_eq!(t2.data.len(), 12);
        assert_eq!(ws.misses(), 1, "second tensor reuses the first's storage");
    }

    #[test]
    fn aligned_take_reaches_a_32b_boundary() {
        let mut ws = Workspace::new();
        for _ in 0..3 {
            let (buf, off) = ws.take_aligned32(64);
            assert!(off + 64 <= buf.len());
            assert_eq!(buf[off..].as_ptr() as usize % 32, 0, "panel base must be 32B-aligned");
            ws.give(buf);
        }
    }

    #[test]
    fn zero_len_take_is_fine() {
        let mut ws = Workspace::new();
        let v = ws.take(0);
        assert!(v.is_empty());
        ws.give(v);
        assert_eq!(ws.pooled(), 0);
    }
}
