//! Explicit AVX2+FMA f32x8 kernels — the `simd` backend (DESIGN.md
//! ADR-007).
//!
//! The scalar `micro` backend plateaus at register-tiling's ceiling:
//! every multiply–add retires one lane. These kernels run the same loop
//! nests over 8-lane `__m256` vectors with fused multiply–adds, which is
//! where the remaining single-core headroom lives. The matmul keeps the
//! ADR-003 structure — a packed shared-operand panel reused across row
//! blocks — but the panel is 16 columns wide (two vector registers) and
//! re-based to a 32-byte boundary inside the workspace slab
//! ([`Workspace::take_aligned32`]) so the inner loop's B reads are
//! aligned vector loads.
//!
//! # Safety model (the ADR-007 argument, in short)
//!
//! - Every `unsafe` intrinsics block in the crate lives in this file.
//! - The `#[target_feature(enable = "avx2,fma")]` kernels are reachable
//!   only through [`SimdBackend`], and `Backend::simd()` hands one out
//!   only after [`simd_available`] confirms both features at runtime; on
//!   any other host it falls back to `micro` (warn-once). Each trait
//!   method additionally `debug_assert!`s availability.
//! - All pointer arithmetic is derived from slice lengths that the safe
//!   [`Backend`](super::backend::Backend) wrappers shape-check before
//!   dispatching; partial vectors at row/column tails go through a stack
//!   staging buffer, never past the end of an operand.
//! - The banding contract of `matmul_rows`/`gram_t_rows` (bitwise
//!   identity under any row partition, required by the pooled executor's
//!   determinism guarantee) holds because the 1-row and 4-row kernels
//!   perform the identical per-row FMA sequence: the k-loop order and
//!   per-lane rounding of an output row never depend on which rows share
//!   its block.

use super::backend::TensorBackend;
use super::{Tensor, Workspace};

/// `true` when the running CPU has the AVX2 and FMA features these
/// kernels require. Checked at runtime (`is_x86_feature_detected!`), so a
/// binary built for the default x86-64 target still runs — and falls back
/// to `micro` — on older hosts.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Detected kernel feature set as a stable string. Part of the
/// calibration-cache key and payload, so a cache written on an AVX2 host
/// can never silently pin `simd` on a host that lacks it.
pub fn cpu_features() -> &'static str {
    if simd_available() {
        "avx2+fma"
    } else {
        "scalar"
    }
}

/// f32x8 kernels behind [`TensorBackend`]. Constructed as a static in
/// `backend.rs` but only ever *dispatched* when [`simd_available`]
/// (`Backend::simd()` resolves to `micro` otherwise).
pub struct SimdBackend;

/// Panel width in columns: two `__m256` registers per packed B-panel row.
const NR: usize = 16;
/// Output rows per register tile (4 rows x 16 cols = 8 accumulators).
const MR: usize = 4;

#[cfg(target_arch = "x86_64")]
mod kernels {
    use std::arch::x86_64::*;

    use super::NR;

    /// Horizontal sum of one vector of partial sums.
    ///
    /// # Safety
    /// AVX2 must be available (caller is a `target_feature` kernel).
    #[inline]
    unsafe fn hsum(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps(v, 1);
        let lo = _mm256_castps256_ps128(v);
        let s4 = _mm_add_ps(lo, hi);
        let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
        let s1 = _mm_add_ss(s2, _mm_shuffle_ps(s2, s2, 1));
        _mm_cvtss_f32(s1)
    }

    /// 4-accumulator FMA dot product (32 elements per iteration).
    ///
    /// # Safety
    /// Caller guarantees AVX2+FMA and `a.len() == b.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add(i + 8)),
                acc1,
            );
            acc2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 16)),
                _mm256_loadu_ps(pb.add(i + 16)),
                acc2,
            );
            acc3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 24)),
                _mm256_loadu_ps(pb.add(i + 24)),
                acc3,
            );
            i += 32;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(
            _mm256_add_ps(acc0, acc1),
            _mm256_add_ps(acc2, acc3),
        ));
        while i < n {
            s += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        s
    }

    /// Store the leading `t < 8` lanes of `v` at `dst` via a stack
    /// staging buffer (no masked stores needed, no out-of-bounds write).
    ///
    /// # Safety
    /// `dst` must be valid for `t` writes.
    #[inline]
    unsafe fn store_tail(v: __m256, dst: *mut f32, t: usize) {
        let mut buf = [0.0f32; 8];
        _mm256_storeu_ps(buf.as_mut_ptr(), v);
        std::ptr::copy_nonoverlapping(buf.as_ptr(), dst, t);
    }

    /// Store one row's pair of accumulators into `w <= 16` output cells.
    ///
    /// # Safety
    /// `c` must be valid for `w` writes.
    #[inline]
    unsafe fn store_row(v0: __m256, v1: __m256, c: *mut f32, w: usize) {
        if w == NR {
            _mm256_storeu_ps(c, v0);
            _mm256_storeu_ps(c.add(8), v1);
        } else if w >= 8 {
            _mm256_storeu_ps(c, v0);
            store_tail(v1, c.add(8), w - 8);
        } else {
            store_tail(v0, c, w);
        }
    }

    /// The 4x16 register tile: rows `c[0..4][0..w]` = A-rows @ panel,
    /// full k reduction in 8 accumulators. The panel is `k` rows of 16
    /// floats, 32-byte aligned (zero-padded when the logical width is
    /// `w < 16`, so the kernel itself is branch-free until the store).
    ///
    /// # Safety
    /// Caller guarantees AVX2+FMA; `a` points at 4 consecutive length-`k`
    /// rows with stride `a_stride`; `panel` holds `k * 16` floats at a
    /// 32-byte boundary; `c` points at 4 output row segments of `w`
    /// writable floats with stride `c_stride`.
    #[allow(clippy::missing_safety_doc)]
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn mm4x16(
        a: *const f32,
        a_stride: usize,
        panel: *const f32,
        k: usize,
        c: *mut f32,
        c_stride: usize,
        w: usize,
    ) {
        let mut acc00 = _mm256_setzero_ps();
        let mut acc01 = _mm256_setzero_ps();
        let mut acc10 = _mm256_setzero_ps();
        let mut acc11 = _mm256_setzero_ps();
        let mut acc20 = _mm256_setzero_ps();
        let mut acc21 = _mm256_setzero_ps();
        let mut acc30 = _mm256_setzero_ps();
        let mut acc31 = _mm256_setzero_ps();
        let (a0, a1) = (a, a.add(a_stride));
        let (a2, a3) = (a.add(2 * a_stride), a.add(3 * a_stride));
        for kk in 0..k {
            let b0 = _mm256_load_ps(panel.add(kk * NR));
            let b1 = _mm256_load_ps(panel.add(kk * NR + 8));
            let v0 = _mm256_set1_ps(*a0.add(kk));
            acc00 = _mm256_fmadd_ps(v0, b0, acc00);
            acc01 = _mm256_fmadd_ps(v0, b1, acc01);
            let v1 = _mm256_set1_ps(*a1.add(kk));
            acc10 = _mm256_fmadd_ps(v1, b0, acc10);
            acc11 = _mm256_fmadd_ps(v1, b1, acc11);
            let v2 = _mm256_set1_ps(*a2.add(kk));
            acc20 = _mm256_fmadd_ps(v2, b0, acc20);
            acc21 = _mm256_fmadd_ps(v2, b1, acc21);
            let v3 = _mm256_set1_ps(*a3.add(kk));
            acc30 = _mm256_fmadd_ps(v3, b0, acc30);
            acc31 = _mm256_fmadd_ps(v3, b1, acc31);
        }
        store_row(acc00, acc01, c, w);
        store_row(acc10, acc11, c.add(c_stride), w);
        store_row(acc20, acc21, c.add(2 * c_stride), w);
        store_row(acc30, acc31, c.add(3 * c_stride), w);
    }

    /// Remainder-row (m % 4) variant of [`mm4x16`]: one output row, same
    /// per-row FMA sequence as the 4-row tile (the banding-invariance
    /// contract depends on this).
    ///
    /// # Safety
    /// Same contract as [`mm4x16`] for a single row.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn mm1x16(a: *const f32, panel: *const f32, k: usize, c: *mut f32, w: usize) {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        for kk in 0..k {
            let b0 = _mm256_load_ps(panel.add(kk * NR));
            let b1 = _mm256_load_ps(panel.add(kk * NR + 8));
            let v = _mm256_set1_ps(*a.add(kk));
            acc0 = _mm256_fmadd_ps(v, b0, acc0);
            acc1 = _mm256_fmadd_ps(v, b1, acc1);
        }
        store_row(acc0, acc1, c, w);
    }

    /// Fused symmetric rank-4 row update (the ADR-003 gram_t quad,
    /// vectorized): `c_row[j] += x0*r0[j] + x1*r1[j] + x2*r2[j] +
    /// x3*r3[j]` for `j in j0..d`. The vector/scalar split point depends
    /// only on `(j0, d)`, never on banding.
    ///
    /// # Safety
    /// Caller guarantees AVX2+FMA; `c_row` and `r0..r3` must be valid for
    /// `d` reads/writes.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn rank4_update(
        c_row: *mut f32,
        j0: usize,
        d: usize,
        x: [f32; 4],
        r0: *const f32,
        r1: *const f32,
        r2: *const f32,
        r3: *const f32,
    ) {
        let x0 = _mm256_set1_ps(x[0]);
        let x1 = _mm256_set1_ps(x[1]);
        let x2 = _mm256_set1_ps(x[2]);
        let x3 = _mm256_set1_ps(x[3]);
        let mut j = j0;
        while j + 8 <= d {
            let mut cv = _mm256_loadu_ps(c_row.add(j));
            cv = _mm256_fmadd_ps(x0, _mm256_loadu_ps(r0.add(j)), cv);
            cv = _mm256_fmadd_ps(x1, _mm256_loadu_ps(r1.add(j)), cv);
            cv = _mm256_fmadd_ps(x2, _mm256_loadu_ps(r2.add(j)), cv);
            cv = _mm256_fmadd_ps(x3, _mm256_loadu_ps(r3.add(j)), cv);
            _mm256_storeu_ps(c_row.add(j), cv);
            j += 8;
        }
        while j < d {
            *c_row.add(j) +=
                x[0] * *r0.add(j) + x[1] * *r1.add(j) + x[2] * *r2.add(j) + x[3] * *r3.add(j);
            j += 1;
        }
    }

    /// Rank-1 remainder-row variant of [`rank4_update`].
    ///
    /// # Safety
    /// Same contract as [`rank4_update`] for a single sample row.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn rank1_update(c_row: *mut f32, j0: usize, d: usize, xi: f32, r: *const f32) {
        let xv = _mm256_set1_ps(xi);
        let mut j = j0;
        while j + 8 <= d {
            let cv = _mm256_fmadd_ps(xv, _mm256_loadu_ps(r.add(j)), _mm256_loadu_ps(c_row.add(j)));
            _mm256_storeu_ps(c_row.add(j), cv);
            j += 8;
        }
        while j < d {
            *c_row.add(j) += xi * *r.add(j);
            j += 1;
        }
    }
}

#[cfg(target_arch = "x86_64")]
impl TensorBackend for SimdBackend {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert!(simd_available(), "simd backend dispatched without AVX2+FMA");
        debug_assert_eq!(a.len(), b.len());
        // SAFETY: feature presence is guaranteed by Backend::simd()'s
        // runtime gate; lengths are equal (checked by the Backend handle).
        unsafe { kernels::dot(a, b) }
    }

    fn matmul_rows(
        &self,
        a: &Tensor,
        b: &Tensor,
        r0: usize,
        r1: usize,
        c_rows: &mut [f32],
        ws: &mut Workspace,
    ) {
        debug_assert!(simd_available(), "simd backend dispatched without AVX2+FMA");
        let k = a.cols();
        let n = b.cols();
        let m = r1 - r0;
        c_rows.fill(0.0);
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        let full_blocks = m / MR;
        // One 16-wide aligned panel serves every column tile; narrower
        // last tiles zero-pad so the register tile stays branch-free.
        let (mut panel_buf, off) = ws.take_aligned32(k * NR);
        for j0 in (0..n).step_by(NR) {
            let j1 = (j0 + NR).min(n);
            let w = j1 - j0;
            let panel = &mut panel_buf[off..off + k * NR];
            if w < NR {
                panel.fill(0.0);
            }
            for kk in 0..k {
                panel[kk * NR..kk * NR + w].copy_from_slice(&b.data[kk * n + j0..kk * n + j1]);
            }
            // SAFETY: row/column indices are bounded by (m, k, n) from
            // the shape-checked operands; the panel holds k*16 floats at
            // a 32-byte boundary; store widths are clamped to w.
            unsafe {
                let pa = a.data.as_ptr();
                let pp = panel.as_ptr();
                let pc = c_rows.as_mut_ptr();
                for blk in 0..full_blocks {
                    kernels::mm4x16(
                        pa.add((r0 + blk * MR) * k),
                        k,
                        pp,
                        k,
                        pc.add(blk * MR * n + j0),
                        n,
                        w,
                    );
                }
                for i in full_blocks * MR..m {
                    kernels::mm1x16(pa.add((r0 + i) * k), pp, k, pc.add(i * n + j0), w);
                }
            }
        }
        ws.give(panel_buf);
    }

    fn gram_t_rows(&self, a: &Tensor, i0: usize, i1: usize, c_rows: &mut [f32], _ws: &mut Workspace) {
        debug_assert!(simd_available(), "simd backend dispatched without AVX2+FMA");
        let (n, d) = (a.rows(), a.cols());
        c_rows.fill(0.0);
        if i1 <= i0 || d == 0 {
            return;
        }
        let quads = n / 4;
        // SAFETY: all row pointers index within a.data (n*d floats) and
        // c_rows ((i1-i0)*d floats); the update kernels stop at d.
        unsafe {
            let pa = a.data.as_ptr();
            let pc = c_rows.as_mut_ptr();
            for q in 0..quads {
                let r0 = pa.add(4 * q * d);
                let r1 = r0.add(d);
                let r2 = r0.add(2 * d);
                let r3 = r0.add(3 * d);
                for i in i0..i1 {
                    let x = [*r0.add(i), *r1.add(i), *r2.add(i), *r3.add(i)];
                    kernels::rank4_update(pc.add((i - i0) * d), i, d, x, r0, r1, r2, r3);
                }
            }
            for row in 4 * quads..n {
                let r = pa.add(row * d);
                for i in i0..i1 {
                    kernels::rank1_update(pc.add((i - i0) * d), i, d, *r.add(i), r);
                }
            }
        }
    }
}

/// Non-x86_64 builds still need the type to exist (the static in
/// `backend.rs` is unconditional), but [`simd_available`] is `false`
/// there, so `Backend::simd()` always resolves to `micro` and these
/// bodies are unreachable.
#[cfg(not(target_arch = "x86_64"))]
impl TensorBackend for SimdBackend {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn dot(&self, _a: &[f32], _b: &[f32]) -> f32 {
        unreachable!("simd backend dispatched on a non-x86_64 target")
    }

    fn matmul_rows(
        &self,
        _a: &Tensor,
        _b: &Tensor,
        _r0: usize,
        _r1: usize,
        _c_rows: &mut [f32],
        _ws: &mut Workspace,
    ) {
        unreachable!("simd backend dispatched on a non-x86_64 target")
    }

    fn gram_t_rows(
        &self,
        _a: &Tensor,
        _i0: usize,
        _i1: usize,
        _c_rows: &mut [f32],
        _ws: &mut Workspace,
    ) {
        unreachable!("simd backend dispatched on a non-x86_64 target")
    }
}

#[cfg(all(test, target_arch = "x86_64"))]
mod tests {
    use super::*;
    use crate::tensor::backend::Backend;
    use crate::util::rng::Pcg64;

    fn rand_t(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    #[test]
    fn simd_kernels_match_naive_when_available() {
        if !simd_available() {
            eprintln!("SKIP: host lacks AVX2+FMA");
            return;
        }
        let mut rng = Pcg64::seeded(123);
        let (naive, simd) = (Backend::naive(), Backend::simd());
        assert_eq!(simd.name(), "simd");
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (4, 8, 16),
            (5, 7, 3),
            (17, 33, 9),
            (12, 20, 31),
            (33, 16, 40),
        ] {
            let a = rand_t(&mut rng, &[m, k]);
            let b = rand_t(&mut rng, &[k, n]);
            let want = naive.matmul(&a, &b);
            let got = simd.matmul(&a, &b);
            for (x, y) in got.data.iter().zip(&want.data) {
                assert!((x - y).abs() <= 1e-4 * (1.0 + y.abs()), "{m}x{k}x{n}: {x} vs {y}");
            }
            let want_g = naive.gram_t(&a);
            let got_g = simd.gram_t(&a);
            for (x, y) in got_g.data.iter().zip(&want_g.data) {
                assert!((x - y).abs() <= 1e-4 * (1.0 + y.abs()), "gram_t {m}x{k}: {x} vs {y}");
            }
        }
        let mut a = vec![0.0f32; 1037];
        let mut b = vec![0.0f32; 1037];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let want: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
        let got = simd.dot(&a, &b) as f64;
        assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()), "{got} vs {want}");
    }

    #[test]
    fn feature_string_is_stable() {
        assert!(["avx2+fma", "scalar"].contains(&cpu_features()));
        assert_eq!(simd_available(), cpu_features() == "avx2+fma");
    }
}
