//! Dense linear-algebra routines for the predictor fit and Muon.
//!
//! - `eigh_jacobi`: symmetric eigendecomposition (cyclic Jacobi) — powers
//!   the Gram-trick SVD that recovers the paper's rank-r NTK basis U.
//! - `cholesky_solve`: SPD solves for the kernel-ridge dual coefficients.
//! - `newton_schulz`: the quintic orthogonalization iteration used by the
//!   Muon optimizer (Jordan et al., 2024), the paper's training optimizer.

use super::{backend, backend::Backend, Tensor, Workspace};

/// Symmetric eigendecomposition by cyclic Jacobi rotations.
///
/// Returns (eigenvalues ascending, eigenvectors as columns). Input must be
/// symmetric n x n; n is small here (the fit-batch size, <= a few hundred).
pub fn eigh_jacobi(a: &Tensor) -> (Vec<f32>, Tensor) {
    let n = a.rows();
    assert_eq!(a.cols(), n, "eigh needs a square matrix");
    let mut m: Vec<f64> = a.data.iter().map(|&v| v as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[p * n + q] * m[p * n + q];
            }
        }
        if off.sqrt() < 1e-11 * (1.0 + frob64(&m)) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    // Extract and sort ascending.
    let mut order: Vec<usize> = (0..n).collect();
    let evals_raw: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    order.sort_by(|&i, &j| evals_raw[i].partial_cmp(&evals_raw[j]).unwrap());
    let evals: Vec<f32> = order.iter().map(|&i| evals_raw[i] as f32).collect();
    let mut vecs = Tensor::zeros(&[n, n]);
    for (new_col, &old_col) in order.iter().enumerate() {
        for row in 0..n {
            vecs.data[row * n + new_col] = v[row * n + old_col] as f32;
        }
    }
    (evals, vecs)
}

fn frob64(m: &[f64]) -> f64 {
    m.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Solve (A) X = B for SPD A via Cholesky. A: (n, n), B: (n, k).
/// Factorization in f64 for stability; returns X: (n, k).
pub fn cholesky_solve(a: &Tensor, b: &Tensor) -> anyhow::Result<Tensor> {
    let n = a.rows();
    anyhow::ensure!(a.cols() == n, "cholesky needs square A");
    anyhow::ensure!(b.rows() == n, "rhs rows must match A");
    let k = b.cols();
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) as f64;
            for p in 0..j {
                s -= l[i * n + p] * l[j * n + p];
            }
            if i == j {
                anyhow::ensure!(s > 0.0, "matrix not positive definite at pivot {i} (s={s})");
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    // Forward then backward substitution per column.
    let mut x = Tensor::zeros(&[n, k]);
    let mut y = vec![0.0f64; n];
    for col in 0..k {
        for i in 0..n {
            let mut s = b.at(i, col) as f64;
            for p in 0..i {
                s -= l[i * n + p] * y[p];
            }
            y[i] = s / l[i * n + i];
        }
        for i in (0..n).rev() {
            let mut s = y[i];
            for p in (i + 1)..n {
                s -= l[p * n + i] * x.at(p, col) as f64;
            }
            x.set(i, col, (s / l[i * n + i]) as f32);
        }
    }
    Ok(x)
}

/// Newton–Schulz quintic orthogonalization (Muon's core step).
///
/// Maps G to an approximate UV^T where G = U S V^T — i.e. sets all singular
/// values to ~1. Coefficients (3.4445, -4.7750, 2.0315) and 5 iterations
/// follow Jordan et al. (2024). Input (m, n); operates on the smaller side.
pub fn newton_schulz(g: &Tensor, steps: usize) -> Tensor {
    newton_schulz_with(backend::active(), g, steps)
}

/// [`newton_schulz`] with an explicit tensor backend (Muon threads its
/// configured backend through here; benches pin specific ones). Allocating
/// convenience over [`newton_schulz_into`].
pub fn newton_schulz_with(be: Backend, g: &Tensor, steps: usize) -> Tensor {
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(&[g.rows(), g.cols()]);
    newton_schulz_into(be, g, steps, &mut out, &mut ws);
    out
}

/// Newton–Schulz into a caller-owned output, with every intermediate drawn
/// from the caller's [`Workspace`] — the zero-allocation form the Muon
/// optimizer uses every update (ADR-003). `out` must match `g`'s shape.
pub fn newton_schulz_into(
    be: Backend,
    g: &Tensor,
    steps: usize,
    out: &mut Tensor,
    ws: &mut Workspace,
) {
    newton_schulz_into_with(
        be,
        |a, b, c, ws| be.matmul_into_ws(a, b, c, ws),
        g,
        steps,
        out,
        ws,
    );
}

/// [`newton_schulz_into`] with the dense matmuls routed through a caller
/// closure, so the session can parallelize the iteration's large products
/// across the persistent worker pool (ADR-007) without `linalg` knowing
/// about the pool. `mm` must compute `c = a @ b` with results bitwise
/// identical to `be.matmul_into_ws` (the pooled path guarantees this via
/// the banding contract); `be` still handles the symmetric Gram fill.
pub fn newton_schulz_into_with<F>(
    be: Backend,
    mut mm: F,
    g: &Tensor,
    steps: usize,
    out: &mut Tensor,
    ws: &mut Workspace,
) where
    F: FnMut(&Tensor, &Tensor, &mut Tensor, &mut Workspace),
{
    let (m, n) = (g.rows(), g.cols());
    // stack-array comparison: the hot path's shape check must not allocate
    assert_eq!(out.shape, [m, n], "newton_schulz output shape mismatch");
    let transposed = m > n;
    let (rows, cols) = if transposed { (n, m) } else { (m, n) };
    // Operate on the smaller side: x is (rows, cols) with rows <= cols.
    let mut x = ws.take_tensor(&[rows, cols]);
    if transposed {
        for i in 0..m {
            for j in 0..n {
                x.data[j * m + i] = g.data[i * n + j];
            }
        }
    } else {
        x.data.copy_from_slice(&g.data);
    }
    // Normalize so singular values are <= 1 (required for convergence).
    let norm = x.frob_norm().max(1e-12);
    x.scale(1.0 / norm);
    const A: f32 = 3.4445;
    const B: f32 = -4.7750;
    const C: f32 = 2.0315;
    let mut xxt = ws.take_tensor(&[rows, rows]);
    let mut xxt2 = ws.take_tensor(&[rows, rows]);
    let mut next = ws.take_tensor(&[rows, cols]);
    for _ in 0..steps {
        // aX + b(XX^T)X + c(XX^T)^2 X
        be.gram_into_ws(&x, &mut xxt, ws); // XX^T, symmetric fill
        mm(&xxt, &xxt, &mut xxt2, ws);
        // combo = b·XX^T + c·(XX^T)², fused in place over xxt
        for (xv, yv) in xxt.data.iter_mut().zip(&xxt2.data) {
            *xv = B * *xv + C * yv;
        }
        mm(&xxt, &x, &mut next, ws);
        for (nv, xv) in next.data.iter_mut().zip(&x.data) {
            *nv += A * xv;
        }
        std::mem::swap(&mut x, &mut next);
    }
    if transposed {
        for i in 0..rows {
            for j in 0..cols {
                out.data[j * n + i] = x.data[i * cols + j];
            }
        }
    } else {
        out.data.copy_from_slice(&x.data);
    }
    ws.give_tensor(x);
    ws.give_tensor(xxt);
    ws.give_tensor(xxt2);
    ws.give_tensor(next);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::{gram, matmul};
    use crate::util::rng::Pcg64;

    fn rand_t(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    #[test]
    fn eigh_reconstructs_symmetric_matrix() {
        let mut rng = Pcg64::seeded(20);
        for n in [1usize, 2, 5, 12, 30] {
            let a = rand_t(&mut rng, &[n, 8.max(n)]);
            let sym = gram(&a); // PSD symmetric
            let (w, v) = eigh_jacobi(&sym);
            // Reconstruct V diag(w) V^T
            let mut vd = v.clone();
            for i in 0..n {
                for j in 0..n {
                    vd.data[i * n + j] *= w[j];
                }
            }
            let rec = matmul(&vd, &v.t());
            let scale = 1.0 + sym.frob_norm();
            for (x, y) in rec.data.iter().zip(&sym.data) {
                assert!((x - y).abs() < 2e-3 * scale, "n={n}: {x} vs {y}");
            }
            // Eigenvalues of a PSD matrix are >= 0 (tolerance).
            assert!(w.iter().all(|&x| x > -1e-3 * scale));
            // Ascending order.
            for k in 1..n {
                assert!(w[k] >= w[k - 1] - 1e-5);
            }
        }
    }

    #[test]
    fn eigh_eigenvectors_orthonormal() {
        let mut rng = Pcg64::seeded(21);
        let a = rand_t(&mut rng, &[10, 10]);
        let sym = gram(&a);
        let (_, v) = eigh_jacobi(&sym);
        let vtv = matmul(&v.t(), &v);
        for i in 0..10 {
            for j in 0..10 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv.at(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn eigh_known_answer() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Tensor::from_vec(vec![2., 1., 1., 2.], &[2, 2]);
        let (w, _) = eigh_jacobi(&a);
        assert!((w[0] - 1.0).abs() < 1e-5);
        assert!((w[1] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn cholesky_solves_spd_system() {
        let mut rng = Pcg64::seeded(22);
        let a = rand_t(&mut rng, &[15, 15]);
        let mut spd = gram(&a);
        for i in 0..15 {
            spd.data[i * 15 + i] += 1.0; // well-conditioned
        }
        let x_true = rand_t(&mut rng, &[15, 3]);
        let b = matmul(&spd, &x_true);
        let x = cholesky_solve(&spd, &b).unwrap();
        for (u, v) in x.data.iter().zip(&x_true.data) {
            assert!((u - v).abs() < 1e-2, "{u} vs {v}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Tensor::from_vec(vec![1., 2., 2., 1.], &[2, 2]); // eigenvalues 3, -1
        assert!(cholesky_solve(&a, &Tensor::zeros(&[2, 1])).is_err());
    }

    /// Exact polar factor UV^T via the eigendecomposition of G G^T.
    fn exact_polar(g: &Tensor) -> Tensor {
        let (m, n) = (g.rows(), g.cols());
        if m > n {
            return exact_polar(&g.t()).t();
        }
        let ggt = matmul(g, &g.t()); // (m, m)
        let (w, v) = eigh_jacobi(&ggt);
        // W = V diag(1/sqrt(w)) V^T G
        let mut vs = v.clone();
        for i in 0..m {
            for j in 0..m {
                vs.data[i * m + j] *= 1.0 / w[j].max(1e-12).sqrt();
            }
        }
        let inv_sqrt = matmul(&vs, &v.t());
        matmul(&inv_sqrt, g)
    }

    #[test]
    fn newton_schulz_orthogonalizes() {
        // The quintic NS iteration (Muon) does NOT converge σ → 1 exactly;
        // it settles singular values in a band around 1 (≈[0.7, 1.2]).
        // The right contract: the output is close in *direction* to the
        // exact polar factor UV^T, and its singular values live in that
        // band. That is what makes the Muon update well-scaled.
        let mut rng = Pcg64::seeded(23);
        for &(m, n) in &[(8usize, 8usize), (6, 12), (12, 6)] {
            let g = rand_t(&mut rng, &[m, n]);
            let o = newton_schulz(&g, 5);
            let w = exact_polar(&g);
            let cos = crate::tensor::stats::cosine(&o.data, &w.data);
            assert!(cos > 0.95, "({m},{n}) cosine to polar factor {cos}");
            // Singular values (via Gram eigenvalues) within the NS band.
            let gram_small = if m <= n {
                matmul(&o, &o.t())
            } else {
                matmul(&o.t(), &o)
            };
            let (evals, _) = eigh_jacobi(&gram_small);
            for &e in &evals {
                let sigma = e.max(0.0).sqrt();
                assert!(
                    (0.4..=1.5).contains(&sigma),
                    "({m},{n}) singular value {sigma} outside NS band"
                );
            }
        }
    }

    #[test]
    fn newton_schulz_into_matches_allocating_form_and_reuses_scratch() {
        // Pin one backend for both sides: the process-wide active backend
        // can be flipped concurrently by other tests.
        let be = Backend::blocked();
        let mut rng = Pcg64::seeded(24);
        let mut ws = Workspace::new();
        let mut warm_misses = 0;
        for round in 0..3 {
            for &(m, n) in &[(6usize, 10usize), (10, 6), (8, 8)] {
                let g = rand_t(&mut rng, &[m, n]);
                let want = newton_schulz_with(be, &g, 5);
                let mut out = Tensor::filled(&[m, n], f32::NAN);
                newton_schulz_into(be, &g, 5, &mut out, &mut ws);
                for (x, y) in out.data.iter().zip(&want.data) {
                    assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
                }
            }
            if round == 0 {
                warm_misses = ws.misses();
            }
        }
        assert_eq!(ws.misses(), warm_misses, "steady-state NS must not allocate");
    }

    #[test]
    fn newton_schulz_preserves_singular_directions() {
        // For a diagonal matrix the NS iterate must stay (nearly) diagonal
        // with entries pushed toward +-1.
        let g = Tensor::from_vec(vec![0.9, 0.0, 0.0, 0.1], &[2, 2]);
        let o = newton_schulz(&g, 5);
        assert!(o.at(0, 1).abs() < 1e-4 && o.at(1, 0).abs() < 1e-4);
        assert!(o.at(0, 0) > 0.7, "{}", o.at(0, 0));
        assert!(o.at(1, 1) > 0.2, "{}", o.at(1, 1));
    }
}
