//! Dense host-side tensor substrate.
//!
//! The coordinator keeps parameters, gradients and predictor state on the
//! host as `Tensor`s (row-major f32); the heavy model math runs on the
//! PJRT device via AOT artifacts, but the optimizer, the predictor fit and
//! all diagnostics need a small, fast host linalg layer — this module.
//!
//! The dense kernels (matmul, Gram products, dot reductions) are pluggable:
//! `backend` defines the [`backend::TensorBackend`] trait with naive /
//! blocked / register-tiled micro-kernel / AVX2 SIMD implementations,
//! selected at startup by config or a calibration probe (DESIGN.md §2,
//! ADR-007). The free functions in `matmul` dispatch through the active
//! backend.

pub mod backend;
pub mod linalg;
pub mod matmul;
pub mod simd;
pub mod stats;
pub mod workspace;

pub use backend::{Backend, BackendKind};
pub use workspace::Workspace;

/// Row-major dense f32 tensor (rank 1 or 2 is all we need).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { data, shape: shape.to_vec() }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        Tensor { data: vec![v; shape.iter().product()], shape: shape.to_vec() }
    }

    /// Identity matrix n x n.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() needs a matrix");
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() needs a matrix");
        self.shape[1]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Transposed copy.
    pub fn t(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(self.len(), shape.iter().product::<usize>(), "reshape size mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// Elementwise in-place a += s * b (axpy).
    pub fn axpy(&mut self, s: f32, b: &Tensor) {
        assert_eq!(self.len(), b.len());
        for (x, y) in self.data.iter_mut().zip(&b.data) {
            *x += s * y;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        stats::norm(&self.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]);
        assert_eq!(t.at(0, 2), 3.0);
        assert_eq!(t.at(1, 0), 4.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn transpose() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]);
        let tt = t.t();
        assert_eq!(tt.shape, vec![3, 2]);
        assert_eq!(tt.at(2, 1), 6.0);
        assert_eq!(tt.t(), t);
    }

    #[test]
    fn eye_and_axpy() {
        let mut a = Tensor::eye(3);
        let b = Tensor::filled(&[3, 3], 2.0);
        a.axpy(0.5, &b);
        assert_eq!(a.at(0, 0), 2.0);
        assert_eq!(a.at(0, 1), 1.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::from_vec(vec![1.0; 5], &[2, 3]);
    }
}
