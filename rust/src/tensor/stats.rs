//! Vector statistics: dot products, norms, cosine alignment.
//!
//! The cosine here is the paper's Section 5.3 monitoring metric ρ̂ — the
//! alignment between per-example true and predicted gradients that governs
//! the break-even condition of Theorem 3.

/// Dot product with 4-way unrolled accumulators (auto-vectorizes well and
/// reduces rounding drift versus a single accumulator).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Dot product in f64 accumulation — used where catastrophic cancellation
/// matters (variance estimators for Prop. 2 validation).
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

pub fn norm(a: &[f32]) -> f32 {
    dot_f64(a, a).sqrt() as f32
}

/// Cosine alignment cos(a, b) in [-1, 1]; 0 if either vector is ~zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = dot_f64(a, a).sqrt();
    let nb = dot_f64(b, b).sqrt();
    if na < 1e-20 || nb < 1e-20 {
        return 0.0;
    }
    (dot_f64(a, b) / (na * nb)) as f32
}

pub fn mean(a: &[f32]) -> f32 {
    if a.is_empty() {
        return 0.0;
    }
    (a.iter().map(|&v| v as f64).sum::<f64>() / a.len() as f64) as f32
}

/// Sample mean and standard error over f64 observations — the "three
/// random seeds ± standard error" protocol of Figure 1.
pub fn mean_stderr(xs: &[f64]) -> (f64, f64) {
    let n = xs.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    let m = xs.iter().sum::<f64>() / n as f64;
    if n == 1 {
        return (m, 0.0);
    }
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
    (m, (var / n as f64).sqrt())
}

/// Running mean/variance (Welford) for streaming diagnostics.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_reference() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.01 - 0.5).collect();
        let b: Vec<f32> = (0..103).map(|i| ((i * 7 % 13) as f32) * 0.1).collect();
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - want).abs() < 1e-3);
    }

    #[test]
    fn cosine_basics() {
        let a = [1.0, 0.0, 0.0];
        let b = [0.0, 1.0, 0.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-6);
        assert!(cosine(&a, &b).abs() < 1e-6);
        let c = [-2.0, 0.0, 0.0];
        assert!((cosine(&a, &c) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&a, &[0.0, 0.0, 0.0]), 0.0);
    }

    #[test]
    fn welford_matches_closed_form() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let m = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 4.0;
        assert!((w.mean() - m).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn mean_stderr_basics() {
        let (m, se) = mean_stderr(&[2.0, 2.0, 2.0]);
        assert_eq!(m, 2.0);
        assert_eq!(se, 0.0);
        let (m, se) = mean_stderr(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert!(se > 0.0);
    }
}
