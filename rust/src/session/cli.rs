//! CLI → [`SessionBuilder`] adapter.
//!
//! `main.rs` stays a thin shell: every `lgp train` / `lgp sweep-f` flag
//! maps onto a typed builder setter here, inside the library, so the CLI
//! path and the programmatic path are the *same* path — the golden test
//! in `rust/tests/session_api.rs` pins that a flag string and the
//! equivalent setter chain produce bit-identical runs.
//!
//! Precedence (unchanged from the old `RunConfig::apply_*` scheme):
//! defaults < `--config file.json` < explicit flags.

use crate::config::RunConfig;
use crate::session::SessionBuilder;
use crate::util::cli::Args;
use std::path::PathBuf;

/// Build a [`SessionBuilder`] from parsed CLI arguments. Enum-valued
/// flags (`--algo`, `--optimizer`, `--backend`) fail here with the same
/// messages as the JSON path; range validation happens at
/// [`SessionBuilder::build`].
pub fn builder_from_args(args: &Args) -> anyhow::Result<SessionBuilder> {
    let mut b = SessionBuilder::new();
    if let Some(path) = args.str_opt("config") {
        let j = RunConfig::load_json_file(std::path::Path::new(&path))?;
        b = b.apply_json(&j)?;
    }
    if let Some(v) = args.str_opt("artifacts") {
        b = b.artifacts(PathBuf::from(v));
    } else if let Some(p) = args.str_opt("preset") {
        b = b.preset(&p);
    }
    if let Some(v) = args.str_opt("algo") {
        b = b.algo(v.parse()?);
    }
    if let Some(v) = args.str_opt("optimizer") {
        b = b.optimizer(v.parse()?);
    }
    if let Some(v) = args.str_opt("out") {
        b = b.out_dir(PathBuf::from(v));
    }
    if let Some(v) = args.str_opt("backend") {
        b = b.backend(v.parse()?);
    }
    if let Some(v) = args.str_opt("estimator") {
        b = b.estimator_kind(v.parse()?);
    }
    // Numeric flags: absent keeps the builder's current value (default <
    // json < cli precedence); present-but-malformed is a hard error, the
    // same contract as the env overrides (`util::env_parse`) — explicit
    // user input must never silently fall back.
    if let Some(v) = args.parsed::<f64>("f")? {
        b = b.f(v);
    }
    if let Some(v) = args.parsed::<usize>("accum")? {
        b = b.accum(v);
    }
    if let Some(v) = args.parsed::<f64>("lr")? {
        b = b.lr(v);
    }
    if let Some(v) = args.parsed::<f64>("weight-decay")? {
        b = b.weight_decay(v);
    }
    if let Some(v) = args.parsed::<f64>("budget")? {
        b = b.budget_secs(v);
    }
    if let Some(v) = args.parsed::<usize>("steps")? {
        b = b.max_steps(v);
    }
    if let Some(v) = args.parsed::<usize>("refit-every")? {
        b = b.refit_every(v);
    }
    if let Some(v) = args.parsed::<f64>("ridge")? {
        b = b.ridge_lambda(v);
    }
    if let Some(v) = args.parsed::<usize>("train-size")? {
        b = b.train_size(v);
    }
    if let Some(v) = args.parsed::<usize>("val-size")? {
        b = b.val_size(v);
    }
    if let Some(v) = args.parsed::<usize>("aug-mult")? {
        b = b.aug_multiplier(v);
    }
    if let Some(v) = args.parsed::<u64>("seed")? {
        b = b.seed(v);
    }
    if let Some(v) = args.parsed::<usize>("eval-every")? {
        b = b.eval_every(v);
    }
    if let Some(v) = args.parsed::<usize>("shards")? {
        b = b.shards(v);
    }
    if let Some(v) = args.parsed::<usize>("tangents")? {
        b = b.tangents(v);
    }
    if args.flag("no-alignment") {
        b = b.track_alignment(false);
    }
    if args.flag("adaptive-f") {
        b = b.adaptive_f(true);
    }
    if let Some(v) = args.str_opt("checkpoint-dir") {
        b = b.checkpoint_dir(PathBuf::from(v));
    }
    if let Some(v) = args.parsed::<usize>("checkpoint-every")? {
        b = b.checkpoint_every(v);
    }
    if let Some(v) = args.parsed::<usize>("checkpoint-keep")? {
        b = b.checkpoint_keep(v);
    }
    if args.flag("resume") {
        b = b.resume(true);
    }
    Ok(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, OptimKind};
    use crate::tensor::BackendKind;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn flags_map_onto_builder_setters() {
        let a = parse(
            "train --preset small --algo gpr --f 0.125 --steps 3 --seed 9 \
             --backend blocked --shards 2 --optimizer adamw --no-alignment",
        );
        let b = builder_from_args(&a).unwrap();
        let c = b.config();
        assert_eq!(c.artifacts_dir, PathBuf::from("artifacts/small"));
        assert_eq!(c.algo, Algo::Gpr);
        assert_eq!(c.optimizer, OptimKind::AdamW);
        assert_eq!(c.backend, BackendKind::Blocked);
        assert_eq!(c.max_steps, 3);
        assert_eq!(c.seed, 9);
        assert_eq!(c.shards, 2);
        assert!(!c.track_alignment);
        assert!((c.f - 0.125).abs() < 1e-12);
    }

    #[test]
    fn estimator_flags_map_onto_builder() {
        use crate::config::EstimatorKind;
        let a = parse("train --estimator mtf --tangents 32");
        let b = builder_from_args(&a).unwrap();
        assert_eq!(b.config().estimator, Some(EstimatorKind::MultiTangent));
        assert_eq!(b.config().tangents, 32);
        let a = parse("train --estimator nope");
        let err = builder_from_args(&a).unwrap_err();
        assert!(format!("{err}").contains("unknown estimator 'nope'"), "{err}");
    }

    #[test]
    fn checkpoint_flags_map_onto_builder() {
        let a = parse(
            "train --checkpoint-dir ckpts --checkpoint-every 5 --checkpoint-keep 3 --resume",
        );
        let b = builder_from_args(&a).unwrap();
        assert_eq!(b.config().checkpoint_dir, Some(PathBuf::from("ckpts")));
        assert_eq!(b.config().checkpoint_every, 5);
        assert_eq!(b.config().checkpoint_keep, 3);
        assert!(b.config().resume);
        let a = parse("train");
        let b = builder_from_args(&a).unwrap();
        assert_eq!(b.config().checkpoint_dir, None);
        assert_eq!(b.config().checkpoint_keep, 0, "retention is opt-in");
        assert!(!b.config().resume);
    }

    #[test]
    fn artifacts_flag_beats_preset_shorthand() {
        let a = parse("train --artifacts custom/dir --preset tiny");
        let b = builder_from_args(&a).unwrap();
        assert_eq!(b.config().artifacts_dir, PathBuf::from("custom/dir"));
    }

    #[test]
    fn bad_enum_flags_error_with_option_list() {
        let a = parse("train --algo nope");
        let err = builder_from_args(&a).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unknown algo 'nope'"), "{msg}");
        assert!(msg.contains("baseline|gpr"), "{msg}");
        let a = parse("train --backend gpu");
        assert!(builder_from_args(&a).is_err());
    }

    #[test]
    fn unset_flags_keep_defaults() {
        let a = parse("train");
        let b = builder_from_args(&a).unwrap();
        assert_eq!(b.config(), &RunConfig::default());
    }

    #[test]
    fn malformed_numeric_flags_error_instead_of_defaulting() {
        // `--steps 3O` (letter O) must not silently train with the
        // default step count — same contract as the env overrides.
        let a = parse("train --steps 3O");
        let err = builder_from_args(&a).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--steps") && msg.contains("3O"), "{msg}");
        let a = parse("train --f 0.2x");
        assert!(builder_from_args(&a).is_err());
    }
}
